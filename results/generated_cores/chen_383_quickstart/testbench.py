"""Auto-generated validation testbench (co-simulation analogue)."""
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))
import chen_383_quickstart as core  # noqa: E402


def run(verbose=True):
    from repro.kernels import ops
    from repro.core.ann import one_step_reference  # noqa: F401
    p = core.params()
    key = jax.random.PRNGKey(0)
    x0 = (jax.random.uniform(key, (core.S_BLOCK, core.I_DIM),
                             dtype=jnp.float32) - 0.5).astype(core.DTYPE)

    # 1) kernel vs oracle, short horizon (pre-divergence window; bf16's
    # ~8e-3 rounding is amplified ~2x/step by the chaotic map, so the
    # comparable window is shorter than f32's).  The ref backend routes
    # scalar cores to the independent x @ w oracle and lattice cores to
    # the bitwise-exact block-coupled oracle — one testbench for both.
    T = 3 if core.DTYPE == jnp.bfloat16 else 8
    got = core.generate(x0, T)
    want = ops.chaotic_trajectory(p, x0, T, activation=core.ACTIVATION,
                                  backend="ref",
                                  compute_unit=core.COMPUTE_UNIT)
    tol = 1.5e-1 if core.DTYPE == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)

    # 2) bounded trajectories over a long horizon (oscillator is stable)
    long = core.generate(x0, 512)
    assert bool(jnp.all(jnp.isfinite(long))), "trajectory diverged"
    assert float(jnp.max(jnp.abs(long))) < 10.0, "trajectory left attractor box"

    # 3) fused PRNG words are resumable: two chunked draws (state +
    # word_offset threaded through) equal one long draw, bit for bit
    words, _ = core.generate_bits(x0, 2048)
    w_a, mid = core.generate_bits(x0, 1024)
    w_b, _ = core.generate_bits(mid, 1024, word_offset=512)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(w_a), np.asarray(w_b)], axis=0),
        np.asarray(words))

    # 4) monobit randomness of emitted words
    ones = int(np.unpackbits(np.asarray(words).view(np.uint8)).sum())
    total = words.size * 32
    frac = ones / total
    assert abs(frac - 0.5) < 0.01, f"monobit bias {frac}"
    if verbose:
        print(f"TESTBENCH PASS: maxerr(T={T})="
              f"{float(jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))):.3g}"
              f" monobit={frac:.4f} resumable=yes")
    return True


if __name__ == "__main__":
    run()
