"""Registry-wide PRNG quality gate (NIST subset per system AND dtype).

The paper (§II, citing Yu et al.) claims ANN-based chaotic PRNGs pass the
NIST SP 800-22 suite; PR 1 verified that for the trained Chen f32 stream
only.  This module sweeps the gate across the whole weight registry and
both serving dtypes (f32 cores and half-width bf16 cores), so the farm can
*quarantine* a (system, dtype) whose bit quality regresses — a registry
entry may train fine yet emit biased words after the bf16 mantissa fold.

Used from tests (tier-1 gate: every f32 system must pass) and from
``benchmarks/farm.py`` (quarantined systems are marked in
BENCH_farm.json so a serving rollout can exclude them).

Two gates live here:

* :func:`nist_gate` / :func:`sweep_registry` — the OFFLINE sweep: the
  full 7-test subset over ``GATE_WORDS`` freshly generated words per
  (system, dtype), run from CI;
* :func:`online_gate` — the ONLINE monitor: a cheap 3-test subset
  (monobit, block frequency, runs) over a rolling window of words a
  farm core actually *served*, cheap enough to run per flush on the
  serving executor.  ``repro.serve.health.HealthMonitor`` feeds it and
  turns verdicts into quarantine + core rotation.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import jax.numpy as jnp
import numpy as np

from repro.prng.nist import (_to_bits, block_frequency, monobit, runs,
                             run_nist_subset)
from repro.prng.stream import ChaoticPRNG, default_params

GATE_WORDS = 30_000          # ~0.96 Mbit per gated stream
GATE_ALPHA = 0.01

# Online monitoring: one rolling window of served words per core.  4096
# words = 128 Kbit — enough that a poisoned stream hard-fails monobit in
# ONE window while a healthy stream's per-window soft-failure odds stay
# at the single-test alpha.
ONLINE_WINDOW_WORDS = 4096

# A single NIST test at alpha=0.01 has a ~1% false-positive rate; gating a
# whole registry on "zero failures anywhere" would flake.  A (system,
# dtype) is quarantined only when MORE tests fail than chance plausibly
# explains: >= 2 failed tests out of the 7-test subset (P[>=2 | p=0.01]
# ~ 2e-3 per stream), or any single test failing catastrophically
# (p-value below ALPHA_HARD, far outside false-positive territory).
ALPHA_HARD = 1e-6
MAX_CHANCE_FAILS = 1


def nist_gate(system: str, dtype: str = "float32", *,
              n_words: int = GATE_WORDS, alpha: float = GATE_ALPHA,
              n_streams: int = 256, seed: int = 0,
              backend: str = "auto") -> Dict[str, object]:
    """Run the NIST subset on one registry (system, dtype) stream.

    Draws through the same fused path the serving stack uses (``ChaoticPRNG``
    with the registry weights and the requested compute dtype), so the gate
    measures exactly what a farm core would emit.
    """
    params = default_params(system=system)
    eng = ChaoticPRNG(params, n_streams=n_streams, backend=backend,
                      dtype=jnp.dtype(dtype))
    words, _ = eng.next_words(eng.init(seed=seed), n_words)
    res = run_nist_subset(words, alpha=alpha)
    failed = sorted(k for k, v in res.items() if not v["passed"])
    hard_failed = sorted(k for k, v in res.items()
                         if v["p_value"] < ALPHA_HARD)
    quarantine = len(failed) > MAX_CHANCE_FAILS or bool(hard_failed)
    return {
        "system": system, "dtype": str(jnp.dtype(dtype)),
        "n_words": int(n_words),
        "failed_tests": failed, "hard_failed_tests": hard_failed,
        "p_values": {k: v["p_value"] for k, v in res.items()},
        "passed": not failed,
        "quarantined": quarantine,
    }


def online_gate(words: np.ndarray, *,
                alpha: float = GATE_ALPHA) -> Dict[str, object]:
    """Gate ONE rolling window of served words (the online monitor).

    Runs the cheap third of the NIST subset — monobit, block frequency,
    runs — over exactly the words given (no generation; the caller
    sampled them off a live core).  Returns the same verdict shape as
    :func:`nist_gate`: ``failed_tests`` are tests under ``alpha``
    (chance-plausible for a single window — the caller should demand
    consecutive failing windows before acting), ``hard_failed_tests``
    are tests under ``ALPHA_HARD`` (far outside false-positive
    territory: act immediately).
    """
    words = np.asarray(words, np.uint32).reshape(-1)
    if words.size == 0:
        raise ValueError("online_gate needs a non-empty word window")
    bits = _to_bits(words)
    p_values = {"monobit": monobit(bits),
                "block_frequency": block_frequency(bits),
                "runs": runs(bits)}
    failed = sorted(k for k, v in p_values.items() if v < alpha)
    hard_failed = sorted(k for k, v in p_values.items()
                         if v < ALPHA_HARD)
    return {"n_words": int(words.size), "p_values": p_values,
            "failed_tests": failed, "hard_failed_tests": hard_failed,
            "passed": not failed}


def sweep_registry(systems: Optional[Iterable[str]] = None,
                   dtypes: Iterable[str] = ("float32", "bfloat16"),
                   **gate_kw) -> Dict[str, Dict[str, object]]:
    """Gate every (system, dtype) pair; keys are '<system>/<dtype>'."""
    if systems is None:
        from repro.core.chaotic import SYSTEMS
        systems = sorted(SYSTEMS)
    return {f"{s}/{jnp.dtype(d)}": nist_gate(s, d, **gate_kw)
            for s in systems for d in dtypes}


def quarantined_systems(sweep: Dict[str, Dict[str, object]]) -> Dict[str, list]:
    """{system: [dtype, ...]} for every quarantined (system, dtype)."""
    out: Dict[str, list] = {}
    for res in sweep.values():
        if res["quarantined"]:
            out.setdefault(res["system"], []).append(res["dtype"])
    return out
