"""Registry-wide PRNG quality gate (NIST subset per system AND dtype).

The paper (§II, citing Yu et al.) claims ANN-based chaotic PRNGs pass the
NIST SP 800-22 suite; PR 1 verified that for the trained Chen f32 stream
only.  This module sweeps the gate across the whole weight registry and
both serving dtypes (f32 cores and half-width bf16 cores), so the farm can
*quarantine* a (system, dtype) whose bit quality regresses — a registry
entry may train fine yet emit biased words after the bf16 mantissa fold.

Used from tests (tier-1 gate: every f32 system must pass) and from
``benchmarks/farm.py`` (quarantined systems are marked in
BENCH_farm.json so a serving rollout can exclude them).
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import jax.numpy as jnp

from repro.prng.nist import run_nist_subset
from repro.prng.stream import ChaoticPRNG, default_params

GATE_WORDS = 30_000          # ~0.96 Mbit per gated stream
GATE_ALPHA = 0.01

# A single NIST test at alpha=0.01 has a ~1% false-positive rate; gating a
# whole registry on "zero failures anywhere" would flake.  A (system,
# dtype) is quarantined only when MORE tests fail than chance plausibly
# explains: >= 2 failed tests out of the 7-test subset (P[>=2 | p=0.01]
# ~ 2e-3 per stream), or any single test failing catastrophically
# (p-value below ALPHA_HARD, far outside false-positive territory).
ALPHA_HARD = 1e-6
MAX_CHANCE_FAILS = 1


def nist_gate(system: str, dtype: str = "float32", *,
              n_words: int = GATE_WORDS, alpha: float = GATE_ALPHA,
              n_streams: int = 256, seed: int = 0,
              backend: str = "auto") -> Dict[str, object]:
    """Run the NIST subset on one registry (system, dtype) stream.

    Draws through the same fused path the serving stack uses (``ChaoticPRNG``
    with the registry weights and the requested compute dtype), so the gate
    measures exactly what a farm core would emit.
    """
    params = default_params(system=system)
    eng = ChaoticPRNG(params, n_streams=n_streams, backend=backend,
                      dtype=jnp.dtype(dtype))
    words, _ = eng.next_words(eng.init(seed=seed), n_words)
    res = run_nist_subset(words, alpha=alpha)
    failed = sorted(k for k, v in res.items() if not v["passed"])
    hard_failed = sorted(k for k, v in res.items()
                         if v["p_value"] < ALPHA_HARD)
    quarantine = len(failed) > MAX_CHANCE_FAILS or bool(hard_failed)
    return {
        "system": system, "dtype": str(jnp.dtype(dtype)),
        "n_words": int(n_words),
        "failed_tests": failed, "hard_failed_tests": hard_failed,
        "p_values": {k: v["p_value"] for k, v in res.items()},
        "passed": not failed,
        "quarantined": quarantine,
    }


def sweep_registry(systems: Optional[Iterable[str]] = None,
                   dtypes: Iterable[str] = ("float32", "bfloat16"),
                   **gate_kw) -> Dict[str, Dict[str, object]]:
    """Gate every (system, dtype) pair; keys are '<system>/<dtype>'."""
    if systems is None:
        from repro.core.chaotic import SYSTEMS
        systems = sorted(SYSTEMS)
    return {f"{s}/{jnp.dtype(d)}": nist_gate(s, d, **gate_kw)
            for s in systems for d in dtypes}


def quarantined_systems(sweep: Dict[str, Dict[str, object]]) -> Dict[str, list]:
    """{system: [dtype, ...]} for every quarantined (system, dtype)."""
    out: Dict[str, list] = {}
    for res in sweep.values():
        if res["quarantined"]:
            out.setdefault(res["system"], []).append(res["dtype"])
    return out
