"""Chaotic-oscillator PRNG streams (the paper's end application).

The trained ANN oscillator (paper Fig. 1: MUX selecting seed vs feedback)
becomes a batched, jit-able random-bit source.  It is plugged into the LM
training stack as a first-class substrate: data-pipeline shuffling, dropout
masks, and stochastic rounding for gradient compression all draw from it.

Seeding: stream seeds are derived from a counter via a splitmix64-style hash
and placed in the normalized attractor box; sensitivity to initial conditions
gives stream independence after a short burn-in (Lyapunov decorrelation),
which the NIST subset in tests verifies empirically.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

_DEFAULT_WEIGHTS: Optional[Dict[str, np.ndarray]] = None


def _splitmix_seeds(counter: jax.Array, n_streams: int, dim: int) -> jax.Array:
    """Derive (S, I) normalized seeds in [-0.9, 0.9] from an integer counter."""
    idx = counter.astype(jnp.uint32) * jnp.uint32(0x9E3779B9) + jnp.arange(
        n_streams * dim, dtype=jnp.uint32).reshape(n_streams, dim) * jnp.uint32(0x85EBCA77)
    z = idx
    z = (z ^ (z >> jnp.uint32(16))) * jnp.uint32(0x7FEB352D)
    z = (z ^ (z >> jnp.uint32(15))) * jnp.uint32(0x846CA68B)
    z = z ^ (z >> jnp.uint32(16))
    return (z.astype(jnp.float32) / jnp.float32(2 ** 32) - 0.5) * 1.8


@dataclasses.dataclass
class ChaoticStream:
    """Stateful convenience wrapper over the stateless ``draw_*`` API."""

    params: Dict[str, jax.Array]
    activation: str = "relu"
    n_streams: int = 256
    burn_in: int = 16
    backend: str = "auto"
    counter: int = 0

    @classmethod
    def from_trained(cls, params, **kw) -> "ChaoticStream":
        return cls(params={k: jnp.asarray(v) for k, v in params.items()}, **kw)

    def _draw_words(self, n_words: int) -> jax.Array:
        p = self.params
        words = draw_words(p["w1"], p["b1"], p["w2"], p["b2"], self.counter,
                           n_words, self.n_streams, self.burn_in,
                           self.activation, self.backend)
        self.counter += 1
        return words

    def uniform(self, shape: Tuple[int, ...], dtype=jnp.float32) -> jax.Array:
        n = int(np.prod(shape)) if shape else 1
        words = self._draw_words(n)
        return (words[:n].astype(jnp.float32) / jnp.float32(2 ** 32)).reshape(shape).astype(dtype)

    def bits(self, n_words: int) -> jax.Array:
        return self._draw_words(n_words)[:n_words]

    def bernoulli(self, p: float, shape: Tuple[int, ...]) -> jax.Array:
        return self.uniform(shape) < p

    def permutation(self, n: int) -> jax.Array:
        """Random permutation via argsort of chaotic keys (shuffling)."""
        return jnp.argsort(self.bits(n))


@functools.partial(jax.jit, static_argnames=("n_words", "n_streams", "burn_in",
                                             "activation", "backend"))
def draw_words(w1, b1, w2, b2, counter: int, n_words: int, n_streams: int,
               burn_in: int, activation: str, backend: str) -> jax.Array:
    params = {"w1": w1, "b1": b1, "w2": w2, "b2": b2}
    dim = params["w1"].shape[0]
    x0 = _splitmix_seeds(jnp.asarray(counter, jnp.uint32), n_streams, dim)
    # 2 samples -> 1 word; streams interleave in the flattened output.
    steps_needed = 2 * ((n_words + n_streams - 1) // n_streams) + 2 * burn_in
    steps_needed = max(steps_needed, 4)
    traj = ops.chaotic_trajectory(params, x0, steps_needed,
                                  activation=activation, backend=backend)
    words = ops.bits_from_trajectory(traj[2 * burn_in // 2:])  # drop burn-in
    return words.reshape(-1)[:n_words]


def default_stream(n_streams: int = 256, seed: int = 0) -> ChaoticStream:
    """A ready-to-use stream over a Chen oscillator trained at import time
    (cached). Training takes ~3 s once per process."""
    global _DEFAULT_WEIGHTS
    if _DEFAULT_WEIGHTS is None:
        from repro.core.ann import AnnConfig, extract_parameters, train
        from repro.core.chaotic import make_dataset
        ds = make_dataset("chen", n_samples=20_000, seed=seed)
        params, _ = train(AnnConfig(hidden=8), ds, epochs=120, lr=3e-3, seed=seed)
        _DEFAULT_WEIGHTS = extract_parameters(params)
    return ChaoticStream.from_trained(_DEFAULT_WEIGHTS, n_streams=n_streams)
