from repro.prng.stream import (ChaoticPRNG, ChaoticStream, StreamState,
                               default_params, default_stream,
                               registry_fingerprint, trained_oscillator)
from repro.prng.nist import cross_correlation, run_nist_subset
from repro.prng.quality import (nist_gate, quarantined_systems,
                                sweep_registry)

__all__ = ["ChaoticPRNG", "ChaoticStream", "StreamState", "cross_correlation",
           "default_params", "default_stream", "nist_gate",
           "quarantined_systems", "registry_fingerprint", "run_nist_subset",
           "sweep_registry", "trained_oscillator"]
