from repro.prng.stream import (ChaoticPRNG, ChaoticStream, StreamState,
                               default_params, default_stream,
                               trained_oscillator)
from repro.prng.nist import cross_correlation, run_nist_subset

__all__ = ["ChaoticPRNG", "ChaoticStream", "StreamState", "cross_correlation",
           "default_params", "default_stream", "run_nist_subset",
           "trained_oscillator"]
