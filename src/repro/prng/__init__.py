from repro.prng.stream import ChaoticStream, default_stream
from repro.prng.nist import run_nist_subset

__all__ = ["ChaoticStream", "default_stream", "run_nist_subset"]
