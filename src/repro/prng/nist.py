"""NIST SP 800-22 subset (paper §II cites Yu et al. passing this suite).

Seven tests implemented from the NIST specification (Rukhin et al., 2001):
monobit frequency, block frequency, runs, longest-run-of-ones, cumulative
sums, serial, and approximate entropy.  Each returns a p-value; a sequence
passes a test at significance alpha=0.01 when p >= alpha.

Pure numpy (these run on extracted bit streams, not in the jit path).
"""
from __future__ import annotations

import math
from typing import Dict

import numpy as np
from scipy import special as sc


def _to_bits(words: np.ndarray) -> np.ndarray:
    """uint32 words -> flat 0/1 bit array (big-endian within each word)."""
    return np.unpackbits(np.ascontiguousarray(words.astype(np.uint32)).view(np.uint8))


def monobit(bits: np.ndarray) -> float:
    n = bits.size
    s = np.abs(2.0 * bits.sum() - n) / math.sqrt(n)
    return float(math.erfc(s / math.sqrt(2.0)))


def block_frequency(bits: np.ndarray, m: int = 128) -> float:
    n = bits.size
    nblocks = n // m
    pi = bits[: nblocks * m].reshape(nblocks, m).mean(axis=1)
    chi2 = 4.0 * m * np.sum((pi - 0.5) ** 2)
    return float(sc.gammaincc(nblocks / 2.0, chi2 / 2.0))


def runs(bits: np.ndarray) -> float:
    n = bits.size
    pi = bits.mean()
    if abs(pi - 0.5) >= 2.0 / math.sqrt(n):
        return 0.0
    v = 1 + int(np.sum(bits[1:] != bits[:-1]))
    num = abs(v - 2.0 * n * pi * (1 - pi))
    den = 2.0 * math.sqrt(2.0 * n) * pi * (1 - pi)
    return float(math.erfc(num / den))


def longest_run(bits: np.ndarray) -> float:
    """Longest-run-of-ones in 128-bit blocks (NIST M=128 variant)."""
    m = 128
    n = bits.size
    nblocks = n // m
    if nblocks < 49:
        m, k_vals, pis = 8, [1, 2, 3, 4], [0.2148, 0.3672, 0.2305, 0.1875]
        nblocks = n // m
    else:
        k_vals = [4, 5, 6, 7, 8, 9]
        pis = [0.1174, 0.2430, 0.2493, 0.1752, 0.1027, 0.1124]
    blocks = bits[: nblocks * m].reshape(nblocks, m)
    longest = np.zeros(nblocks, dtype=np.int64)
    run = np.zeros(nblocks, dtype=np.int64)
    for j in range(m):
        run = (run + 1) * blocks[:, j]
        longest = np.maximum(longest, run)
    counts = np.zeros(len(k_vals), dtype=np.float64)
    for i, k in enumerate(k_vals):
        if i == 0:
            counts[i] = np.sum(longest <= k)
        elif i == len(k_vals) - 1:
            counts[i] = np.sum(longest >= k)
        else:
            counts[i] = np.sum(longest == k)
    exp = nblocks * np.asarray(pis)
    chi2 = np.sum((counts - exp) ** 2 / exp)
    return float(sc.gammaincc((len(k_vals) - 1) / 2.0, chi2 / 2.0))


def cusum(bits: np.ndarray) -> float:
    n = bits.size
    x = 2.0 * bits.astype(np.float64) - 1.0
    s = np.cumsum(x)
    z = np.max(np.abs(s))
    if z == 0:
        return 0.0
    total = 0.0
    for k in range(int((-n / z + 1) // 4), int((n / z - 1) // 4) + 1):
        total += (sc.ndtr((4 * k + 1) * z / math.sqrt(n)) -
                  sc.ndtr((4 * k - 1) * z / math.sqrt(n)))
    for k in range(int((-n / z - 3) // 4), int((n / z - 1) // 4) + 1):
        total -= (sc.ndtr((4 * k + 3) * z / math.sqrt(n)) -
                  sc.ndtr((4 * k + 1) * z / math.sqrt(n)))
    return float(1.0 - total)


def _psi2(bits: np.ndarray, m: int) -> float:
    if m <= 0:
        return 0.0
    n = bits.size
    ext = np.concatenate([bits, bits[: m - 1]]) if m > 1 else bits
    # m-bit pattern index per position
    idx = np.zeros(n, dtype=np.int64)
    for j in range(m):
        idx = (idx << 1) | ext[j: j + n]
    counts = np.bincount(idx, minlength=2 ** m).astype(np.float64)
    return float((2 ** m / n) * np.sum(counts ** 2) - n)


def serial(bits: np.ndarray, m: int = 5) -> float:
    d1 = _psi2(bits, m) - _psi2(bits, m - 1)
    return float(sc.gammaincc(2 ** (m - 2), d1 / 2.0))


def approximate_entropy(bits: np.ndarray, m: int = 4) -> float:
    n = bits.size

    def phi(mm: int) -> float:
        if mm == 0:
            return 0.0
        ext = np.concatenate([bits, bits[:mm - 1]]) if mm > 1 else bits
        idx = np.zeros(n, dtype=np.int64)
        for j in range(mm):
            idx = (idx << 1) | ext[j: j + n]
        counts = np.bincount(idx, minlength=2 ** mm).astype(np.float64)
        c = counts[counts > 0] / n
        return float(np.sum(c * np.log(c)))

    ap_en = phi(m) - phi(m + 1)
    chi2 = 2.0 * n * (math.log(2.0) - ap_en)
    return float(sc.gammaincc(2 ** (m - 1), chi2 / 2.0))


ALL_TESTS = {
    "monobit": monobit,
    "block_frequency": block_frequency,
    "runs": runs,
    "longest_run": longest_run,
    "cusum": cusum,
    "serial": serial,
    "approximate_entropy": approximate_entropy,
}


def cross_correlation(words_a: np.ndarray, words_b: np.ndarray,
                      max_lag: int = 8) -> Dict[str, float]:
    """Independence check between two bit streams (fork-quality gate).

    For each lag in [0, max_lag], correlates the ±1 bit sequences; under
    independence each normalized correlation is ~N(0, 1), so the min p-value
    over lags is Bonferroni-corrected.  Returns {max_abs_corr, p_value}.
    """
    a = 2.0 * _to_bits(np.asarray(words_a)).astype(np.float64) - 1.0
    b = 2.0 * _to_bits(np.asarray(words_b)).astype(np.float64) - 1.0
    n = min(a.size, b.size)
    a, b = a[:n], b[:n]
    worst_z, worst_corr = 0.0, 0.0
    for lag in range(max_lag + 1):
        m = n - lag
        corr = float(np.dot(a[:m], b[lag:lag + m])) / m
        z = abs(corr) * math.sqrt(m)
        if z > worst_z:
            worst_z, worst_corr = z, corr
    p = math.erfc(worst_z / math.sqrt(2.0))
    return {"max_abs_corr": abs(worst_corr),
            "p_value": min(1.0, p * (max_lag + 1))}


def run_nist_subset(words: np.ndarray, alpha: float = 0.01) -> Dict[str, Dict[str, float]]:
    """Run all tests on uint32 words. Returns {test: {p_value, passed}}."""
    bits = _to_bits(np.asarray(words))
    out = {}
    for name, fn in ALL_TESTS.items():
        p = fn(bits)
        out[name] = {"p_value": p, "passed": bool(p >= alpha)}
    return out
