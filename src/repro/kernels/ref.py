"""Pure-jnp oracles for the Pallas kernels (the co-simulation references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def chaotic_ann_ref(w1: Array, b1: Array, w2: Array, b2: Array,
                    x0: Array, n_steps: int, activation: str = "relu") -> Array:
    """Iterate the I-H-I oscillator ``n_steps`` times for a batch of streams.

    Args:
      w1: (I, H); b1: (H,); w2: (H, I); b2: (I,)
      x0: (S, I) initial states, one independent oscillator per row.
    Returns:
      (n_steps, S, I) trajectory (excluding x0), in x0's dtype.
    """
    phi = {"relu": jax.nn.relu, "tanh": jnp.tanh, "sigmoid": jax.nn.sigmoid}[activation]
    dtype = x0.dtype

    def step(x, _):
        h = phi(x @ w1.astype(dtype) + b1.astype(dtype))
        y = h @ w2.astype(dtype) + b2.astype(dtype)
        return y, y

    _, traj = jax.lax.scan(step, x0, None, length=n_steps)
    return traj
