"""Pure-jnp oracles for the Pallas kernels (the co-simulation references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def chaotic_ann_ref(w1: Array, b1: Array, w2: Array, b2: Array,
                    x0: Array, n_steps: int, activation: str = "relu") -> Array:
    """Iterate the I-H-I oscillator ``n_steps`` times for a batch of streams.

    Args:
      w1: (I, H); b1: (H,); w2: (H, I); b2: (I,)
      x0: (S, I) initial states, one independent oscillator per row.
    Returns:
      (n_steps, S, I) trajectory (excluding x0), in x0's dtype.
    """
    phi = {"relu": jax.nn.relu, "tanh": jnp.tanh, "sigmoid": jax.nn.sigmoid}[activation]
    dtype = x0.dtype

    def step(x, _):
        h = phi(x @ w1.astype(dtype) + b1.astype(dtype))
        y = h @ w2.astype(dtype) + b2.astype(dtype)
        return y, y

    _, traj = jax.lax.scan(step, x0, None, length=n_steps)
    return traj


def chaotic_ann_lattice_ref(w1: Array, b1: Array, w2: Array, b2: Array,
                            x0: Array, n_steps: int,
                            activation: str = "relu", *, lattice,
                            coupling: Array | None = None,
                            compute_unit: str = "vpu") -> Array:
    """Block-coupled lattice oracle, bitwise identical to the Pallas kernels.

    Unlike ``chaotic_ann_ref`` (an independent ``x @ w`` formulation that
    matches the mxu kernel bitwise but the vpu kernel only to fp-order
    ulps), the lattice oracle scans the kernels' own ``_make_step`` closure
    on the kernels' own (I, S) layout — same expression tree, same
    accumulation order — so ref-vs-Pallas equality is exact for BOTH
    compute units, which is what pins down the coupled dynamics.

    Args:
      w1 (I, H), b1 (H,), w2 (H, I), b2 (I,), x0 (S, I) — lattice-expanded.
      lattice: static ``(n_nodes, base_dim, topology, strength)``.
      coupling: dense (I, I) operator; required when compute_unit="mxu".
      compute_unit: which kernel expression tree to mirror — the two units
        produce legitimately different (both deterministic) streams.
    Returns:
      (n_steps, S, I) trajectory (excluding x0), in x0's dtype.
    """
    from repro.kernels.chaotic_ann import _check_lattice, _make_step
    dtype = x0.dtype
    i_dim, h_dim = w1.shape
    _check_lattice(lattice, i_dim, i_dim)
    cpl = None
    if compute_unit == "mxu":
        if coupling is None:
            raise ValueError("mxu lattice oracle needs the coupling operand")
        cpl = coupling.astype(dtype)
    step = _make_step(
        w1.astype(dtype), b1.astype(dtype).reshape(-1, 1),
        w2.astype(dtype), b2.astype(dtype).reshape(-1, 1),
        activation=activation, compute_unit=compute_unit,
        i_dim=i_dim, h_dim=h_dim, lattice=lattice, cpl=cpl)

    def body(x, _):
        y = step(x)
        return y, y

    _, traj = jax.lax.scan(body, x0.T, None, length=n_steps)
    return traj.transpose(0, 2, 1)
