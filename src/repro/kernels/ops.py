"""Public jit'd entry points for the kernels package.

``chaotic_trajectory`` selects the Pallas kernel (interpret-mode on CPU,
compiled on TPU) or the pure-jnp reference, with a uniform (S, I) API.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.chaotic_ann import chaotic_ann_pallas

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())


def chaotic_trajectory(params: Dict[str, jax.Array], x0: jax.Array, n_steps: int,
                       *, activation: str = "relu", backend: str = "auto",
                       s_block: int = 256, t_block: int = 128, unroll: int = 1,
                       compute_unit: str = "vpu") -> jax.Array:
    """Generate (n_steps, S, I) oscillator trajectories.

    backend: 'auto' | 'pallas' | 'pallas_interpret' | 'ref'.
    'auto' uses the compiled Pallas kernel on TPU and interpret mode on CPU.
    """
    w1, b1, w2, b2 = params["w1"], params["b1"], params["w2"], params["b2"]
    if backend == "ref":
        return ref.chaotic_ann_ref(w1, b1, w2, b2, x0, n_steps, activation)
    interpret = (backend == "pallas_interpret") or (backend == "auto" and not _ON_TPU)
    return chaotic_ann_pallas(
        w1, b1, w2, b2, x0, n_steps=n_steps, s_block=s_block, t_block=t_block,
        unroll=unroll, activation=activation, compute_unit=compute_unit,
        interpret=interpret)


def uniform_from_trajectory(traj: jax.Array, scale_bits: int = 23) -> jax.Array:
    """Map trajectory floats in [-1, 1]-ish range to uniform [0, 1) floats by
    keeping the chaotic low-order mantissa bits (the PRNG post-processing
    stage of the paper's Fig. 1 oscillator-as-PRNG usage)."""
    bits = bits_from_trajectory(traj)
    return bits.astype(jnp.float32) / jnp.float32(2 ** 32)


def bits_from_trajectory(traj: jax.Array) -> jax.Array:
    """Extract uint32 words from chaotic samples.

    Chaotic trajectories are smooth at the top of the mantissa but the low
    mantissa bits decorrelate in a few steps (positive Lyapunov exponent).
    Following the standard chaotic-PRNG recipe, we take the low 16 mantissa
    bits of each f32 sample and pack two consecutive samples per u32 word,
    XOR-folded with a golden-ratio Weyl sequence to whiten residual bias.
    Input (..., I) floats; output (...,) uint32 (I folded in).
    """
    x = traj.astype(jnp.float32)
    u = jax.lax.bitcast_convert_type(x, jnp.uint32)
    lo = u & jnp.uint32(0xFFFF)
    # Fold the I system dimensions together (they are strongly coupled but
    # their low bits differ; XOR with odd shifts mixes them).
    folded = lo[..., 0]
    for i in range(1, traj.shape[-1]):
        folded = folded ^ (lo[..., i] << jnp.uint32(5 * i % 16))
    # Pack pairs along the leading (time) axis into 32-bit words.
    t = folded.shape[0] // 2
    words = (folded[0:2 * t:2] << jnp.uint32(16)) | folded[1:2 * t:2]
    # Weyl whitening.
    idx = jnp.arange(t, dtype=jnp.uint32)
    weyl = idx * jnp.uint32(0x9E3779B9)
    words = words ^ weyl.reshape((t,) + (1,) * (words.ndim - 1))
    # Final avalanche (xorshift-multiply, Murmur3 finalizer style).
    words = words ^ (words >> jnp.uint32(16))
    words = words * jnp.uint32(0x85EBCA6B)
    words = words ^ (words >> jnp.uint32(13))
    words = words * jnp.uint32(0xC2B2AE35)
    words = words ^ (words >> jnp.uint32(16))
    return words
