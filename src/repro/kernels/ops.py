"""Public jit'd entry points for the kernels package.

``chaotic_trajectory`` selects the Pallas kernel (interpret-mode on CPU,
compiled on TPU) or the pure-jnp reference, with a uniform (S, I) API.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

import numpy as np

from repro.kernels import ref
from repro.kernels.chaotic_ann import (chaotic_ann_bits_pallas,
                                       chaotic_ann_gang_bits_pallas,
                                       chaotic_ann_gang_bits_sharded,
                                       chaotic_ann_gang_stacked_pallas,
                                       chaotic_ann_gang_stacked_sharded,
                                       chaotic_ann_pallas,
                                       gang_effective_rows,
                                       gang_partition_maps)

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())


def _kernel_kwargs(config) -> Dict[str, object]:
    """Kernel microarchitecture kwargs from a DSE ``Candidate``."""
    return dict(s_block=config.s_block, t_block=config.t_block,
                unroll=config.unroll, compute_unit=config.compute_unit)


def _lattice_args(params: Dict[str, jax.Array], compute_unit: str):
    """Lattice routing from a params dict.

    A block-coupled lattice core carries two extra keys next to the
    standard (lattice-expanded) ``w1/b1/w2/b2``: ``lattice_meta`` (the
    static descriptor) and ``coupling`` (the dense (I, I) operator).
    Returns ``(lattice, coupling)`` for the kernels — coupling only on the
    mxu route, where it is a resident MXU operand; the vpu kernels rebuild
    the operator from the descriptor as wrapped rolls.
    Scalar cores return ``(None, None)`` and every call site degrades to
    the exact pre-lattice behavior.
    """
    if "lattice_meta" not in params:
        return None, None
    from repro.core.ann import lattice_meta_tuple
    lattice = lattice_meta_tuple(np.asarray(params["lattice_meta"]))
    cpl = None
    if compute_unit == "mxu":
        cpl = jnp.asarray(params["coupling"])
    return lattice, cpl


def chaotic_trajectory(params: Dict[str, jax.Array], x0: jax.Array, n_steps: int,
                       *, activation: str = "relu", backend: str = "auto",
                       s_block: int = 256, t_block: int = 128, unroll: int = 1,
                       compute_unit: str = "vpu", config=None) -> jax.Array:
    """Generate (n_steps, S, I) oscillator trajectories.

    backend: 'auto' | 'pallas' | 'pallas_interpret' | 'ref'.
    'auto' uses the compiled Pallas kernel on TPU and interpret mode on CPU.
    config: optional ``repro.core.dse.Candidate`` — when given, overrides the
    explicit (s_block, t_block, unroll, compute_unit) arguments so the DSE
    output drives the kernel instantiation.
    """
    w1, b1, w2, b2 = params["w1"], params["b1"], params["w2"], params["b2"]
    kw = dict(s_block=s_block, t_block=t_block, unroll=unroll,
              compute_unit=compute_unit)
    if config is not None:
        kw = _kernel_kwargs(config)
    lattice, cpl = _lattice_args(params, kw["compute_unit"])
    if backend == "ref":
        if lattice is not None:
            return ref.chaotic_ann_lattice_ref(
                w1, b1, w2, b2, x0, n_steps, activation, lattice=lattice,
                coupling=cpl, compute_unit=kw["compute_unit"])
        return ref.chaotic_ann_ref(w1, b1, w2, b2, x0, n_steps, activation)
    interpret = (backend == "pallas_interpret") or (backend == "auto" and not _ON_TPU)
    return chaotic_ann_pallas(
        w1, b1, w2, b2, x0, cpl, n_steps=n_steps, activation=activation,
        lattice=lattice, interpret=interpret, **kw)


def chaotic_bits(params: Dict[str, jax.Array], x0: jax.Array, n_steps: int,
                 word_offset=0, *, activation: str = "relu",
                 backend: str = "auto", s_block: int = 256,
                 t_block: int = 128, unroll: int = 1,
                 compute_unit: str = "vpu",
                 config=None) -> Tuple[jax.Array, jax.Array]:
    """Fused PRNG draw: (n_steps // 2, S) uint32 words + (S, I) final state.

    The pallas backends use the fused kernel (trajectory never reaches HBM
    as floats); the 'ref' backend materializes the reference trajectory and
    packs it with ``pack_words`` — both produce the same words for the same
    float trajectory, which is the co-simulation contract tested in
    tests/test_fused_bits.py.
    """
    w1, b1, w2, b2 = params["w1"], params["b1"], params["w2"], params["b2"]
    kw = dict(s_block=s_block, t_block=t_block, unroll=unroll,
              compute_unit=compute_unit)
    if config is not None:
        kw = _kernel_kwargs(config)
    lattice, cpl = _lattice_args(params, kw["compute_unit"])
    if backend == "ref":
        if lattice is not None:
            traj = ref.chaotic_ann_lattice_ref(
                w1, b1, w2, b2, x0, n_steps, activation, lattice=lattice,
                coupling=cpl, compute_unit=kw["compute_unit"])
        else:
            traj = ref.chaotic_ann_ref(w1, b1, w2, b2, x0, n_steps,
                                       activation)
        return pack_words(traj, word_offset), traj[-1]
    interpret = (backend == "pallas_interpret") or (backend == "auto" and not _ON_TPU)
    return chaotic_ann_bits_pallas(
        w1, b1, w2, b2, x0, word_offset, cpl, n_steps=n_steps,
        activation=activation, lattice=lattice, interpret=interpret, **kw)


def chaotic_bits_gang(params: Dict[str, jax.Array], x0: jax.Array,
                      n_steps: int, word_offset=0, *, core_map,
                      row_map=None,
                      activation: str = "relu", backend: str = "auto",
                      s_block: int = 256, t_block: int = 128,
                      unroll: int = 1, compute_unit: str = "vpu",
                      mesh=None, mesh_axis: str = "data",
                      partitioner=None,
                      config=None) -> Tuple[jax.Array, jax.Array]:
    """Gang-scheduled fused PRNG draw: C stacked networks, ONE launch.

    ``params`` carries a leading core axis (w1 (C, I, H), b1 (C, H),
    w2 (C, H, I), b2 (C, I)); ``x0`` is the concatenated (S, I) stream pool
    with each ``s_block``-lane block homogeneous in core, and
    ``core_map[g]`` names the weight slab of block ``g``.  Lanes evolve
    independently, so per lane the result is bit-identical to a per-core
    ``chaotic_bits`` launch with that lane's network — the property the
    farm's gang scheduler relies on (tests/test_gang.py).

    ``row_map`` (optional, same shape as ``core_map``) makes the launch
    demand-shaped: block ``g`` computes only
    ``gang_effective_rows(row_map, n_steps, t_block, unroll)[g]`` word
    rows (its demand rounded up to the kernel's unroll-chunk granularity)
    and its state advances by exactly that many; word rows past a block's
    effective demand are unwritten garbage that callers must slice away.

    The 'ref' backend replays each lane block through the reference
    trajectory + ``pack_words`` with its own weights (C tiny launches),
    keeping the usual co-simulation contract — including the effective-row
    rounding of a ragged launch (garbage rows are zero-filled there).

    ``mesh``/``mesh_axis`` (pallas backends only) shard the launch across
    the named device axis: the pool and both scalar-prefetch maps
    partition on the lane/block axis while the weight slabs replicate, so
    one *logical* gang launch spans every device bit-identically.
    ``partitioner`` overrides the per-device map partitioner (default
    ``gang_partition_maps``, which pads the block axis with dead zero-row
    blocks until it divides the device count).  The 'ref' oracle ignores
    the mesh — sharding must never change the words.
    """
    kw = dict(s_block=s_block, t_block=t_block, unroll=unroll,
              compute_unit=compute_unit)
    if config is not None:
        kw = _kernel_kwargs(config)
    lattice, cpl = _lattice_args(params, kw["compute_unit"])
    if backend == "ref":
        s_blk = kw["s_block"]
        cmap = [int(c) for c in jnp.asarray(core_map)]
        eff = (gang_effective_rows(row_map, n_steps, kw["t_block"],
                                   kw["unroll"])
               if row_map is not None else
               np.full(len(cmap), n_steps // 2, np.int32))
        off = jnp.broadcast_to(jnp.asarray(word_offset, jnp.uint32),
                               (x0.shape[0],))
        n_rows = n_steps // 2
        words_parts, state_parts = [], []
        for g, c in enumerate(cmap):
            xg = x0[g * s_blk:(g + 1) * s_blk]
            r_g = int(eff[g])
            if r_g == 0:
                words_parts.append(jnp.zeros((n_rows, s_blk), jnp.uint32))
                state_parts.append(xg)
                continue
            if lattice is not None:
                traj = ref.chaotic_ann_lattice_ref(
                    params["w1"][c], params["b1"][c], params["w2"][c],
                    params["b2"][c], xg, 2 * r_g, activation,
                    lattice=lattice, coupling=cpl,
                    compute_unit=kw["compute_unit"])
            else:
                traj = ref.chaotic_ann_ref(
                    params["w1"][c], params["b1"][c], params["w2"][c],
                    params["b2"][c], xg, 2 * r_g, activation)
            w = pack_words(traj, off[g * s_blk:(g + 1) * s_blk])
            if r_g < n_rows:
                w = jnp.concatenate(
                    [w, jnp.zeros((n_rows - r_g, s_blk), jnp.uint32)])
            words_parts.append(w)
            state_parts.append(traj[-1])
        return (jnp.concatenate(words_parts, axis=1),
                jnp.concatenate(state_parts, axis=0))
    interpret = (backend == "pallas_interpret") or (backend == "auto" and not _ON_TPU)
    rmap = None if row_map is None else jnp.asarray(row_map, jnp.int32)
    if mesh is not None and int(mesh.shape[mesh_axis]) > 1:
        n_dev = int(mesh.shape[mesh_axis])
        part = partitioner if partitioner is not None else gang_partition_maps
        cmap_p, rmap_p, pad = part(core_map, rmap, n_dev=n_dev,
                                   n_rows=n_steps // 2)
        s_total = x0.shape[0]
        xp, offp = x0, jnp.broadcast_to(
            jnp.asarray(word_offset, jnp.uint32), (s_total,))
        if pad:
            s_blk = kw["s_block"]
            xp = jnp.concatenate(
                [x0, jnp.zeros((pad * s_blk, x0.shape[1]), x0.dtype)])
            offp = jnp.concatenate(
                [offp, jnp.zeros(pad * s_blk, jnp.uint32)])
        words, state = chaotic_ann_gang_bits_sharded(
            params["w1"], params["b1"], params["w2"], params["b2"], xp,
            cmap_p, offp, rmap_p, cpl, mesh=mesh, mesh_axis=mesh_axis,
            n_steps=n_steps, activation=activation, lattice=lattice,
            interpret=interpret, **kw)
        if pad:
            words, state = words[:, :s_total], state[:s_total]
        return words, state
    return chaotic_ann_gang_bits_pallas(
        params["w1"], params["b1"], params["w2"], params["b2"], x0,
        core_map, word_offset, rmap, cpl, n_steps=n_steps,
        activation=activation, lattice=lattice, interpret=interpret, **kw)


def chaotic_bits_gang_stacked(params: Dict[str, jax.Array], x0: jax.Array,
                              n_steps: int, word_offset=0, *,
                              row_map=None,
                              activation: str = "relu",
                              backend: str = "auto", s_block: int = 256,
                              t_block: int = 128, unroll: int = 1,
                              compute_unit: str = "vpu",
                              mesh=None, mesh_axis: str = "data",
                              config=None) -> Tuple[jax.Array, jax.Array]:
    """Sublane-stacked gang draw for C EQUAL-shape pools: one grid cell
    advances the whole group.

    ``params`` carries a leading core axis; ``x0`` is (C, S, I) — one pool
    per core, all the same shape.  The fast path for homogeneous farm
    groups (see ``chaotic_ann_gang_stacked_pallas``); ragged groups go
    through ``chaotic_bits_gang``.  vpu groups only — the stacked update
    is the broadcast-FMA order itself.

    ``row_map`` (optional, (C,)) freezes core ``c``'s state after exactly
    ``row_map[c]`` word rows (no FMA saved — the sublane stack is one
    fused sweep — but the core's final state and word prefix match a
    per-core launch of that many rows, so a demand-shaped absorb never
    buffers overdraw).  Word rows past a core's demand are garbage.
    Returns words (n_steps // 2, C, S) and final state (C, S, I).

    ``mesh``/``mesh_axis`` (pallas backends only) shard the equal-size
    pools on the STREAM axis across the named device axis — every device
    keeps the full sublane stack with 1/n_dev of each pool's lanes; the
    pool size must divide the device count (the gang scheduler checks
    this before choosing the stacked layout on a mesh).  The 'ref' oracle
    ignores the mesh.
    """
    kw = dict(s_block=s_block, t_block=t_block, unroll=unroll,
              compute_unit=compute_unit)
    if config is not None:
        kw = _kernel_kwargs(config)
    lattice, cpl = _lattice_args(params, kw["compute_unit"])
    if backend == "ref":
        n_cores = x0.shape[0]
        n_rows = n_steps // 2
        rows = (np.minimum(np.asarray(row_map, np.int64), n_rows)
                if row_map is not None else
                np.full(n_cores, n_rows, np.int64))
        off = jnp.broadcast_to(jnp.asarray(word_offset, jnp.uint32),
                               x0.shape[:2])
        words_parts, state_parts = [], []
        for c in range(n_cores):
            r_c = int(rows[c])
            if r_c == 0:
                words_parts.append(
                    jnp.zeros((n_rows, x0.shape[1]), jnp.uint32))
                state_parts.append(x0[c])
                continue
            if lattice is not None:
                traj = ref.chaotic_ann_lattice_ref(
                    params["w1"][c], params["b1"][c], params["w2"][c],
                    params["b2"][c], x0[c], 2 * r_c, activation,
                    lattice=lattice, coupling=cpl,
                    compute_unit=kw["compute_unit"])
            else:
                traj = ref.chaotic_ann_ref(
                    params["w1"][c], params["b1"][c], params["w2"][c],
                    params["b2"][c], x0[c], 2 * r_c, activation)
            w = pack_words(traj, off[c])
            if r_c < n_rows:
                w = jnp.concatenate(
                    [w, jnp.zeros((n_rows - r_c, x0.shape[1]), jnp.uint32)])
            words_parts.append(w)
            state_parts.append(traj[-1])
        return (jnp.stack(words_parts, axis=1),
                jnp.stack(state_parts, axis=0))
    interpret = (backend == "pallas_interpret") or (backend == "auto" and not _ON_TPU)
    rmap = None if row_map is None else jnp.asarray(row_map, jnp.int32)
    if mesh is not None and int(mesh.shape[mesh_axis]) > 1:
        return chaotic_ann_gang_stacked_sharded(
            params["w1"], params["b1"], params["w2"], params["b2"], x0,
            word_offset, rmap, mesh=mesh, mesh_axis=mesh_axis,
            n_steps=n_steps, activation=activation, lattice=lattice,
            interpret=interpret, **kw)
    return chaotic_ann_gang_stacked_pallas(
        params["w1"], params["b1"], params["w2"], params["b2"], x0,
        word_offset, rmap, n_steps=n_steps, activation=activation,
        lattice=lattice, interpret=interpret, **kw)


def uniform_from_trajectory(traj: jax.Array) -> jax.Array:
    """Map trajectory floats in [-1, 1]-ish range to uniform [0, 1) floats by
    keeping the chaotic low-order mantissa bits (the PRNG post-processing
    stage of the paper's Fig. 1 oscillator-as-PRNG usage).

    Uses the top 24 bits so every representable output is strictly < 1.0
    (dividing the full u32 by 2^32 rounds words near 2^32 up to exactly 1.0
    in f32, breaking the half-open-interval contract).
    """
    bits = bits_from_trajectory(traj)
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2 ** -24)


def _fold_low16(traj: jax.Array) -> jax.Array:
    """(..., I) floats -> (...,) uint32: low mantissa bits, I folded in.

    Chaotic trajectories are smooth at the top of the mantissa but the low
    mantissa bits decorrelate in a few steps (positive Lyapunov exponent).
    The I system dimensions are strongly coupled but their low bits differ;
    XOR with odd shifts mixes them.

    For f32 the low 16 bits of the bit pattern are taken.  Half-width
    floats are bitcast at their own width and masked to their mantissa —
    casting bf16 up to f32 first would leave the low 16 bits all zero and
    emit a zero-entropy counter hash.
    """
    if traj.dtype.itemsize == 2:
        u = jax.lax.bitcast_convert_type(traj, jnp.uint16).astype(jnp.uint32)
        mask = (1 << jnp.finfo(traj.dtype).nmant) - 1
        lo = u & jnp.uint32(mask)
    else:
        u = jax.lax.bitcast_convert_type(traj.astype(jnp.float32), jnp.uint32)
        lo = u & jnp.uint32(0xFFFF)
    folded = lo[..., 0]
    for i in range(1, traj.shape[-1]):
        folded = folded ^ (lo[..., i] << jnp.uint32(5 * i % 16))
    return folded


def _finalize_words(words: jax.Array) -> jax.Array:
    """Final avalanche (xorshift-multiply, Murmur3 finalizer style)."""
    words = words ^ (words >> jnp.uint32(16))
    words = words * jnp.uint32(0x85EBCA6B)
    words = words ^ (words >> jnp.uint32(13))
    words = words * jnp.uint32(0xC2B2AE35)
    words = words ^ (words >> jnp.uint32(16))
    return words


def bits_from_trajectory(traj: jax.Array) -> jax.Array:
    """Extract uint32 words from chaotic samples.

    Following the standard chaotic-PRNG recipe, we take the low 16 mantissa
    bits of each f32 sample and pack two consecutive samples per u32 word,
    XOR-folded with a golden-ratio Weyl sequence to whiten residual bias.
    Input (..., I) floats; output (...,) uint32 (I folded in).
    """
    folded = _fold_low16(traj)
    # Pack pairs along the leading (time) axis into 32-bit words.
    t = folded.shape[0] // 2
    words = (folded[0:2 * t:2] << jnp.uint32(16)) | folded[1:2 * t:2]
    # Weyl whitening.
    idx = jnp.arange(t, dtype=jnp.uint32)
    weyl = idx * jnp.uint32(0x9E3779B9)
    words = words ^ weyl.reshape((t,) + (1,) * (words.ndim - 1))
    return _finalize_words(words)


def pack_words(traj: jax.Array, word_offset=0) -> jax.Array:
    """Offset-aware reference of the fused kernel's packing stage.

    traj: (T, S, I) floats with T even.  word_offset: scalar or (S,) uint32,
    the global word-row index of the first packed row (per stream).  Equal to
    ``bits_from_trajectory(traj)`` when word_offset == 0; the offset is what
    lets a chunked, resumable stream reproduce one long draw bit-exactly.
    Returns (T // 2, S) uint32.
    """
    folded = _fold_low16(traj)
    t = folded.shape[0] // 2
    words = (folded[0:2 * t:2] << jnp.uint32(16)) | folded[1:2 * t:2]
    off = jnp.asarray(word_offset, jnp.uint32)
    idx = jnp.arange(t, dtype=jnp.uint32)[:, None] + off[None, ...]
    words = words ^ (idx * jnp.uint32(0x9E3779B9))
    return _finalize_words(words)
