"""Pallas TPU kernel: fused ANN-based chaotic oscillator (the HENNC core).

TPU adaptation of the paper's chaotic unit (Fig. 1).  On FPGA the unit is a
MAC array with parallelism ``P`` multipliers; on TPU the throughput unit is a
*block of independent oscillator streams* mapped onto the vector lanes:

  - streams live on the 128-wide lane axis (``s_block`` a multiple of 128),
  - the I/H feature dims live on the 8-deep sublane axis,
  - the oscillator state is carried in a VMEM scratch buffer across the whole
    time grid — the feedback path (output -> next input) never touches HBM,
  - only finished trajectory blocks (t_block steps) are streamed out to HBM.

Two compute-unit modes, mirroring the paper's DSP-vs-LUT choice:
  - ``vpu``: the two tiny matmuls are computed as I (resp. H) broadcast
    fused-multiply-adds over (H, s_block) / (I, s_block) vregs — full lane
    utilization, no MXU padding waste (I, H << 128).
  - ``mxu``: ``jnp.dot`` — contraction dims are MXU-padded to 128; wasteful
    for I=3 but included as a real design-space axis (it wins for large H).

Grid: (S/s_block, T/t_block); the T axis iterates fastest (TPU grids execute
sequentially minor-to-major), so the per-stream-block state scratch is
initialized at t==0 and carried across t blocks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces (present in jax 0.8.x)
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except ImportError:  # pragma: no cover
    _VMEM = None

LANES = 128
SUBLANES = 8


def _activation(name: str):
    return {"relu": jax.nn.relu, "tanh": jnp.tanh, "sigmoid": jax.nn.sigmoid}[name]


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _make_step(w1, b1, w2, b2, *, activation: str, compute_unit: str,
               i_dim: int, h_dim: int):
    """Shared oscillator update used by every kernel in this module.

    Operates on x of shape (I_pad, s): padded feature rows of the weights are
    zero, so padding never contaminates live rows.
    """
    phi = _activation(activation)

    def one_step(x):
        if compute_unit == "mxu":
            h = phi(jnp.dot(w1.T, x, preferred_element_type=jnp.float32)
                    .astype(x.dtype) + b1)
            y = jnp.dot(w2.T, h, preferred_element_type=jnp.float32)
            return y.astype(x.dtype) + b2
        # VPU path: broadcast-FMA over lanes; static unroll over tiny dims.
        h = jnp.zeros((w1.shape[1], x.shape[1]), x.dtype)
        for i in range(i_dim):
            h = h + w1[i, :][:, None] * x[i, :][None, :]
        h = phi(h + b1)
        y = jnp.zeros_like(x)
        for j in range(h_dim):
            y = y + w2[j, :][:, None] * h[j, :][None, :]
        return y + b2

    return one_step


def _kernel(w1_ref, b1_ref, w2_ref, b2_ref, x0_ref, out_ref, state_ref,
            *, t_block: int, unroll: int, activation: str, compute_unit: str,
            i_dim: int, h_dim: int):
    """One (stream-block, time-block) grid cell.

    Ref shapes (per block):
      w1: (I_pad, H_pad)  b1: (H_pad, 1)  w2: (H_pad, I_pad)  b2: (I_pad, 1)
      x0: (I_pad, s_block)      out: (t_block, I_pad, s_block)
      state (VMEM scratch): (I_pad, s_block)
    """
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        state_ref[...] = x0_ref[...]

    one_step = _make_step(w1_ref[...], b1_ref[...], w2_ref[...], b2_ref[...],
                          activation=activation, compute_unit=compute_unit,
                          i_dim=i_dim, h_dim=h_dim)

    def unrolled_chunk(x, base):
        for u in range(unroll):
            x = one_step(x)
            out_ref[base + u] = x
        return x

    x = state_ref[...]
    n_chunks = t_block // unroll
    if n_chunks == 1:
        x = unrolled_chunk(x, 0)
    else:
        def body(c, x):
            return unrolled_chunk(x, c * unroll)
        x = jax.lax.fori_loop(0, n_chunks, body, x)
    state_ref[...] = x


@functools.partial(
    jax.jit,
    static_argnames=("n_steps", "s_block", "t_block", "unroll", "activation",
                     "compute_unit", "interpret"),
)
def chaotic_ann_pallas(w1, b1, w2, b2, x0, *, n_steps: int,
                       s_block: int = 256, t_block: int = 128, unroll: int = 1,
                       activation: str = "relu", compute_unit: str = "vpu",
                       interpret: bool = False):
    """Run the fused oscillator kernel.

    Args:
      w1 (I, H), b1 (H,), w2 (H, I), b2 (I,), x0 (S, I).
      n_steps: total steps (padded up to a multiple of t_block internally).
      s_block/t_block/unroll/compute_unit: DSE-searchable microarchitecture.
    Returns:
      (n_steps, S, I) trajectory matching ``ref.chaotic_ann_ref``.
    """
    i_dim, h_dim = w1.shape
    s_total = x0.shape[0]
    dtype = x0.dtype
    if t_block % unroll:
        raise ValueError(f"t_block {t_block} must be divisible by unroll {unroll}")

    i_pad = _pad_to(max(i_dim, 1), SUBLANES)
    h_pad = _pad_to(max(h_dim, 1), SUBLANES)
    s_pad = _pad_to(s_total, s_block)
    t_pad = _pad_to(n_steps, t_block)

    w1p = jnp.zeros((i_pad, h_pad), dtype).at[:i_dim, :h_dim].set(w1.astype(dtype))
    b1p = jnp.zeros((h_pad, 1), dtype).at[:h_dim, 0].set(b1.astype(dtype))
    w2p = jnp.zeros((h_pad, i_pad), dtype).at[:h_dim, :i_dim].set(w2.astype(dtype))
    b2p = jnp.zeros((i_pad, 1), dtype).at[:i_dim, 0].set(b2.astype(dtype))
    # (S, I) -> (I_pad, S_pad): streams on lanes.
    x0p = jnp.zeros((i_pad, s_pad), dtype).at[:i_dim, :s_total].set(x0.T.astype(dtype))

    grid = (s_pad // s_block, t_pad // t_block)
    scratch = [_VMEM((i_pad, s_block), dtype)] if _VMEM is not None else []

    out = pl.pallas_call(
        functools.partial(_kernel, t_block=t_block, unroll=unroll,
                          activation=activation, compute_unit=compute_unit,
                          i_dim=i_dim, h_dim=h_dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((i_pad, h_pad), lambda s, t: (0, 0)),    # w1
            pl.BlockSpec((h_pad, 1), lambda s, t: (0, 0)),        # b1
            pl.BlockSpec((h_pad, i_pad), lambda s, t: (0, 0)),    # w2
            pl.BlockSpec((i_pad, 1), lambda s, t: (0, 0)),        # b2
            pl.BlockSpec((i_pad, s_block), lambda s, t: (0, s)),  # x0
        ],
        out_specs=pl.BlockSpec((t_block, i_pad, s_block), lambda s, t: (t, 0, s)),
        out_shape=jax.ShapeDtypeStruct((t_pad, i_pad, s_pad), dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(w1p, b1p, w2p, b2p, x0p)

    # (t_pad, I_pad, s_pad) -> (n_steps, S, I)
    return out[:n_steps, :i_dim, :s_total].transpose(0, 2, 1)


# ---------------------------------------------------------------------------
# Fused bit-extraction kernel: the trajectory never leaves VMEM in float form.
# ---------------------------------------------------------------------------

_GOLDEN = 0x9E3779B9          # Weyl increment (2^32 / phi)


def _fold16(x, i_dim: int):
    """Low-mantissa fold of one oscillator sample block.

    x: (I_pad, s) floats -> (1, s) uint32, the low mantissa bits of each
    live system dimension XOR-folded with odd shifts.  Bit-exact twin of
    the per-sample stage of ``ops.bits_from_trajectory`` — including the
    half-width rule: bf16 is bitcast at its own width and masked to its
    7 mantissa bits (an upcast to f32 would zero the low 16 bits and kill
    the entropy).
    """
    if x.dtype.itemsize == 2:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
        lo = u & jnp.uint32((1 << jnp.finfo(x.dtype).nmant) - 1)
    else:
        u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
        lo = u & jnp.uint32(0xFFFF)
    folded = lo[0:1, :]
    for i in range(1, i_dim):
        folded = folded ^ (lo[i:i + 1, :] << jnp.uint32(5 * i % 16))
    return folded


def _finalize(w):
    """Murmur3-style avalanche, identical to ``ops.bits_from_trajectory``."""
    w = w ^ (w >> jnp.uint32(16))
    w = w * jnp.uint32(0x85EBCA6B)
    w = w ^ (w >> jnp.uint32(13))
    w = w * jnp.uint32(0xC2B2AE35)
    w = w ^ (w >> jnp.uint32(16))
    return w


def _bits_kernel(w1_ref, b1_ref, w2_ref, b2_ref, x0_ref, off_ref,
                 words_ref, state_ref, *, t_block: int, unroll: int,
                 activation: str, compute_unit: str, i_dim: int, h_dim: int):
    """One (stream-block, time-block) grid cell of the fused PRNG kernel.

    Per block:
      off:   (1, s_block) uint32  per-stream word-row offset (Weyl counter)
      words: (t_block//2, s_block) uint32  output words
      state: (I_pad, s_block)  output, doubles as the VMEM carry across the
             time grid (same output block revisited for every t), so the
             float trajectory is never written to HBM.
    """
    t = pl.program_id(1)
    rows_per_block = t_block // 2

    @pl.when(t == 0)
    def _init():
        state_ref[...] = x0_ref[...]

    one_step = _make_step(w1_ref[...], b1_ref[...], w2_ref[...], b2_ref[...],
                          activation=activation, compute_unit=compute_unit,
                          i_dim=i_dim, h_dim=h_dim)
    offs = off_ref[...]

    def one_row(x, r):
        """Two oscillator steps -> one packed uint32 word row."""
        x1 = one_step(x)
        x2 = one_step(x1)
        word = (_fold16(x1, i_dim) << jnp.uint32(16)) | _fold16(x2, i_dim)
        row_idx = offs + (t * rows_per_block + r).astype(jnp.uint32)
        word = word ^ (row_idx * jnp.uint32(_GOLDEN))
        words_ref[pl.ds(r, 1), :] = _finalize(word)
        return x2

    def chunk(x, base):
        for u in range(unroll):
            x = one_row(x, base + u)
        return x

    x = state_ref[...]
    n_chunks = rows_per_block // unroll
    if n_chunks == 1:
        x = chunk(x, 0)
    else:
        x = jax.lax.fori_loop(0, n_chunks,
                              lambda c, x: chunk(x, c * unroll), x)
    state_ref[...] = x


def _bits_blocks(n_steps: int, t_block: int, unroll: int):
    """Largest legal (t_block, unroll) not exceeding the requested ones.

    The fused kernel must run *exactly* n_steps (the final state is part of
    the contract), so t_block has to divide n_steps; it must also be even
    (2 samples -> 1 word) and unroll counts word rows, so it must divide
    t_block // 2.
    """
    t_block = max(2, t_block - (t_block % 2))
    tb = math.gcd(t_block, n_steps)
    un = max(1, math.gcd(unroll, tb // 2))
    return tb, un


@functools.partial(
    jax.jit,
    static_argnames=("n_steps", "s_block", "t_block", "unroll", "activation",
                     "compute_unit", "interpret"),
)
def chaotic_ann_bits_pallas(w1, b1, w2, b2, x0, word_offset=0, *,
                            n_steps: int, s_block: int = 256,
                            t_block: int = 128, unroll: int = 1,
                            activation: str = "relu",
                            compute_unit: str = "vpu",
                            interpret: bool = False):
    """Fused oscillator + bit-extraction: streams PRNG words straight out.

    Runs the same update as ``chaotic_ann_pallas`` but packs the low-mantissa
    bits of each pair of consecutive samples into one uint32 word *inside the
    kernel* (Weyl-whitened + Murmur3-finalized, bit-exact with
    ``ops.bits_from_trajectory``), so only ~1/4 of the trajectory bytes ever
    reach HBM and no second extraction pass is needed.

    Args:
      w1 (I, H), b1 (H,), w2 (H, I), b2 (I,), x0 (S, I).
      word_offset: scalar or (S,) uint32 — the global word-row counter(s) of
        the first emitted row; makes chunked draws resume the exact Weyl
        sequence of one long draw.
      n_steps: steps to run; must be even (2 samples -> 1 word row).
    Returns:
      words: (n_steps // 2, S) uint32 word rows,
      final_state: (S, I) oscillator state after n_steps (resume handle).
    """
    if n_steps < 2 or n_steps % 2:
        raise ValueError(f"n_steps must be even and >= 2, got {n_steps}")
    i_dim, h_dim = w1.shape
    s_total = x0.shape[0]
    dtype = x0.dtype
    t_block, unroll = _bits_blocks(n_steps, t_block, unroll)

    i_pad = _pad_to(max(i_dim, 1), SUBLANES)
    h_pad = _pad_to(max(h_dim, 1), SUBLANES)
    s_pad = _pad_to(s_total, s_block)
    n_rows = n_steps // 2

    w1p = jnp.zeros((i_pad, h_pad), dtype).at[:i_dim, :h_dim].set(w1.astype(dtype))
    b1p = jnp.zeros((h_pad, 1), dtype).at[:h_dim, 0].set(b1.astype(dtype))
    w2p = jnp.zeros((h_pad, i_pad), dtype).at[:h_dim, :i_dim].set(w2.astype(dtype))
    b2p = jnp.zeros((i_pad, 1), dtype).at[:i_dim, 0].set(b2.astype(dtype))
    x0p = jnp.zeros((i_pad, s_pad), dtype).at[:i_dim, :s_total].set(x0.T.astype(dtype))
    off = jnp.asarray(word_offset, jnp.uint32)
    offp = jnp.zeros((1, s_pad), jnp.uint32).at[0, :s_total].set(
        jnp.broadcast_to(off, (s_total,)))

    grid = (s_pad // s_block, n_steps // t_block)
    words, state = pl.pallas_call(
        functools.partial(_bits_kernel, t_block=t_block, unroll=unroll,
                          activation=activation, compute_unit=compute_unit,
                          i_dim=i_dim, h_dim=h_dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((i_pad, h_pad), lambda s, t: (0, 0)),    # w1
            pl.BlockSpec((h_pad, 1), lambda s, t: (0, 0)),        # b1
            pl.BlockSpec((h_pad, i_pad), lambda s, t: (0, 0)),    # w2
            pl.BlockSpec((i_pad, 1), lambda s, t: (0, 0)),        # b2
            pl.BlockSpec((i_pad, s_block), lambda s, t: (0, s)),  # x0
            pl.BlockSpec((1, s_block), lambda s, t: (0, s)),      # offsets
        ],
        out_specs=[
            pl.BlockSpec((t_block // 2, s_block), lambda s, t: (t, s)),
            pl.BlockSpec((i_pad, s_block), lambda s, t: (0, s)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, s_pad), jnp.uint32),
            jax.ShapeDtypeStruct((i_pad, s_pad), dtype),
        ],
        interpret=interpret,
    )(w1p, b1p, w2p, b2p, x0p, offp)

    return words[:, :s_total], state[:i_dim, :s_total].T
