"""Pallas TPU kernel: fused ANN-based chaotic oscillator (the HENNC core).

TPU adaptation of the paper's chaotic unit (Fig. 1).  On FPGA the unit is a
MAC array with parallelism ``P`` multipliers; on TPU the throughput unit is a
*block of independent oscillator streams* mapped onto the vector lanes:

  - streams live on the 128-wide lane axis (``s_block`` a multiple of 128),
  - the I/H feature dims live on the 8-deep sublane axis,
  - the oscillator state is carried in a VMEM scratch buffer across the whole
    time grid — the feedback path (output -> next input) never touches HBM,
  - only finished trajectory blocks (t_block steps) are streamed out to HBM.

Two compute-unit modes, mirroring the paper's DSP-vs-LUT choice:
  - ``vpu``: the two tiny matmuls are computed as I (resp. H) broadcast
    fused-multiply-adds over (H, s_block) / (I, s_block) vregs — full lane
    utilization, no MXU padding waste (I, H << 128).
  - ``mxu``: ``jnp.dot`` — contraction dims are MXU-padded to 128; wasteful
    for I=3 but included as a real design-space axis (it wins for large H).

Grid: (S/s_block, T/t_block); the T axis iterates fastest (TPU grids execute
sequentially minor-to-major), so the per-stream-block state scratch is
initialized at t==0 and carried across t blocks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces (present in jax 0.8.x)
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except ImportError:  # pragma: no cover
    _VMEM = None

LANES = 128
SUBLANES = 8


def _activation(name: str):
    return {"relu": jax.nn.relu, "tanh": jnp.tanh, "sigmoid": jax.nn.sigmoid}[name]


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _grid_dims(n_nodes: int) -> tuple:
    """Most-square P x Q factorization for grid topology (must match
    ``repro.core.chaotic._grid_shape`` — same operator, two layouts)."""
    p = max(1, int(math.isqrt(n_nodes)))
    while n_nodes % p:
        p -= 1
    return p, n_nodes // p


def _lattice_delta(x, lattice):
    """Diffusive-coupling increment of a block-coupled lattice, as wrapped
    sublane rolls — the VPU form of the block-sparse coupling operator.

    x: (R, s) with R a whole number of ``period = n_nodes * base_dim``
    row groups (one for the solo kernel, C for the sublane-stacked gang —
    the node index is periodic per group, so ONE formula serves both
    layouts).  Each component row r accumulates its graph neighbours:
    ``delta[r] = strength * (sum_neighbours x[r'] - deg * x[r])``, where
    neighbour rows are reached by rolling the whole block by +-stride and
    correcting the ring-wrap rows with an iota mask (1-D iota is illegal
    on TPU; ``broadcasted_iota`` over (R, 1)).  Exactly the same jnp
    expression runs in every kernel AND the ``ref`` backend scan, so the
    coupled step is bitwise identical across all of them.
    """
    n_nodes, base_dim, topology, strength = lattice
    period = n_nodes * base_dim
    r = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], 1), 0)
    node = (r % period) // base_dim

    def ring_pair(idx, n_ring, stride):
        prev = jnp.where(idx == 0,
                         jnp.roll(x, -(n_ring - 1) * stride, axis=0),
                         jnp.roll(x, stride, axis=0))
        nxt = jnp.where(idx == n_ring - 1,
                        jnp.roll(x, (n_ring - 1) * stride, axis=0),
                        jnp.roll(x, -stride, axis=0))
        return prev + nxt

    if topology == "ring":
        acc = ring_pair(node, n_nodes, base_dim)
        deg = 2
    else:  # grid: P x Q torus, two nested rings
        pp, qq = _grid_dims(n_nodes)
        acc = (ring_pair(node // qq, pp, qq * base_dim)
               + ring_pair(node % qq, qq, base_dim))
        deg = 4
    eps = jnp.asarray(strength, x.dtype)
    return (acc - deg * x) * eps


def _check_lattice(lattice, i_dim: int, i_pad: int):
    """Validate the static lattice descriptor against the kernel dims."""
    n_nodes, base_dim, _topo, _eps = lattice
    if n_nodes * base_dim != i_dim:
        raise ValueError(f"lattice {n_nodes}x{base_dim} != i_dim {i_dim}")
    if i_pad != i_dim:
        raise ValueError(
            f"lattice state dim {i_dim} must be a whole number of sublanes "
            f"(got padding to {i_pad}); the wrapped-roll coupling cannot "
            f"cross padding rows")


def _round_half(v, dtype):
    """Round an f32 accumulator to a half-width state dtype, non-elidably.

    XLA's allow-excess-precision pass may cancel a bf16 round trip — the
    ``convert(f32->bf16)`` every ``preferred_element_type=f32`` matmul
    boundary emits, feeding the next step's ``convert(bf16->f32)`` — so a
    multi-step kernel body can carry MORE precision between steps than a
    one-step-per-carry scan, silently breaking bitwise kernel/ref identity
    (the carry of a scan is materialized at bf16; a fused body's isn't).
    ``reduce_precision`` cannot be elided, so the state rounds exactly once
    per step everywhere.  f32 states pass through untouched.
    """
    if jnp.dtype(dtype) == jnp.bfloat16:
        fi = jnp.finfo(jnp.bfloat16)
        v = jax.lax.reduce_precision(v, fi.nexp, fi.nmant)
    return v.astype(dtype)


def _make_step(w1, b1, w2, b2, *, activation: str, compute_unit: str,
               i_dim: int, h_dim: int, lattice=None, cpl=None):
    """Shared oscillator update used by every kernel in this module.

    Operates on x of shape (I_pad, s): padded feature rows of the weights are
    zero, so padding never contaminates live rows.

    ``lattice = (n_nodes, base_dim, topology, strength)`` adds the
    block-coupled diffusive term: on mxu it is one more genuine MXU
    contraction with the resident ``cpl`` (I, I) operand; on vpu it is the
    roll-based ``_lattice_delta`` (no matrix ever materialized).  The two
    units produce legitimately different word streams (different fp
    expression trees) — determinism keys on ``compute_unit`` as ever.
    """
    phi = _activation(activation)

    def couple(x):
        if cpl is not None:
            return _round_half(
                jnp.dot(cpl, x, preferred_element_type=jnp.float32), x.dtype)
        return _lattice_delta(x, lattice)

    def one_step(x):
        if compute_unit == "mxu":
            h = phi(_round_half(
                jnp.dot(w1.T, x, preferred_element_type=jnp.float32),
                x.dtype) + b1)
            y = _round_half(
                jnp.dot(w2.T, h, preferred_element_type=jnp.float32), x.dtype)
            y = y + b2
        else:
            # VPU path: broadcast-FMA over lanes; static unroll over tiny
            # dims.
            h = jnp.zeros((w1.shape[1], x.shape[1]), x.dtype)
            for i in range(i_dim):
                h = h + w1[i, :][:, None] * x[i, :][None, :]
            h = phi(h + b1)
            y = jnp.zeros_like(x)
            for j in range(h_dim):
                y = y + w2[j, :][:, None] * h[j, :][None, :]
            y = y + b2
        if lattice is not None:
            y = y + couple(x)
        # pin the carry itself: the bf16 add chain after the matmul
        # boundaries is equally subject to excess-precision fusion
        return _round_half(y, y.dtype)

    return one_step


def _prep_lattice(lattice, coupling, compute_unit: str, i_dim: int,
                  i_pad: int, dtype):
    """Shared launch-side lattice validation.

    Returns ``(use_cpl, cplp)``: whether the kernel takes the dense (I, I)
    coupling operand (mxu only — the vpu paths rebuild the operator from the
    static descriptor as wrapped rolls and never materialize a matrix), and
    the dtype-cast operand itself.
    """
    if lattice is None:
        return False, None
    _check_lattice(lattice, i_dim, i_pad)
    if compute_unit != "mxu":
        return False, None
    if coupling is None:
        raise ValueError(
            "mxu lattice launches need the dense coupling operand")
    if coupling.shape != (i_dim, i_dim):
        raise ValueError(f"coupling shape {coupling.shape} != "
                         f"({i_dim}, {i_dim})")
    return True, jnp.asarray(coupling, dtype)


def _kernel(*refs, t_block: int, unroll: int, activation: str,
            compute_unit: str, i_dim: int, h_dim: int, lattice, has_cpl):
    """One (stream-block, time-block) grid cell.

    Ref shapes (per block):
      w1: (I_pad, H_pad)  b1: (H_pad, 1)  w2: (H_pad, I_pad)  b2: (I_pad, 1)
      [cpl: (I_pad, I_pad) — mxu lattice launches only]
      x0: (I_pad, s_block)      out: (t_block, I_pad, s_block)
      state (VMEM scratch): (I_pad, s_block)
    """
    if has_cpl:
        (w1_ref, b1_ref, w2_ref, b2_ref, cpl_ref, x0_ref, out_ref,
         state_ref) = refs
    else:
        (w1_ref, b1_ref, w2_ref, b2_ref, x0_ref, out_ref, state_ref) = refs
        cpl_ref = None
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        state_ref[...] = x0_ref[...]

    one_step = _make_step(w1_ref[...], b1_ref[...], w2_ref[...], b2_ref[...],
                          activation=activation, compute_unit=compute_unit,
                          i_dim=i_dim, h_dim=h_dim, lattice=lattice,
                          cpl=cpl_ref[...] if has_cpl else None)

    def unrolled_chunk(x, base):
        for u in range(unroll):
            x = one_step(x)
            out_ref[base + u] = x
        return x

    x = state_ref[...]
    n_chunks = t_block // unroll
    if n_chunks == 1:
        x = unrolled_chunk(x, 0)
    else:
        def body(c, x):
            return unrolled_chunk(x, c * unroll)
        x = jax.lax.fori_loop(0, n_chunks, body, x)
    state_ref[...] = x


@functools.partial(
    jax.jit,
    static_argnames=("n_steps", "s_block", "t_block", "unroll", "activation",
                     "compute_unit", "lattice", "interpret"),
)
def chaotic_ann_pallas(w1, b1, w2, b2, x0, coupling=None, *, n_steps: int,
                       s_block: int = 256, t_block: int = 128, unroll: int = 1,
                       activation: str = "relu", compute_unit: str = "vpu",
                       lattice=None, interpret: bool = False):
    """Run the fused oscillator kernel.

    Args:
      w1 (I, H), b1 (H,), w2 (H, I), b2 (I,), x0 (S, I).
      coupling: dense (I, I) diffusive operator — consumed only by mxu
        lattice launches (one extra resident MXU operand).
      n_steps: total steps (padded up to a multiple of t_block internally).
      s_block/t_block/unroll/compute_unit: DSE-searchable microarchitecture.
      lattice: optional static ``(n_nodes, base_dim, topology, strength)``
        descriptor — turns the core into a block-coupled lattice (vpu
        applies the coupling as wrapped sublane rolls, no matrix operand).
    Returns:
      (n_steps, S, I) trajectory matching ``ref.chaotic_ann_ref``.
    """
    i_dim, h_dim = w1.shape
    s_total = x0.shape[0]
    dtype = x0.dtype
    if t_block % unroll:
        raise ValueError(f"t_block {t_block} must be divisible by unroll {unroll}")

    i_pad = _pad_to(max(i_dim, 1), SUBLANES)
    h_pad = _pad_to(max(h_dim, 1), SUBLANES)
    s_pad = _pad_to(s_total, s_block)
    t_pad = _pad_to(n_steps, t_block)
    use_cpl, cplp = _prep_lattice(lattice, coupling, compute_unit,
                                  i_dim, i_pad, dtype)

    w1p = jnp.zeros((i_pad, h_pad), dtype).at[:i_dim, :h_dim].set(w1.astype(dtype))
    b1p = jnp.zeros((h_pad, 1), dtype).at[:h_dim, 0].set(b1.astype(dtype))
    w2p = jnp.zeros((h_pad, i_pad), dtype).at[:h_dim, :i_dim].set(w2.astype(dtype))
    b2p = jnp.zeros((i_pad, 1), dtype).at[:i_dim, 0].set(b2.astype(dtype))
    # (S, I) -> (I_pad, S_pad): streams on lanes.
    x0p = jnp.zeros((i_pad, s_pad), dtype).at[:i_dim, :s_total].set(x0.T.astype(dtype))

    grid = (s_pad // s_block, t_pad // t_block)
    scratch = [_VMEM((i_pad, s_block), dtype)] if _VMEM is not None else []

    in_specs = [
        pl.BlockSpec((i_pad, h_pad), lambda s, t: (0, 0)),    # w1
        pl.BlockSpec((h_pad, 1), lambda s, t: (0, 0)),        # b1
        pl.BlockSpec((h_pad, i_pad), lambda s, t: (0, 0)),    # w2
        pl.BlockSpec((i_pad, 1), lambda s, t: (0, 0)),        # b2
    ]
    inputs = [w1p, b1p, w2p, b2p]
    if use_cpl:
        in_specs.append(pl.BlockSpec((i_pad, i_pad), lambda s, t: (0, 0)))
        inputs.append(cplp)
    in_specs.append(pl.BlockSpec((i_pad, s_block), lambda s, t: (0, s)))
    inputs.append(x0p)

    out = pl.pallas_call(
        functools.partial(_kernel, t_block=t_block, unroll=unroll,
                          activation=activation, compute_unit=compute_unit,
                          i_dim=i_dim, h_dim=h_dim, lattice=lattice,
                          has_cpl=use_cpl),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((t_block, i_pad, s_block), lambda s, t: (t, 0, s)),
        out_shape=jax.ShapeDtypeStruct((t_pad, i_pad, s_pad), dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*inputs)

    # (t_pad, I_pad, s_pad) -> (n_steps, S, I)
    return out[:n_steps, :i_dim, :s_total].transpose(0, 2, 1)


# ---------------------------------------------------------------------------
# Fused bit-extraction kernel: the trajectory never leaves VMEM in float form.
# ---------------------------------------------------------------------------

_GOLDEN = 0x9E3779B9          # Weyl increment (2^32 / phi)


def _fold16(x, i_dim: int):
    """Low-mantissa fold of one oscillator sample block.

    x: (I_pad, s) floats -> (1, s) uint32, the low mantissa bits of each
    live system dimension XOR-folded with odd shifts.  Bit-exact twin of
    the per-sample stage of ``ops.bits_from_trajectory`` — including the
    half-width rule: bf16 is bitcast at its own width and masked to its
    7 mantissa bits (an upcast to f32 would zero the low 16 bits and kill
    the entropy).
    """
    if x.dtype.itemsize == 2:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
        lo = u & jnp.uint32((1 << jnp.finfo(x.dtype).nmant) - 1)
    else:
        u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
        lo = u & jnp.uint32(0xFFFF)
    folded = lo[0:1, :]
    for i in range(1, i_dim):
        folded = folded ^ (lo[i:i + 1, :] << jnp.uint32(5 * i % 16))
    return folded


def _finalize(w):
    """Murmur3-style avalanche, identical to ``ops.bits_from_trajectory``."""
    w = w ^ (w >> jnp.uint32(16))
    w = w * jnp.uint32(0x85EBCA6B)
    w = w ^ (w >> jnp.uint32(13))
    w = w * jnp.uint32(0xC2B2AE35)
    w = w ^ (w >> jnp.uint32(16))
    return w


def _bits_kernel(*refs, t_block: int, unroll: int,
                 activation: str, compute_unit: str, i_dim: int, h_dim: int,
                 lattice, has_cpl):
    """One (stream-block, time-block) grid cell of the fused PRNG kernel.

    Per block:
      [cpl:  (I_pad, I_pad) coupling — mxu lattice launches only]
      off:   (1, s_block) uint32  per-stream word-row offset (Weyl counter)
      words: (t_block//2, s_block) uint32  output words
      state: (I_pad, s_block)  output, doubles as the VMEM carry across the
             time grid (same output block revisited for every t), so the
             float trajectory is never written to HBM.
    """
    if has_cpl:
        (w1_ref, b1_ref, w2_ref, b2_ref, cpl_ref, x0_ref, off_ref,
         words_ref, state_ref) = refs
    else:
        (w1_ref, b1_ref, w2_ref, b2_ref, x0_ref, off_ref,
         words_ref, state_ref) = refs
        cpl_ref = None
    t = pl.program_id(1)
    rows_per_block = t_block // 2

    @pl.when(t == 0)
    def _init():
        state_ref[...] = x0_ref[...]

    one_step = _make_step(w1_ref[...], b1_ref[...], w2_ref[...], b2_ref[...],
                          activation=activation, compute_unit=compute_unit,
                          i_dim=i_dim, h_dim=h_dim, lattice=lattice,
                          cpl=cpl_ref[...] if has_cpl else None)
    offs = off_ref[...]

    def one_row(x, r):
        """Two oscillator steps -> one packed uint32 word row."""
        x1 = one_step(x)
        x2 = one_step(x1)
        word = (_fold16(x1, i_dim) << jnp.uint32(16)) | _fold16(x2, i_dim)
        row_idx = offs + (t * rows_per_block + r).astype(jnp.uint32)
        word = word ^ (row_idx * jnp.uint32(_GOLDEN))
        words_ref[pl.ds(r, 1), :] = _finalize(word)
        return x2

    def chunk(x, base):
        for u in range(unroll):
            x = one_row(x, base + u)
        return x

    x = state_ref[...]
    n_chunks = rows_per_block // unroll
    if n_chunks == 1:
        x = chunk(x, 0)
    else:
        x = jax.lax.fori_loop(0, n_chunks,
                              lambda c, x: chunk(x, c * unroll), x)
    state_ref[...] = x


def _bits_blocks(n_steps: int, t_block: int, unroll: int):
    """Largest legal (t_block, unroll) not exceeding the requested ones.

    The fused kernel must run *exactly* n_steps (the final state is part of
    the contract), so t_block has to divide n_steps; it must also be even
    (2 samples -> 1 word) and unroll counts word rows, so it must divide
    t_block // 2.
    """
    t_block = max(2, t_block - (t_block % 2))
    tb = math.gcd(t_block, n_steps)
    un = max(1, math.gcd(unroll, tb // 2))
    return tb, un


@functools.partial(
    jax.jit,
    static_argnames=("n_steps", "s_block", "t_block", "unroll", "activation",
                     "compute_unit", "lattice", "interpret"),
)
def chaotic_ann_bits_pallas(w1, b1, w2, b2, x0, word_offset=0, coupling=None,
                            *, n_steps: int, s_block: int = 256,
                            t_block: int = 128, unroll: int = 1,
                            activation: str = "relu",
                            compute_unit: str = "vpu",
                            lattice=None, interpret: bool = False):
    """Fused oscillator + bit-extraction: streams PRNG words straight out.

    Runs the same update as ``chaotic_ann_pallas`` but packs the low-mantissa
    bits of each pair of consecutive samples into one uint32 word *inside the
    kernel* (Weyl-whitened + Murmur3-finalized, bit-exact with
    ``ops.bits_from_trajectory``), so only ~1/4 of the trajectory bytes ever
    reach HBM and no second extraction pass is needed.

    Args:
      w1 (I, H), b1 (H,), w2 (H, I), b2 (I,), x0 (S, I).
      word_offset: scalar or (S,) uint32 — the global word-row counter(s) of
        the first emitted row; makes chunked draws resume the exact Weyl
        sequence of one long draw.
      coupling / lattice: see ``chaotic_ann_pallas`` — the same static
        lattice descriptor (and, for mxu, dense operand) turns the core
        into a block-coupled oscillator lattice.
      n_steps: steps to run; must be even (2 samples -> 1 word row).
    Returns:
      words: (n_steps // 2, S) uint32 word rows,
      final_state: (S, I) oscillator state after n_steps (resume handle).
    """
    if n_steps < 2 or n_steps % 2:
        raise ValueError(f"n_steps must be even and >= 2, got {n_steps}")
    i_dim, h_dim = w1.shape
    s_total = x0.shape[0]
    dtype = x0.dtype
    t_block, unroll = _bits_blocks(n_steps, t_block, unroll)

    i_pad = _pad_to(max(i_dim, 1), SUBLANES)
    h_pad = _pad_to(max(h_dim, 1), SUBLANES)
    s_pad = _pad_to(s_total, s_block)
    n_rows = n_steps // 2
    use_cpl, cplp = _prep_lattice(lattice, coupling, compute_unit,
                                  i_dim, i_pad, dtype)

    w1p = jnp.zeros((i_pad, h_pad), dtype).at[:i_dim, :h_dim].set(w1.astype(dtype))
    b1p = jnp.zeros((h_pad, 1), dtype).at[:h_dim, 0].set(b1.astype(dtype))
    w2p = jnp.zeros((h_pad, i_pad), dtype).at[:h_dim, :i_dim].set(w2.astype(dtype))
    b2p = jnp.zeros((i_pad, 1), dtype).at[:i_dim, 0].set(b2.astype(dtype))
    x0p = jnp.zeros((i_pad, s_pad), dtype).at[:i_dim, :s_total].set(x0.T.astype(dtype))
    off = jnp.asarray(word_offset, jnp.uint32)
    offp = jnp.zeros((1, s_pad), jnp.uint32).at[0, :s_total].set(
        jnp.broadcast_to(off, (s_total,)))

    in_specs = [
        pl.BlockSpec((i_pad, h_pad), lambda s, t: (0, 0)),    # w1
        pl.BlockSpec((h_pad, 1), lambda s, t: (0, 0)),        # b1
        pl.BlockSpec((h_pad, i_pad), lambda s, t: (0, 0)),    # w2
        pl.BlockSpec((i_pad, 1), lambda s, t: (0, 0)),        # b2
    ]
    inputs = [w1p, b1p, w2p, b2p]
    if use_cpl:
        in_specs.append(pl.BlockSpec((i_pad, i_pad), lambda s, t: (0, 0)))
        inputs.append(cplp)
    in_specs += [
        pl.BlockSpec((i_pad, s_block), lambda s, t: (0, s)),  # x0
        pl.BlockSpec((1, s_block), lambda s, t: (0, s)),      # offsets
    ]
    inputs += [x0p, offp]

    grid = (s_pad // s_block, n_steps // t_block)
    words, state = pl.pallas_call(
        functools.partial(_bits_kernel, t_block=t_block, unroll=unroll,
                          activation=activation, compute_unit=compute_unit,
                          i_dim=i_dim, h_dim=h_dim, lattice=lattice,
                          has_cpl=use_cpl),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((t_block // 2, s_block), lambda s, t: (t, s)),
            pl.BlockSpec((i_pad, s_block), lambda s, t: (0, s)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, s_pad), jnp.uint32),
            jax.ShapeDtypeStruct((i_pad, s_pad), dtype),
        ],
        interpret=interpret,
    )(*inputs)

    return words[:, :s_total], state[:i_dim, :s_total].T


# ---------------------------------------------------------------------------
# Gang-scheduled variant: C compatible networks, ONE launch.
# ---------------------------------------------------------------------------


def _gang_bits_kernel(*refs, t_block: int, unroll: int, activation: str,
                      compute_unit: str, i_dim: int, h_dim: int,
                      ragged: bool, lattice, has_cpl):
    """One (lane-block, time-block) grid cell of the gang PRNG kernel.

    Identical math to ``_bits_kernel`` (state output doubles as the VMEM
    carry across the time grid); the only difference is that the weight
    refs carry a leading length-1 core axis whose block was DMA'd from slab
    ``core_map[g]`` of the stacked weights (scalar-prefetch index map), so
    every lane block computes its own network in the same launch.
    ``cmap_ref`` is the prefetched map itself — consumed by the index maps,
    unused in the body.

    Ragged variant: a second scalar-prefetched map carries the word rows
    each lane block actually owes.  The row loop's trip count becomes
    dynamic — a cell computes only the unroll-chunks covering its block's
    remaining demand and cells wholly past it fall through with the state
    carry untouched — so a ragged gang launch does no overdraw FMA work.
    Word rows past a block's demand are left unwritten (garbage); callers
    slice to the per-block demand.
    """
    refs = list(refs)
    _cmap_ref = refs.pop(0)
    rmap_ref = refs.pop(0) if ragged else None
    w1_ref, b1_ref, w2_ref, b2_ref = refs[:4]
    refs = refs[4:]
    cpl_ref = refs.pop(0) if has_cpl else None
    x0_ref, off_ref, words_ref, state_ref = refs
    g = pl.program_id(0)
    t = pl.program_id(1)
    rows_per_block = t_block // 2

    @pl.when(t == 0)
    def _init():
        state_ref[...] = x0_ref[...]

    one_step = _make_step(w1_ref[0], b1_ref[0], w2_ref[0], b2_ref[0],
                          activation=activation, compute_unit=compute_unit,
                          i_dim=i_dim, h_dim=h_dim, lattice=lattice,
                          cpl=cpl_ref[...] if has_cpl else None)
    offs = off_ref[...]

    def one_row(x, r):
        x1 = one_step(x)
        x2 = one_step(x1)
        word = (_fold16(x1, i_dim) << jnp.uint32(16)) | _fold16(x2, i_dim)
        row_idx = offs + (t * rows_per_block + r).astype(jnp.uint32)
        word = word ^ (row_idx * jnp.uint32(_GOLDEN))
        words_ref[pl.ds(r, 1), :] = _finalize(word)
        return x2

    def chunk(x, base):
        for u in range(unroll):
            x = one_row(x, base + u)
        return x

    x = state_ref[...]
    n_chunks = rows_per_block // unroll
    if ragged:
        remaining = jnp.maximum(rmap_ref[g] - t * rows_per_block, 0)
        active = jnp.minimum((remaining + unroll - 1) // unroll, n_chunks)
        x = jax.lax.fori_loop(0, active,
                              lambda c, x: chunk(x, c * unroll), x)
    elif n_chunks == 1:
        x = chunk(x, 0)
    else:
        x = jax.lax.fori_loop(0, n_chunks,
                              lambda c, x: chunk(x, c * unroll), x)
    state_ref[...] = x


def gang_row_granularity(n_steps: int, t_block: int, unroll: int) -> int:
    """Word-row granularity of ragged early-out in the lane-concat kernel.

    The dynamic row loop skips whole unroll-chunks, so a block's computed
    rows are its ``row_map`` entry rounded up to the post-gcd unroll (the
    same ``_bits_blocks`` collapse the kernel itself applies).
    """
    _, un = _bits_blocks(n_steps, t_block, unroll)
    return un


def gang_effective_rows(row_map, n_steps: int, t_block: int,
                        unroll: int) -> np.ndarray:
    """Word rows each lane block of a ragged gang launch actually computes
    (and therefore the rows its member's state/counters advance by)."""
    un = gang_row_granularity(n_steps, t_block, unroll)
    r = np.asarray(row_map, np.int64)
    return np.minimum(-(-r // un) * un, n_steps // 2).astype(np.int32)


@functools.partial(
    jax.jit,
    static_argnames=("n_steps", "s_block", "t_block", "unroll", "activation",
                     "compute_unit", "lattice", "interpret"),
)
def chaotic_ann_gang_bits_pallas(w1, b1, w2, b2, x0, core_map, word_offset=0,
                                 row_map=None, coupling=None, *, n_steps: int,
                                 s_block: int = 256,
                                 t_block: int = 128, unroll: int = 1,
                                 activation: str = "relu",
                                 compute_unit: str = "vpu",
                                 lattice=None, interpret: bool = False):
    """Gang-scheduled fused PRNG: C stacked networks, one kernel launch.

    The farm's gang path: weights carry a leading core axis and the pooled
    stream axis is divided into ``s_block``-lane blocks, each homogeneous in
    core.  ``core_map[g]`` names the weight slab of lane block ``g``; it is
    scalar-prefetched so the BlockSpec index maps route each grid cell's
    weight DMA to its own slab (the grouped/ragged-batching trick of MaxText
    -style serving stacks).  Per lane the computation is exactly
    ``chaotic_ann_bits_pallas`` with that lane's core — lanes evolve
    independently, so gang words/states are bit-identical to C per-core
    launches.

    Args:
      w1 (C, I, H), b1 (C, H), w2 (C, H, I), b2 (C, I): stacked weights.
      x0 (S, I): concatenated stream pool; S must equal
        ``len(core_map) * s_block`` (pad each member pool to an s_block
        multiple before concatenating).
      core_map: (n_blocks,) int array, values in [0, C).
      word_offset: scalar or (S,) uint32 per-lane word-row offsets.
      row_map: optional (n_blocks,) int array — word rows each lane block
        owes (demand-shaped launch).  Block ``g`` computes exactly
        ``gang_effective_rows(row_map, ...)[g]`` rows (its demand rounded
        up to the unroll-chunk granularity) and its state advances by that
        many rows; word rows past it are unwritten garbage.  Per lane the
        computed prefix is bit-identical to a per-core launch of that many
        rows (absolute-row Weyl indexing).  None = every block computes
        all ``n_steps // 2`` rows (the padded group-max launch).
      coupling / lattice: see ``chaotic_ann_pallas``.  ONE coupling operand
        is shared by every lane block — a gang only admits cores with
        identical lattice meta (the scheduler's compat key), so the shared
        operand is exact, not an approximation.
      n_steps: steps to run; must be even (2 samples -> 1 word row).
    Returns:
      words: (n_steps // 2, S) uint32 word rows,
      final_state: (S, I) oscillator state after each lane's own rows.
    """
    if n_steps < 2 or n_steps % 2:
        raise ValueError(f"n_steps must be even and >= 2, got {n_steps}")
    n_cores, i_dim, h_dim = w1.shape
    s_total = x0.shape[0]
    n_blocks = core_map.shape[0]
    if s_total != n_blocks * s_block:
        raise ValueError(
            f"pool of {s_total} lanes != {n_blocks} core-map blocks x "
            f"s_block {s_block}; pad each member pool to an s_block multiple")
    ragged = row_map is not None
    if ragged and row_map.shape != core_map.shape:
        raise ValueError(f"row_map shape {row_map.shape} != core_map shape "
                         f"{core_map.shape}")
    dtype = x0.dtype
    t_block, unroll = _bits_blocks(n_steps, t_block, unroll)

    i_pad = _pad_to(max(i_dim, 1), SUBLANES)
    h_pad = _pad_to(max(h_dim, 1), SUBLANES)
    n_rows = n_steps // 2
    use_cpl, cplp = _prep_lattice(lattice, coupling, compute_unit,
                                  i_dim, i_pad, dtype)

    w1p = jnp.zeros((n_cores, i_pad, h_pad), dtype
                    ).at[:, :i_dim, :h_dim].set(w1.astype(dtype))
    b1p = jnp.zeros((n_cores, h_pad, 1), dtype
                    ).at[:, :h_dim, 0].set(b1.astype(dtype))
    w2p = jnp.zeros((n_cores, h_pad, i_pad), dtype
                    ).at[:, :h_dim, :i_dim].set(w2.astype(dtype))
    b2p = jnp.zeros((n_cores, i_pad, 1), dtype
                    ).at[:, :i_dim, 0].set(b2.astype(dtype))
    x0p = jnp.zeros((i_pad, s_total), dtype
                    ).at[:i_dim, :].set(x0.T.astype(dtype))
    off = jnp.asarray(word_offset, jnp.uint32)
    offp = jnp.broadcast_to(off, (s_total,)).reshape(1, s_total)
    cmap = jnp.asarray(core_map, jnp.int32)

    # Scalar-prefetch arguments: the core-id map always; the per-block row
    # map only for ragged launches (the index maps ignore it).
    scalars = [cmap]
    if ragged:
        scalars.append(jnp.minimum(jnp.asarray(row_map, jnp.int32), n_rows))
    n_sc = len(scalars)

    def _w(g, t, *maps):
        return (maps[0][g], 0, 0)

    in_specs = [
        pl.BlockSpec((1, i_pad, h_pad), _w),
        pl.BlockSpec((1, h_pad, 1), _w),
        pl.BlockSpec((1, h_pad, i_pad), _w),
        pl.BlockSpec((1, i_pad, 1), _w),
    ]
    inputs = [w1p, b1p, w2p, b2p]
    if use_cpl:
        in_specs.append(
            pl.BlockSpec((i_pad, i_pad), lambda g, t, *m: (0, 0)))  # shared
        inputs.append(cplp)
    in_specs += [
        pl.BlockSpec((i_pad, s_block), lambda g, t, *m: (0, g)),   # x0
        pl.BlockSpec((1, s_block), lambda g, t, *m: (0, g)),  # offsets
    ]
    inputs += [x0p, offp]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_sc,
        grid=(n_blocks, n_steps // t_block),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((t_block // 2, s_block), lambda g, t, *m: (t, g)),
            pl.BlockSpec((i_pad, s_block), lambda g, t, *m: (0, g)),
        ],
    )
    words, state = pl.pallas_call(
        functools.partial(_gang_bits_kernel, t_block=t_block, unroll=unroll,
                          activation=activation, compute_unit=compute_unit,
                          i_dim=i_dim, h_dim=h_dim, ragged=ragged,
                          lattice=lattice, has_cpl=use_cpl),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, s_total), jnp.uint32),
            jax.ShapeDtypeStruct((i_pad, s_total), dtype),
        ],
        interpret=interpret,
    )(*scalars, *inputs)

    return words, state[:i_dim, :].T


# ---------------------------------------------------------------------------
# Sublane-stacked gang variant: C equal-shape pools, ONE grid cell per
# (lane-block, time-block) — the whole group's update is a single set of
# vector ops on C-times-taller vregs.
# ---------------------------------------------------------------------------


def _stacked_fold16(x, n_cores: int, i_pad: int, i_dim: int):
    """Fold the live dims of every core at once: (C*I_pad, s) -> (C, s).

    Strided sublane slices pick dimension ``i`` of every core in one op, so
    the fold stays one XOR chain of (C, s) values — the same low-mantissa
    bits, shifts, and order per lane as ``_fold16`` on each core alone.
    """
    if x.dtype.itemsize == 2:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
        lo = u & jnp.uint32((1 << jnp.finfo(x.dtype).nmant) - 1)
    else:
        u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
        lo = u & jnp.uint32(0xFFFF)
    folded = lo[0::i_pad, :]
    for i in range(1, i_dim):
        folded = folded ^ (lo[i::i_pad, :] << jnp.uint32(5 * i % 16))
    return folded


def _make_stacked_step(w1t, b1s, w2t, b2s, *, activation: str,
                       n_cores: int, i_pad: int, h_pad: int,
                       i_dim: int, h_dim: int, lattice=None):
    """Whole-group oscillator update on sublane-stacked state.

    x: (C*I_pad, s) — core c's state occupies sublane rows
    [c*I_pad, c*I_pad + I).  Weight tables are pre-broadcast outside the
    kernel: w1t[i] is the (C*H_pad, 1) column of every core's w1[:, i, :],
    so step ``h += w1t[i] * x[i of every core]`` is ONE fused
    multiply-add over the stacked group — same accumulation order per lane
    as the per-core VPU path, hence bit-identical words.

    Lattice groups reuse ``_lattice_delta`` unchanged: with the enforced
    ``i_pad == i_dim`` the stacked state is exactly C back-to-back lattice
    periods, so the node-index iota is core-periodic and the wrapped rolls
    add each core's own neighbour rows — the identical jnp expression (and
    values) as the solo kernel, keeping the gang bit-identical per lane.
    """
    phi = _activation(activation)

    def one_step(x):
        h = jnp.zeros((n_cores * h_pad, x.shape[1]), x.dtype)
        for i in range(i_dim):
            xi = jnp.repeat(x[i::i_pad, :], h_pad, axis=0)
            h = h + w1t[i] * xi
        h = phi(h + b1s)
        y = jnp.zeros_like(x)
        for j in range(h_dim):
            hj = jnp.repeat(h[j::h_pad, :], i_pad, axis=0)
            y = y + w2t[j] * hj
        y = y + b2s
        if lattice is not None:
            y = y + _lattice_delta(x, lattice)
        return y

    return one_step


def _gang_stacked_kernel(*refs, t_block: int, unroll: int,
                         activation: str, n_cores: int, i_pad: int,
                         h_pad: int, i_dim: int, h_dim: int, ragged: bool,
                         lattice):
    """One (lane-block, time-block) cell computing ALL C cores at once.

    Ragged variant: an extra (C, 1) row-map input freezes a core's state
    once its own word-row demand is met — the stacked FMA sweep still
    spans the whole group (the sublane stack is one fused op), but a
    frozen core's state stops advancing at exactly its demand, so its
    final state (and word prefix) is bit-identical to a per-core launch
    of that many rows.  Word rows past a core's demand are garbage.
    """
    if ragged:
        (w1t_ref, b1_ref, w2t_ref, b2_ref, x0_ref, off_ref, rmap_ref,
         words_ref, state_ref) = refs
    else:
        (w1t_ref, b1_ref, w2t_ref, b2_ref, x0_ref, off_ref,
         words_ref, state_ref) = refs
        rmap_ref = None
    t = pl.program_id(1)
    rows_per_block = t_block // 2

    @pl.when(t == 0)
    def _init():
        state_ref[...] = x0_ref[...]

    one_step = _make_stacked_step(
        w1t_ref[...], b1_ref[...], w2t_ref[...], b2_ref[...],
        activation=activation, n_cores=n_cores, i_pad=i_pad, h_pad=h_pad,
        i_dim=i_dim, h_dim=h_dim, lattice=lattice)
    offs = off_ref[...]
    rmap = rmap_ref[...] if ragged else None

    def one_row(x, r):
        x1 = one_step(x)
        x2 = one_step(x1)
        word = ((_stacked_fold16(x1, n_cores, i_pad, i_dim)
                 << jnp.uint32(16))
                | _stacked_fold16(x2, n_cores, i_pad, i_dim))
        row_idx = offs + (t * rows_per_block + r).astype(jnp.uint32)
        word = word ^ (row_idx * jnp.uint32(_GOLDEN))
        words_ref[pl.ds(r, 1), :, :] = _finalize(word)[None]
        if ragged:
            alive = (t * rows_per_block + r) < rmap          # (C, 1) bool
            keep = jnp.repeat(alive, i_pad, axis=0)          # core-major
            x2 = jnp.where(keep, x2, x)
        return x2

    def chunk(x, base):
        for u in range(unroll):
            x = one_row(x, base + u)
        return x

    x = state_ref[...]
    n_chunks = rows_per_block // unroll
    if n_chunks == 1:
        x = chunk(x, 0)
    else:
        x = jax.lax.fori_loop(0, n_chunks,
                              lambda c, x: chunk(x, c * unroll), x)
    state_ref[...] = x


@functools.partial(
    jax.jit,
    static_argnames=("n_steps", "s_block", "t_block", "unroll", "activation",
                     "compute_unit", "lattice", "interpret"),
)
def chaotic_ann_gang_stacked_pallas(w1, b1, w2, b2, x0, word_offset=0,
                                    row_map=None, *,
                                    n_steps: int, s_block: int = 256,
                                    t_block: int = 128, unroll: int = 1,
                                    activation: str = "relu",
                                    compute_unit: str = "vpu",
                                    lattice=None, interpret: bool = False):
    """Gang launch for C equal-shape pools, stacked on the SUBLANE axis.

    Where ``chaotic_ann_gang_bits_pallas`` concatenates pools along the
    lane axis (one grid cell per member lane block), this variant exploits
    equal pool shapes to stack the group along the *sublane* axis: state is
    (C * I_pad, s_block) in one grid cell, and each update step is ONE
    broadcast-FMA sweep over the stacked group — C networks advance for the
    per-cell cost of one.  This is the paper's parallelism-P MAC array
    applied across *cores* instead of across streams, and it is what makes
    small gang flushes cheaper than C small per-core flushes (per-launch
    and per-grid-cell overheads are paid once, not C times).

    Per lane the FMA accumulation order, bit fold, and whitening are
    identical to the per-core kernel, so words and final states are
    bit-identical to C ``chaotic_ann_bits_pallas`` launches.

    Args:
      w1 (C, I, H), b1 (C, H), w2 (C, H, I), b2 (C, I): stacked weights.
      x0 (C, S, I): one equal-size pool per core.
      word_offset: scalar or (C, S) uint32 per-lane word-row offsets.
      row_map: optional (C,) int array of per-core word-row demands.  The
        stacked sweep still advances the whole group together (no FMA
        saved — the sublane stack is one fused op), but core ``c``'s state
        is frozen after exactly ``row_map[c]`` rows, so its final state and
        its ``words[:row_map[c]]`` prefix are bit-identical to a per-core
        launch of ``2 * row_map[c]`` steps; later word rows are garbage.
        None = every core computes all rows (the padded group-max launch).
    Returns:
      words: (n_steps // 2, C, S) uint32, final_state: (C, S, I).
    """
    if n_steps < 2 or n_steps % 2:
        raise ValueError(f"n_steps must be even and >= 2, got {n_steps}")
    if compute_unit != "vpu":
        # The stacked step IS the broadcast-FMA order; a dot-based (mxu)
        # group must take the lane-concat gang path to stay bit-identical.
        raise ValueError("stacked gang launches support compute_unit='vpu' "
                         "only; use chaotic_ann_gang_bits_pallas for mxu")
    n_cores, i_dim, h_dim = w1.shape
    s_total = x0.shape[1]
    dtype = x0.dtype
    t_block, unroll = _bits_blocks(n_steps, t_block, unroll)

    i_pad = _pad_to(max(i_dim, 1), SUBLANES)
    h_pad = _pad_to(max(h_dim, 1), SUBLANES)
    s_pad = _pad_to(s_total, s_block)
    n_rows = n_steps // 2
    if lattice is not None:
        _check_lattice(lattice, i_dim, i_pad)

    # Pre-broadcast weight tables: w1t[i] (C*H_pad, 1) holds w1[c, i, j] at
    # row c*H_pad + j; w2t[j] (C*I_pad, 1) holds w2[c, j, i'] at c*I_pad+i'.
    w1t = jnp.zeros((i_dim, n_cores * h_pad, 1), dtype)
    w1t = w1t.at[:, :, 0].set(
        jnp.pad(w1.astype(dtype), ((0, 0), (0, 0), (0, h_pad - h_dim)))
        .transpose(1, 0, 2).reshape(i_dim, n_cores * h_pad))
    b1s = jnp.zeros((n_cores * h_pad, 1), dtype).at[:, 0].set(
        jnp.pad(b1.astype(dtype), ((0, 0), (0, h_pad - h_dim))).reshape(-1))
    w2t = jnp.zeros((h_dim, n_cores * i_pad, 1), dtype)
    w2t = w2t.at[:, :, 0].set(
        jnp.pad(w2.astype(dtype), ((0, 0), (0, 0), (0, i_pad - i_dim)))
        .transpose(1, 0, 2).reshape(h_dim, n_cores * i_pad))
    b2s = jnp.zeros((n_cores * i_pad, 1), dtype).at[:, 0].set(
        jnp.pad(b2.astype(dtype), ((0, 0), (0, i_pad - i_dim))).reshape(-1))
    # (C, S, I) -> (C*I_pad, S_pad): core-major sublane stacking.
    x0p = jnp.zeros((n_cores, i_pad, s_pad), dtype).at[
        :, :i_dim, :s_total].set(x0.transpose(0, 2, 1).astype(dtype))
    x0p = x0p.reshape(n_cores * i_pad, s_pad)
    off = jnp.asarray(word_offset, jnp.uint32)
    offp = jnp.zeros((n_cores, s_pad), jnp.uint32).at[:, :s_total].set(
        jnp.broadcast_to(off, (n_cores, s_total)))
    ragged = row_map is not None

    in_specs = [
        pl.BlockSpec((i_dim, n_cores * h_pad, 1),
                     lambda s, t: (0, 0, 0)),                 # w1t
        pl.BlockSpec((n_cores * h_pad, 1), lambda s, t: (0, 0)),
        pl.BlockSpec((h_dim, n_cores * i_pad, 1),
                     lambda s, t: (0, 0, 0)),                 # w2t
        pl.BlockSpec((n_cores * i_pad, 1), lambda s, t: (0, 0)),
        pl.BlockSpec((n_cores * i_pad, s_block),
                     lambda s, t: (0, s)),                    # x0
        pl.BlockSpec((n_cores, s_block), lambda s, t: (0, s)),  # offsets
    ]
    inputs = [w1t, b1s, w2t, b2s, x0p, offp]
    if ragged:
        if np.shape(row_map) != (n_cores,):
            raise ValueError(f"row_map must have shape ({n_cores},), got "
                             f"{np.shape(row_map)}")
        rmapp = jnp.minimum(jnp.asarray(row_map, jnp.int32),
                            n_rows).reshape(n_cores, 1)
        in_specs.append(pl.BlockSpec((n_cores, 1), lambda s, t: (0, 0)))
        inputs.append(rmapp)

    grid = (s_pad // s_block, n_steps // t_block)
    words, state = pl.pallas_call(
        functools.partial(_gang_stacked_kernel, t_block=t_block,
                          unroll=unroll, activation=activation,
                          n_cores=n_cores, i_pad=i_pad, h_pad=h_pad,
                          i_dim=i_dim, h_dim=h_dim, ragged=ragged,
                          lattice=lattice),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((t_block // 2, n_cores, s_block),
                         lambda s, t: (t, 0, s)),
            pl.BlockSpec((n_cores * i_pad, s_block), lambda s, t: (0, s)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, n_cores, s_pad), jnp.uint32),
            jax.ShapeDtypeStruct((n_cores * i_pad, s_pad), dtype),
        ],
        interpret=interpret,
    )(*inputs)

    words = words[:, :, :s_total]
    state = state.reshape(n_cores, i_pad, s_pad)[
        :, :i_dim, :s_total].transpose(0, 2, 1)
    return words, state


# ---------------------------------------------------------------------------
# Device-sharded gang launches: the same gang kernels, with the pooled
# stream axis (and the per-block scalar-prefetch maps) partitioned across a
# named mesh axis.  Weight slabs are replicated — the maxtext-style choice:
# shard the batch-like axis, keep the (tiny) params everywhere.
# ---------------------------------------------------------------------------


def gang_partition_maps(core_map, row_map, *, n_dev: int, n_rows: int):
    """Partition the per-block gang maps across ``n_dev`` devices.

    Pads the block axis with DEAD blocks (core 0, zero row demand) until it
    divides the device count, so every device owns the same number of
    ``s_block``-lane blocks and scalar-prefetches its own contiguous slice
    of the core-id and row maps.  Padding forces the launch ragged — dead
    blocks must compute zero rows — and a ``row_map`` of ``n_rows`` per
    real block reproduces the padded group-max launch exactly, so the
    rounding is free.

    Returns ``(core_map, row_map, pad_blocks)`` as numpy arrays.  Device
    ``d`` consumes ``core_map[d * B:(d + 1) * B]`` with ``B = len(core_map)
    // n_dev`` — exactly the contiguous slice the shard_map inside the
    sharded kernels hands it.
    """
    cmap = np.asarray(core_map, np.int32)
    n_blocks = cmap.shape[0]
    pad = (-n_blocks) % n_dev
    rmap = None if row_map is None else np.asarray(row_map, np.int32)
    if pad == 0:
        return cmap, rmap, 0
    if rmap is None:
        rmap = np.full(n_blocks, n_rows, np.int32)
    return (np.concatenate([cmap, np.zeros(pad, np.int32)]),
            np.concatenate([rmap, np.zeros(pad, np.int32)]), pad)


def chaotic_ann_gang_bits_sharded(w1, b1, w2, b2, x0, core_map,
                                  word_offset=0, row_map=None, coupling=None,
                                  *, mesh,
                                  mesh_axis: str = "data", n_steps: int,
                                  s_block: int = 256, t_block: int = 128,
                                  unroll: int = 1, activation: str = "relu",
                                  compute_unit: str = "vpu",
                                  lattice=None, interpret: bool = False):
    """Lane-concat gang launch partitioned across ``mesh[mesh_axis]``.

    Weight slabs are replicated (passed through with ``P()`` specs — NOT
    closed over, which would bake them into the trace as constants and
    defeat the jit cache, recompiling every flush); the pooled stream
    axis and BOTH scalar-prefetch maps shard on the named axis, so each
    device runs the single-device gang kernel on its own contiguous run
    of lane blocks with its *own slice* of the core-id/row maps.  Lanes
    evolve independently and word whitening is indexed by absolute
    per-lane row offsets, so the result is bit-identical to the
    unsharded gang launch (and hence to per-core launches) at any device
    count.  The shard_map'd callable is cached per (mesh, static config)
    and jitted, so steady-state flushes reuse one compiled program per
    launch shape.

    The block axis must divide the device count — pad the maps (and the
    pool) with ``gang_partition_maps`` dead blocks first.
    """
    n_dev = int(mesh.shape[mesh_axis])
    cmap = jnp.asarray(core_map, jnp.int32)
    n_blocks = int(cmap.shape[0])
    if n_blocks % n_dev:
        raise ValueError(
            f"{n_blocks} lane blocks do not divide {n_dev} devices on mesh "
            f"axis {mesh_axis!r}; pad the maps with gang_partition_maps")
    s_total = x0.shape[0]
    off = jnp.broadcast_to(jnp.asarray(word_offset, jnp.uint32), (s_total,))

    args = [w1, b1, w2, b2, x0, off, cmap]
    if row_map is not None:
        args.append(jnp.asarray(row_map, jnp.int32))
    has_cpl = lattice is not None and compute_unit == "mxu"
    if has_cpl:
        if coupling is None:
            raise ValueError(
                "mxu lattice launches need the dense coupling operand")
        args.append(jnp.asarray(coupling))
    fn = _sharded_gang_bits_fn(
        mesh, mesh_axis, row_map is not None, n_steps, s_block, t_block,
        unroll, activation, compute_unit, lattice, has_cpl, interpret)
    return fn(*args)


@functools.lru_cache(maxsize=128)
def _sharded_gang_bits_fn(mesh, mesh_axis, has_rmap, n_steps, s_block,
                          t_block, unroll, activation, compute_unit,
                          lattice, has_cpl, interpret):
    """Jitted shard_map'd lane-concat gang launch, cached per (mesh,
    static kernel config).  Weights/pool/maps are traced arguments, so
    jit retraces only when a launch SHAPE is new — per-flush weight or
    demand values hit the compiled program."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    kw = dict(n_steps=n_steps, s_block=s_block, t_block=t_block,
              unroll=unroll, activation=activation,
              compute_unit=compute_unit, lattice=lattice,
              interpret=interpret)
    in_specs = [P(), P(), P(), P(),
                P(mesh_axis, None), P(mesh_axis), P(mesh_axis)]
    if has_rmap:
        in_specs.append(P(mesh_axis))
    if has_cpl:
        in_specs.append(P())       # coupling: replicated like the weights

    def local(w1, b1, w2, b2, x_l, off_l, cmap_l, *rest):
        rest = list(rest)
        rmap_l = rest.pop(0) if has_rmap else None
        cpl = rest.pop(0) if has_cpl else None
        return chaotic_ann_gang_bits_pallas(
            w1, b1, w2, b2, x_l, cmap_l, off_l, rmap_l, cpl, **kw)

    return jax.jit(shard_map(
        local, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=(P(None, mesh_axis), P(mesh_axis, None)),
        check_rep=False))


def chaotic_ann_gang_stacked_sharded(w1, b1, w2, b2, x0, word_offset=0,
                                     row_map=None, *, mesh,
                                     mesh_axis: str = "data", n_steps: int,
                                     s_block: int = 256, t_block: int = 128,
                                     unroll: int = 1,
                                     activation: str = "relu",
                                     compute_unit: str = "vpu",
                                     lattice=None, interpret: bool = False):
    """Sublane-stacked gang launch partitioned across ``mesh[mesh_axis]``.

    The group's equal-size pools shard on the STREAM axis (every device
    keeps all C cores stacked on sublanes, with 1/n_dev of each pool's
    lanes); the (C,) row map is replicated since a core's freeze row is
    lane-independent.  Weight tables are replicated as traced arguments
    (``P()`` specs), and the shard_map'd callable is cached per (mesh,
    static config) + jitted — same no-recompile-per-flush discipline as
    the lane-concat variant.  The pool size must divide the device
    count; ragged pool sizes take the lane-concat sharded path instead.
    """
    n_dev = int(mesh.shape[mesh_axis])
    n_cores, s_total = x0.shape[0], x0.shape[1]
    if s_total % n_dev:
        raise ValueError(
            f"stacked pool of {s_total} lanes does not divide {n_dev} "
            f"devices on mesh axis {mesh_axis!r}")
    off = jnp.broadcast_to(jnp.asarray(word_offset, jnp.uint32),
                           (n_cores, s_total))

    args = [w1, b1, w2, b2, x0, off]
    if row_map is not None:
        args.append(jnp.asarray(row_map, jnp.int32))
    fn = _sharded_gang_stacked_fn(
        mesh, mesh_axis, row_map is not None, n_steps, s_block, t_block,
        unroll, activation, compute_unit, lattice, interpret)
    return fn(*args)


@functools.lru_cache(maxsize=128)
def _sharded_gang_stacked_fn(mesh, mesh_axis, has_rmap, n_steps, s_block,
                             t_block, unroll, activation, compute_unit,
                             lattice, interpret):
    """Jitted shard_map'd sublane-stacked gang launch, cached per (mesh,
    static kernel config) — see ``_sharded_gang_bits_fn``."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    kw = dict(n_steps=n_steps, s_block=s_block, t_block=t_block,
              unroll=unroll, activation=activation,
              compute_unit=compute_unit, lattice=lattice,
              interpret=interpret)
    in_specs = [P(), P(), P(), P(),
                P(None, mesh_axis, None), P(None, mesh_axis)]
    if has_rmap:
        in_specs.append(P())            # (C,) freeze rows: lane-independent

        def local(w1, b1, w2, b2, x_l, off_l, rmap_l):
            return chaotic_ann_gang_stacked_pallas(
                w1, b1, w2, b2, x_l, off_l, rmap_l, **kw)
    else:
        def local(w1, b1, w2, b2, x_l, off_l):
            return chaotic_ann_gang_stacked_pallas(
                w1, b1, w2, b2, x_l, off_l, None, **kw)
    return jax.jit(shard_map(
        local, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=(P(None, None, mesh_axis), P(None, mesh_axis, None)),
        check_rep=False))
