"""Pallas TPU kernel: fused ANN-based chaotic oscillator (the HENNC core).

TPU adaptation of the paper's chaotic unit (Fig. 1).  On FPGA the unit is a
MAC array with parallelism ``P`` multipliers; on TPU the throughput unit is a
*block of independent oscillator streams* mapped onto the vector lanes:

  - streams live on the 128-wide lane axis (``s_block`` a multiple of 128),
  - the I/H feature dims live on the 8-deep sublane axis,
  - the oscillator state is carried in a VMEM scratch buffer across the whole
    time grid — the feedback path (output -> next input) never touches HBM,
  - only finished trajectory blocks (t_block steps) are streamed out to HBM.

Two compute-unit modes, mirroring the paper's DSP-vs-LUT choice:
  - ``vpu``: the two tiny matmuls are computed as I (resp. H) broadcast
    fused-multiply-adds over (H, s_block) / (I, s_block) vregs — full lane
    utilization, no MXU padding waste (I, H << 128).
  - ``mxu``: ``jnp.dot`` — contraction dims are MXU-padded to 128; wasteful
    for I=3 but included as a real design-space axis (it wins for large H).

Grid: (S/s_block, T/t_block); the T axis iterates fastest (TPU grids execute
sequentially minor-to-major), so the per-stream-block state scratch is
initialized at t==0 and carried across t blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces (present in jax 0.8.x)
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except ImportError:  # pragma: no cover
    _VMEM = None

LANES = 128
SUBLANES = 8


def _activation(name: str):
    return {"relu": jax.nn.relu, "tanh": jnp.tanh, "sigmoid": jax.nn.sigmoid}[name]


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _kernel(w1_ref, b1_ref, w2_ref, b2_ref, x0_ref, out_ref, state_ref,
            *, t_block: int, unroll: int, activation: str, compute_unit: str,
            i_dim: int, h_dim: int):
    """One (stream-block, time-block) grid cell.

    Ref shapes (per block):
      w1: (I_pad, H_pad)  b1: (H_pad, 1)  w2: (H_pad, I_pad)  b2: (I_pad, 1)
      x0: (I_pad, s_block)      out: (t_block, I_pad, s_block)
      state (VMEM scratch): (I_pad, s_block)
    """
    t = pl.program_id(1)
    phi = _activation(activation)

    @pl.when(t == 0)
    def _init():
        state_ref[...] = x0_ref[...]

    w1 = w1_ref[...]
    b1 = b1_ref[...]
    w2 = w2_ref[...]
    b2 = b2_ref[...]

    def one_step(x):
        # x: (I_pad, s). Padded feature rows of the weights are zero, so
        # padding never contaminates live rows.
        if compute_unit == "mxu":
            h = phi(jnp.dot(w1.T, x, preferred_element_type=jnp.float32)
                    .astype(x.dtype) + b1)
            y = jnp.dot(w2.T, h, preferred_element_type=jnp.float32)
            return y.astype(x.dtype) + b2
        # VPU path: broadcast-FMA over lanes; static unroll over tiny dims.
        h = jnp.zeros((w1.shape[1], x.shape[1]), x.dtype)
        for i in range(i_dim):
            h = h + w1[i, :][:, None] * x[i, :][None, :]
        h = phi(h + b1)
        y = jnp.zeros_like(x)
        for j in range(h_dim):
            y = y + w2[j, :][:, None] * h[j, :][None, :]
        return y + b2

    def unrolled_chunk(x, base):
        for u in range(unroll):
            x = one_step(x)
            out_ref[base + u] = x
        return x

    x = state_ref[...]
    n_chunks = t_block // unroll
    if n_chunks == 1:
        x = unrolled_chunk(x, 0)
    else:
        def body(c, x):
            return unrolled_chunk(x, c * unroll)
        x = jax.lax.fori_loop(0, n_chunks, body, x)
    state_ref[...] = x


@functools.partial(
    jax.jit,
    static_argnames=("n_steps", "s_block", "t_block", "unroll", "activation",
                     "compute_unit", "interpret"),
)
def chaotic_ann_pallas(w1, b1, w2, b2, x0, *, n_steps: int,
                       s_block: int = 256, t_block: int = 128, unroll: int = 1,
                       activation: str = "relu", compute_unit: str = "vpu",
                       interpret: bool = False):
    """Run the fused oscillator kernel.

    Args:
      w1 (I, H), b1 (H,), w2 (H, I), b2 (I,), x0 (S, I).
      n_steps: total steps (padded up to a multiple of t_block internally).
      s_block/t_block/unroll/compute_unit: DSE-searchable microarchitecture.
    Returns:
      (n_steps, S, I) trajectory matching ``ref.chaotic_ann_ref``.
    """
    i_dim, h_dim = w1.shape
    s_total = x0.shape[0]
    dtype = x0.dtype
    if t_block % unroll:
        raise ValueError(f"t_block {t_block} must be divisible by unroll {unroll}")

    i_pad = _pad_to(max(i_dim, 1), SUBLANES)
    h_pad = _pad_to(max(h_dim, 1), SUBLANES)
    s_pad = _pad_to(s_total, s_block)
    t_pad = _pad_to(n_steps, t_block)

    w1p = jnp.zeros((i_pad, h_pad), dtype).at[:i_dim, :h_dim].set(w1.astype(dtype))
    b1p = jnp.zeros((h_pad, 1), dtype).at[:h_dim, 0].set(b1.astype(dtype))
    w2p = jnp.zeros((h_pad, i_pad), dtype).at[:h_dim, :i_dim].set(w2.astype(dtype))
    b2p = jnp.zeros((i_pad, 1), dtype).at[:i_dim, 0].set(b2.astype(dtype))
    # (S, I) -> (I_pad, S_pad): streams on lanes.
    x0p = jnp.zeros((i_pad, s_pad), dtype).at[:i_dim, :s_total].set(x0.T.astype(dtype))

    grid = (s_pad // s_block, t_pad // t_block)
    scratch = [_VMEM((i_pad, s_block), dtype)] if _VMEM is not None else []

    out = pl.pallas_call(
        functools.partial(_kernel, t_block=t_block, unroll=unroll,
                          activation=activation, compute_unit=compute_unit,
                          i_dim=i_dim, h_dim=h_dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((i_pad, h_pad), lambda s, t: (0, 0)),    # w1
            pl.BlockSpec((h_pad, 1), lambda s, t: (0, 0)),        # b1
            pl.BlockSpec((h_pad, i_pad), lambda s, t: (0, 0)),    # w2
            pl.BlockSpec((i_pad, 1), lambda s, t: (0, 0)),        # b2
            pl.BlockSpec((i_pad, s_block), lambda s, t: (0, s)),  # x0
        ],
        out_specs=pl.BlockSpec((t_block, i_pad, s_block), lambda s, t: (t, 0, s)),
        out_shape=jax.ShapeDtypeStruct((t_pad, i_pad, s_pad), dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(w1p, b1p, w2p, b2p, x0p)

    # (t_pad, I_pad, s_pad) -> (n_steps, S, I)
    return out[:n_steps, :i_dim, :s_total].transpose(0, 2, 1)
