"""Design-space exploration with analytical cost/latency estimation
(paper §III-B.1/2, Eqs. 8-9, Figs. 3-5) — adapted from FPGA to TPU v5e.

The paper's flow: (1) parameterize the microarchitecture by a parallelism
level P; (2) *measure* post-synthesis latency/cost for a sample of design
points; (3) fit cheap closed-form estimators — latency = (I·H)·poly3(P),
cost = c1·I·H + c2·I + c3·H + β — with per-mode coefficient tables (DSP vs
LUT); (4) use the estimators to sweep the space in seconds and hand the user
min-latency / lowest-cost / Pareto candidates.

TPU mapping (see DESIGN.md §2):
  P              -> log2(stream-block width / 128 lanes)
  DSP vs LUT     -> MXU vs VPU compute path (+ bf16 vs f32 dtype)
  #LUT cost      -> VMEM working-set bytes of the kernel instance
  post-synthesis latency -> cycle count from the microarchitectural model
                    below, cross-validated against compiled-HLO FLOP/byte
                    counts (`validate_cycle_model_vs_hlo` in tests)

The same estimate-then-validate structure is preserved: `measure_candidate`
is the ground-truth oracle (the paper's Vivado report), `LatencyModel` /
`CostModel` are the fitted estimators (the paper's Eqs. 8-9), and
`benchmarks/table3_dse.py` reports estimate-vs-actual exactly like Table III.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# TPU v5e hardware model (single core).  Documented model constants; the
# roofline numerators elsewhere use the same peak numbers.
# ---------------------------------------------------------------------------
CLOCK_HZ = 940e6
PEAK_BF16_FLOPS = 197e12                    # per chip
MXU_MACS_PER_CYCLE_BF16 = PEAK_BF16_FLOPS / 2 / CLOCK_HZ   # ~104.8k
MXU_MACS_PER_CYCLE_F32 = MXU_MACS_PER_CYCLE_BF16 / 4        # f32 via passes
VPU_FMA_VREGS_PER_CYCLE = 4                 # (8,128) vreg FMAs issued/cycle
HBM_BYTES_PER_CYCLE = 819e9 / CLOCK_HZ      # ~871 B
VMEM_BYTES = 128 * 2 ** 20                  # v5e VMEM
VMEM_USABLE = int(VMEM_BYTES * 0.75)        # compiler headroom
GRID_STEP_OVERHEAD_CYCLES = 500.0           # per pallas grid cell (control)
LOOP_ITER_OVERHEAD_CYCLES = 8.0             # fori_loop bookkeeping per chunk

LANES = 128
SUBLANES = 8


def _pad(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclasses.dataclass(frozen=True, order=True)
class Candidate:
    """One point in the kernel design space (paper: one HLS solution).

    ``n_nodes > 1`` marks a block-coupled lattice core: ``i_dim``/``h_dim``
    are the full lattice dims (n_nodes x base dims), the weights are
    block-diagonal by construction, and the step carries a diffusive
    coupling term (an extra MXU contraction on the mxu path, roll/select
    passes on the vpu path).  The field is last so older serialized
    candidates (``Candidate(**solution["candidate"])``) keep loading.
    """

    i_dim: int = 3
    h_dim: int = 8
    p: int = 1                  # parallelism level; s_block = 128 * 2**p
    compute_unit: str = "vpu"   # 'vpu' | 'mxu'  (paper: LUT | DSP)
    dtype_bytes: int = 4        # 4 = f32, 2 = bf16
    unroll: int = 4
    t_block: int = 128
    n_nodes: int = 1            # lattice nodes (1 = scalar system)

    @property
    def s_block(self) -> int:
        return LANES * (2 ** self.p)

    @property
    def i_pad(self) -> int:
        return _pad(self.i_dim, SUBLANES)

    @property
    def h_pad(self) -> int:
        return _pad(self.h_dim, SUBLANES)

    @property
    def dtype_name(self) -> str:
        return {2: "bfloat16", 4: "float32"}[self.dtype_bytes]


# ---------------------------------------------------------------------------
# Ground-truth oracle ("post-synthesis measurement" analogue)
# ---------------------------------------------------------------------------

def _overhead_share(c: Candidate) -> float:
    """Per-step control-overhead share of a candidate's (t_block, unroll).

    Used both inside the cycle oracle below and as the tie-break of every
    selection path — ``select`` modes, Pareto-front tie order, and the
    ``select_config`` autotuner.  The Eq. 8/9 estimators are blind to these
    two knobs (they normalize per P / per size), so one scoring rule here
    keeps the DSE output consistent across the flow (a ``select``-emitted
    core and a ``select_config``-tuned service agree on the solution).
    """
    return (GRID_STEP_OVERHEAD_CYCLES / c.t_block
            + LOOP_ITER_OVERHEAD_CYCLES / c.unroll)


def measure_candidate(c: Candidate) -> Dict[str, float]:
    """Microarchitectural cycle/byte accounting for one oscillator step of a
    full stream block, plus the VMEM working set.  Deterministic; this plays
    the role of the paper's post-synthesis Vivado report."""
    vregs = lambda rows, cols: (_pad(rows, SUBLANES) // SUBLANES) * (_pad(cols, LANES) // LANES)

    if c.compute_unit == "vpu":
        # h accumulate: i_dim FMAs over (h_pad, s_block); activation: 1 pass;
        # y accumulate: h_dim FMAs over (i_pad, s_block); bias adds: 2 passes.
        fma_vregs = (
            c.i_dim * vregs(c.h_pad, c.s_block)
            + vregs(c.h_pad, c.s_block)
            + c.h_dim * vregs(c.i_pad, c.s_block)
            + vregs(c.h_pad, c.s_block) + vregs(c.i_pad, c.s_block)
        )
        if c.n_nodes > 1:
            # Block-sparse diffusive coupling: the kernel applies it as
            # wrapped rolls + boundary selects + the scaled accumulate
            # over the (i_pad, s_block) state — ~10 elementwise passes
            # for a ring (grid pays ~2x; model the ring floor), NOT an
            # n_nodes^2 matmul.
            fma_vregs += 10 * vregs(c.i_pad, c.s_block)
        compute_cycles = fma_vregs / VPU_FMA_VREGS_PER_CYCLE
    else:
        macs_per_cycle = (MXU_MACS_PER_CYCLE_BF16 if c.dtype_bytes == 2
                          else MXU_MACS_PER_CYCLE_F32)
        # Both matmuls pad contraction + one free dim to 128 on the MXU.
        macs = (_pad(c.i_pad, 128) * _pad(c.h_pad, 128) * c.s_block
                + _pad(c.h_pad, 128) * _pad(c.i_pad, 128) * c.s_block)
        extra_vpu = 0.0
        if c.n_nodes > 1:
            # The coupling operator is one more genuinely MXU-shaped
            # contraction: (i_pad x i_pad) @ (i_pad x s_block).  The
            # operator is block-sparse (nearest-neighbour blocks only),
            # but the block-sparse route already did its work upstream —
            # the lattice state is n_nodes x base_dim, not n_nodes^2, so
            # a single 128-padded pass covers it.
            macs += _pad(c.i_pad, 128) * _pad(c.i_pad, 128) * c.s_block
            extra_vpu = vregs(c.i_pad, c.s_block)   # the += into y
        # activation + biases still run on the VPU
        vpu_cycles = (vregs(c.h_pad, c.s_block) * 2 + vregs(c.i_pad, c.s_block)
                      + extra_vpu) / VPU_FMA_VREGS_PER_CYCLE
        compute_cycles = macs / macs_per_cycle + vpu_cycles

    # HBM traffic per step: the trajectory write-out (state never leaves VMEM).
    hbm_bytes_per_step = c.i_pad * c.s_block * c.dtype_bytes
    memory_cycles = hbm_bytes_per_step / HBM_BYTES_PER_CYCLE

    # Per-step share of control overheads (shared with the DSE tie-break).
    overhead = _overhead_share(c)

    cycles_per_step = max(compute_cycles, memory_cycles) + overhead
    # Paper-comparable "iteration latency": cycles for one oscillator update
    # of ONE stream (the FPGA implements exactly one oscillator).
    per_stream_cycles = cycles_per_step / c.s_block

    vmem = vmem_bytes(c)
    return {
        "cycles_per_step": cycles_per_step,
        "per_stream_latency_cycles": per_stream_cycles,
        "compute_cycles": compute_cycles,
        "memory_cycles": memory_cycles,
        "overhead_cycles": overhead,
        "vmem_bytes": float(vmem),
        "samples_per_sec": c.s_block / cycles_per_step * CLOCK_HZ,
        "fits_vmem": float(vmem <= VMEM_USABLE),
    }


def vmem_bytes(c: Candidate) -> int:
    """Closed-form VMEM working set of the kernel instance (the cost)."""
    d = c.dtype_bytes
    weights = (c.i_pad * c.h_pad + c.h_pad + c.h_pad * c.i_pad + c.i_pad) * d
    if c.n_nodes > 1 and c.compute_unit == "mxu":
        weights += c.i_pad * c.i_pad * d     # resident coupling operator
    state = c.i_pad * c.s_block * d          # scratch carry
    hidden = c.h_pad * c.s_block * d * c.unroll   # live h per unrolled step
    x0_blk = c.i_pad * c.s_block * d
    out_blk = 2 * c.t_block * c.i_pad * c.s_block * d   # double-buffered
    return weights + state + hidden + x0_blk + out_blk


def stacked_gang_vmem_bytes(c: Candidate, n_cores: int) -> int:
    """VMEM working set of one ``chaotic_ann_gang_stacked_pallas`` launch
    stacking ``n_cores`` equal pools on the sublane axis.

    Everything the solo instance keeps per core — state carry, live
    hidden, x0 block — is resident for all C cores at once, the
    pre-broadcast weight tables are (i_dim, C*h_pad)/(h_dim, C*i_pad),
    and the words block is (t_block/2, C, s_block) double-buffered.
    This is the planner's stacked-layout feasibility check: the pool
    size where this crosses ``VMEM_USABLE`` is the stacked-layout VMEM
    cliff, past which the planner must fall back to a lane-concat
    (ragged/padded) launch.
    """
    C = max(1, int(n_cores))
    d = c.dtype_bytes
    tables = (c.i_dim * C * c.h_pad + C * c.h_pad
              + c.h_dim * C * c.i_pad + C * c.i_pad) * d
    state = C * c.i_pad * c.s_block * d
    hidden = C * c.h_pad * c.s_block * d * c.unroll
    x0_blk = C * c.i_pad * c.s_block * d
    out_blk = 2 * (c.t_block // 2) * C * c.s_block * 4   # uint32 words
    return tables + state + hidden + x0_blk + out_blk


# ---------------------------------------------------------------------------
# Fitted estimators (paper Eqs. 8 & 9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LatencyModel:
    """Latency = (I·H) · (b3·P³ + b2·P² + b1·P + b0)   (paper Eq. 8).

    Separate coefficient tables per (compute_unit, dtype) — the paper keeps
    separate tables for DSP vs no-DSP."""

    coeffs: Dict[Tuple[str, int], np.ndarray] = dataclasses.field(default_factory=dict)

    @staticmethod
    def fit(p_levels: Sequence[int] = range(0, 6),
            sizes: Sequence[Tuple[int, int]] = ((3, 4), (3, 8), (3, 16), (4, 8), (4, 16)),
            units: Sequence[str] = ("vpu", "mxu"),
            dtypes: Sequence[int] = (4, 2)) -> "LatencyModel":
        """Paper §III-B.2: measure a range of solutions, normalize latency by
        I·H, average per P, then fit a degree-3 polynomial in P."""
        model = LatencyModel()
        for unit, dt in itertools.product(units, dtypes):
            norm_by_p = []
            for p in p_levels:
                vals = []
                for (i, h) in sizes:
                    m = measure_candidate(Candidate(i_dim=i, h_dim=h, p=p,
                                                    compute_unit=unit, dtype_bytes=dt))
                    vals.append(m["per_stream_latency_cycles"] / (i * h))
                norm_by_p.append(np.mean(vals))
            model.coeffs[(unit, dt)] = np.polyfit(np.asarray(list(p_levels), dtype=np.float64),
                                                  np.asarray(norm_by_p), deg=3)
        return model

    def predict(self, i_dim: int, h_dim: int, p: int,
                compute_unit: str = "vpu", dtype_bytes: int = 4) -> float:
        b = self.coeffs[(compute_unit, dtype_bytes)]
        return float((i_dim * h_dim) * np.polyval(b, float(p)))


@dataclasses.dataclass
class CostModel:
    """#VMEM-bytes = c1·I·H + c2·I + c3·H + β, per parallelism level
    (paper Eq. 9, with a per-P constant table)."""

    coeffs: Dict[Tuple[int, str, int], np.ndarray] = dataclasses.field(default_factory=dict)

    @staticmethod
    def fit(p_levels: Sequence[int] = range(0, 6),
            i_range: Sequence[int] = (2, 3, 4, 6, 8),
            h_range: Sequence[int] = (4, 8, 12, 16, 24, 32),
            units: Sequence[str] = ("vpu", "mxu"),
            dtypes: Sequence[int] = (4, 2)) -> "CostModel":
        model = CostModel()
        for p, unit, dt in itertools.product(p_levels, units, dtypes):
            rows, ys = [], []
            for i, h in itertools.product(i_range, h_range):
                c = Candidate(i_dim=i, h_dim=h, p=p, compute_unit=unit, dtype_bytes=dt)
                rows.append([i * h, i, h, 1.0])
                ys.append(float(vmem_bytes(c)))
            sol, *_ = np.linalg.lstsq(np.asarray(rows), np.asarray(ys), rcond=None)
            model.coeffs[(p, unit, dt)] = sol
        return model

    def predict(self, i_dim: int, h_dim: int, p: int,
                compute_unit: str = "vpu", dtype_bytes: int = 4) -> float:
        c1, c2, c3, beta = self.coeffs[(p, compute_unit, dtype_bytes)]
        return float(c1 * i_dim * h_dim + c2 * i_dim + c3 * h_dim + beta)


# ---------------------------------------------------------------------------
# Gang launch-cost model: Eq. 8/9 lifted from kernel instances to LAUNCHES
# ---------------------------------------------------------------------------

# Fixed per-launch overhead (dispatch, host sync, argument marshalling) in
# model cycles.  The PR 3 gang scheduler implicitly set this to infinity
# ("one launch is always cheaper"); the planner needs a finite default, and
# ``GangCostModel.fit`` replaces it with a measured value.
GANG_LAUNCH_OVERHEAD_CYCLES = 30_000.0
# Host-side buffering rate for overdraw words (absorb copies them into
# per-client numpy buffers); modeled well below HBM speed.
HOST_BUFFER_BYTES_PER_CYCLE = HBM_BYTES_PER_CYCLE / 4.0


@dataclasses.dataclass
class GangCostModel:
    """Predicts the cost of ONE kernel launch for (membership, per-core
    rows, layout) — the estimator a gang *planner* minimizes over.

    ``LatencyModel``/``CostModel`` (paper Eqs. 8/9) estimate the per-stream
    step latency and VMEM cost of a kernel instance; they say nothing about
    what a whole launch costs, which is what decides whether a skewed-demand
    group should launch once at the group max (PR 3's policy), once ragged
    (each lane block computes only its own demand), or split into several
    launches.  The launch cost here is

        cycles = launch_overhead_cycles
               + sum_over_lane_blocks( 2 * rows_block ) * step_cycles
               + buffered_overdraw_words * 4 / HOST_BUFFER_BYTES_PER_CYCLE

    where ``step_cycles`` comes from the same microarchitectural accounting
    as ``measure_candidate`` — for a sublane-stacked gang sweep the
    compute/memory terms scale with the stack height C (one fused op
    advances all C cores), while the per-cell control overhead is paid once.

    ``fit`` calibrates the wall-clock-sensitive knobs against real
    launches on the serving machine: the fixed per-launch overhead, a
    per-grid-cell overhead (an analytic share is already inside
    ``step_cycles`` via ``_overhead_share``, but executed cells can carry
    a much larger fixed cost — e.g. Pallas interpret mode pays several ms
    per cell), and a stacked-sweep scale factor (XLA executes a C-tall
    sweep at other than exactly C times the single-core rate).
    ``sec_per_cycle`` is kept so fitted costs can be reported in seconds.
    """

    launch_overhead_cycles: float = GANG_LAUNCH_OVERHEAD_CYCLES
    cell_overhead_cycles: float = 0.0
    stacked_step_scale: float = 1.0
    # Per-row cost of the ragged-stacked freeze (one mask compare + select
    # over the stacked state per word row); analytic default ~2 vreg ops.
    freeze_row_cycles: float = 4.0
    # Extra dispatch cost per device beyond the first when a launch is
    # shard_map'd across a mesh (collective setup, per-device program
    # dispatch).  The compute/cell terms are counted on the *busiest
    # device's shard* (``n_dev`` in launch_cycles/gang_cost/solo_cost), so
    # this is the only term that grows with the mesh — ``fit(mesh=...)``
    # measures it from a real sharded launch.
    cross_dev_overhead_cycles: float = 10_000.0
    sec_per_cycle: Optional[float] = None

    def step_cycles(self, c: Candidate, stack: int = 1) -> float:
        """Cycles for one oscillator step of one s_block-wide lane block
        with ``stack`` cores stacked on the sublane axis."""
        m = measure_candidate(c)
        compute = m["compute_cycles"] * stack
        memory = m["memory_cycles"] * stack
        scale = self.stacked_step_scale if stack > 1 else 1.0
        return max(compute, memory) * scale + _overhead_share(c)

    def launch_cycles(self, c: Candidate, rows_by_block: Sequence[int],
                      *, stack: int = 1, n_dev: int = 1) -> float:
        """One launch computing ``rows_by_block[i]`` word rows in lane
        block ``i`` (2 oscillator steps per word row).

        Only the FMA steps shrink with a block's rows: the grid is static
        (every block iterates the launch's full time axis), so an
        early-out cell still pays its dispatch/DMA share — cell overhead
        counts the whole max(rows)-deep grid for every block.

        ``n_dev > 1`` models the shard_map'd launch: lane blocks split
        into contiguous runs of ``ceil(blocks/n_dev)`` per device, so the
        step and cell terms follow the *busiest device's shard* (SPMD
        wall time) and each extra device adds
        ``cross_dev_overhead_cycles`` of dispatch.
        """
        n_dev = max(1, int(n_dev))
        rows_per_cell = max(1, c.t_block // 2)
        t_cells = max(1, -(-int(max(rows_by_block)) // rows_per_cell))
        blocks_local = -(-len(rows_by_block) // n_dev)
        if n_dev > 1:
            rb = list(rows_by_block)
            steps = 2.0 * float(max(
                sum(rb[d * blocks_local:(d + 1) * blocks_local])
                for d in range(n_dev)))
        else:
            steps = 2.0 * float(sum(rows_by_block))
        cells = blocks_local * t_cells
        return (self.launch_overhead_cycles
                + self.cross_dev_overhead_cycles * (n_dev - 1)
                + self.cell_overhead_cycles * cells
                + steps * self.step_cycles(c, stack))

    def buffer_cycles(self, overdrawn_words: float) -> float:
        """Host cost of buffering overdraw words nobody asked for yet."""
        return 4.0 * float(overdrawn_words) / HOST_BUFFER_BYTES_PER_CYCLE

    def gang_cost(self, c: Candidate, demands: Sequence[int],
                  blocks: Sequence[int], lanes: Sequence[int], *,
                  layout: str, rows_by_block: Optional[Sequence[int]] = None,
                  n_dev: int = 1) -> float:
        """Cost of one gang launch serving members with ``demands`` word
        rows (``blocks``/``lanes`` = per-member lane-block and live-lane
        counts).

        layout 'stacked': the whole group advances max(demands) rows per
        lane block (ragged freeze changes buffering, not compute).
        layout 'concat': pass ``rows_by_block`` for a ragged launch — the
        per-BLOCK effective rows, ``sum(blocks)`` long, member ``i``
        occupying ``blocks[i]`` consecutive equal entries; None means the
        padded group-max launch.
        """
        dmax = max(demands)
        if layout == "stacked":
            cost = self.launch_cycles(c, [dmax] * blocks[0],
                                      stack=len(demands), n_dev=n_dev)
            # ragged freeze absorbs exactly the demand -> no overdraw, but
            # pays the per-row freeze mask over the whole launch (split
            # across devices along the lane axis)
            if rows_by_block is not None:
                cost += (self.freeze_row_cycles * dmax * blocks[0]
                         / max(1, n_dev))
                over = 0
            else:
                over = sum((dmax - d) * l for d, l in zip(demands, lanes))
        else:
            if rows_by_block is None:
                rows_by_block = [dmax] * sum(blocks)
                per_member = [dmax] * len(demands)
            else:
                # every block of a member computes its demand, so the
                # member's advanced rows are its first block's entry
                starts = np.cumsum([0] + list(blocks[:-1]))
                per_member = [rows_by_block[int(s)] for s in starts]
            over = sum((r - d) * l
                       for r, d, l in zip(per_member, demands, lanes))
            cost = self.launch_cycles(c, rows_by_block, n_dev=n_dev)
        return cost + self.buffer_cycles(max(0, over))

    def solo_cost(self, c: Candidate, rows: int, blocks: int, *,
                  n_dev: int = 1) -> float:
        """One per-core launch of ``rows`` word rows over ``blocks`` lane
        blocks (``n_dev``: the pool's own shard_map'd launch when its
        service sits on a mesh)."""
        return self.launch_cycles(c, [rows] * blocks, n_dev=n_dev)

    def seconds(self, cycles: float) -> Optional[float]:
        return None if self.sec_per_cycle is None else cycles * self.sec_per_cycle

    @classmethod
    def fit(cls, c: Candidate, *, backend: str = "auto", n_cores: int = 3,
            reps: int = 3, clock=None, mesh=None,
            mesh_axis: str = "data") -> "GangCostModel":
        """Calibrate (launch_overhead_cycles, cell_overhead_cycles,
        stacked_step_scale, sec_per_cycle) from real launches of
        candidate ``c`` — the paper's estimate-then-validate loop applied
        to the launch model.

        Five measurements separate the terms:
          t1  solo launch, 1 grid cell   (t_block//2 rows)
          t2  solo launch, 2 cells, 2x the steps
          t3  solo launch, 2 cells, SAME steps (t_block halved)
          t4  sublane-stacked gang launch of ``n_cores`` cores, 1 cell
          t5  the same stacked launch with a skewed row map (freeze)
        so  cell_sec = t3 - t1,  step_sec = (t2 - t3) / steps,
        launch_sec = t1 - cell_sec - steps * step_sec, t4 gives the
        stacked-sweep scale and t5 - t4 the per-row freeze cost.  Runs
        5 + 5*reps kernel launches.  ``clock`` injects the timer
        (``repro.clock.Clock``); the default ``SystemClock`` measures
        real wall time.

        With a ``mesh`` (>1 device on ``mesh_axis``), one extra
        measurement t6 — a lane-concat gang of one block per device,
        shard_map'd across the mesh — calibrates
        ``cross_dev_overhead_cycles``: each device does exactly t1's
        per-shard work, so the residual over t1 split across the extra
        devices is the per-device dispatch fee.  (On a host with fewer
        physical CPUs than forced devices this honestly measures the
        serialization penalty, steering the planner away from
        over-sharding.)
        """
        import dataclasses as _dc

        import jax
        import jax.numpy as jnp

        from repro.clock import SystemClock
        from repro.kernels import ops  # lazy: keep dse importable alone

        clock = clock or SystemClock()
        base = cls()
        rng = np.random.default_rng(0)
        dtype = jnp.dtype(c.dtype_name)

        def mk_params():
            return {"w1": jnp.asarray(rng.normal(0, .4, (c.i_dim, c.h_dim)),
                                      dtype),
                    "b1": jnp.asarray(rng.normal(0, .1, (c.h_dim,)), dtype),
                    "w2": jnp.asarray(rng.normal(0, .4, (c.h_dim, c.i_dim)),
                                      dtype),
                    "b2": jnp.asarray(rng.normal(0, .1, (c.i_dim,)), dtype)}

        def timed(fn):
            fn()                                   # compile
            ts = []
            for _ in range(reps):
                t0 = clock.now()
                out = fn()
                jax.tree_util.tree_map(
                    lambda a: a.block_until_ready()
                    if hasattr(a, "block_until_ready") else a, out)
                ts.append(clock.now() - t0)
            ts.sort()
            return ts[len(ts) // 2]

        params = mk_params()
        x0 = jnp.asarray(rng.normal(0, .3, (c.s_block, c.i_dim)), dtype)
        rows = max(4, c.t_block // 2)
        steps = 2 * rows
        c_half = _dc.replace(c, t_block=max(2, c.t_block // 2))
        t1 = timed(lambda: ops.chaotic_bits(
            params, x0, steps, config=c, backend=backend))
        t2 = timed(lambda: ops.chaotic_bits(
            params, x0, 2 * steps, config=c, backend=backend))
        t3 = timed(lambda: ops.chaotic_bits(
            params, x0, steps, config=c_half, backend=backend))
        if t2 <= t3:                              # timing noise: keep defaults
            return base
        cell_sec = max(0.0, t3 - t1)
        step_sec = (t2 - t3) / steps
        launch_sec = max(0.0, t1 - cell_sec - steps * step_sec)
        spc = step_sec / base.step_cycles(c)
        overhead = float(np.clip(launch_sec / spc, 500.0, 5e8))
        cell_overhead = float(np.clip(cell_sec / spc, 0.0, 5e8))
        scale, freeze = 1.0, cls.freeze_row_cycles
        if c.compute_unit == "vpu":
            plist = [mk_params() for _ in range(n_cores)]
            stacked = {k: jnp.stack([p[k] for p in plist])
                       for k in ("w1", "b1", "w2", "b2")}
            xs = jnp.asarray(rng.normal(0, .3, (n_cores, c.s_block, c.i_dim)),
                             dtype)
            t4 = timed(lambda: ops.chaotic_bits_gang_stacked(
                stacked, xs, steps, config=c, backend=backend))
            st_step_sec = max(1e-12, t4 - launch_sec - cell_sec) / steps
            m = measure_candidate(c)
            sweep = max(m["compute_cycles"], m["memory_cycles"]) * n_cores
            scale = float(np.clip(
                (st_step_sec / spc - _overhead_share(c)) / sweep, 0.1, 4.0))
            skew_map = np.asarray([rows] + [min(rows, 4)] * (n_cores - 1),
                                  np.int32)
            t5 = timed(lambda: ops.chaotic_bits_gang_stacked(
                stacked, xs, steps, row_map=skew_map, config=c,
                backend=backend))
            freeze = float(np.clip((t5 - t4) / rows / spc,
                                   cls.freeze_row_cycles, 5e7))
        cross = cls.cross_dev_overhead_cycles
        if mesh is not None and int(mesh.shape[mesh_axis]) > 1:
            n_dev = int(mesh.shape[mesh_axis])
            plist = [mk_params() for _ in range(n_dev)]
            gparams = {k: jnp.stack([p[k] for p in plist])
                       for k in ("w1", "b1", "w2", "b2")}
            xg = jnp.asarray(
                rng.normal(0, .3, (n_dev * c.s_block, c.i_dim)), dtype)
            cmap = np.arange(n_dev, dtype=np.int32)
            t6 = timed(lambda: ops.chaotic_bits_gang(
                gparams, xg, steps, core_map=cmap, config=c,
                backend=backend, mesh=mesh, mesh_axis=mesh_axis))
            cross = float(np.clip((t6 - t1) / (n_dev - 1) / spc, 0.0, 5e8))
        return cls(launch_overhead_cycles=overhead,
                   cell_overhead_cycles=cell_overhead,
                   stacked_step_scale=scale, freeze_row_cycles=freeze,
                   cross_dev_overhead_cycles=cross,
                   sec_per_cycle=spc)


# ---------------------------------------------------------------------------
# Exploration (paper §III-B.1, Figs. 3 & 5)
# ---------------------------------------------------------------------------

def enumerate_candidates(i_dim: int, h_dim: int,
                         p_levels: Sequence[int] = range(0, 6),
                         units: Sequence[str] = ("vpu", "mxu"),
                         dtypes: Sequence[int] = (4, 2),
                         unrolls: Sequence[int] = (1, 2, 4, 8),
                         t_blocks: Sequence[int] = (32, 64, 128, 256),
                         n_nodes: int = 1) -> List[Candidate]:
    out = []
    for p, u, d, un, tb in itertools.product(p_levels, units, dtypes, unrolls, t_blocks):
        c = Candidate(i_dim=i_dim, h_dim=h_dim, p=p, compute_unit=u,
                      dtype_bytes=d, unroll=un, t_block=tb, n_nodes=n_nodes)
        if vmem_bytes(c) <= VMEM_USABLE:
            out.append(c)
    return out


def _objective_score(c: Candidate, i_dim: int, h_dim: int,
                     lm: "LatencyModel", cm: "CostModel",
                     objective: str) -> Tuple[float, ...]:
    """The shared selection key: (primary estimate, objective-true ties).

    Ties are broken in the objective's own currency: min_latency prefers
    the lower analytic control-overhead share, lowest_cost prefers the
    smaller *measured* VMEM working set (the estimator is blind to
    (t_block, unroll) but the real footprint is not — out/hidden buffers
    scale with both), with overhead as the final tie-break.

    Lattice candidates (``n_nodes > 1``) score on the extended cycle
    model directly: the Eq. 8/9 estimators were fitted on scalar-core
    sizes (I<=8, H<=32) and normalize per I*H, so extrapolating them to
    lattice dims would erase exactly the block-sparse compute-unit
    tradeoff the lattice arms of ``measure_candidate`` encode.
    """
    if c.n_nodes > 1:
        m = measure_candidate(c)
        if objective == "min_latency":
            return (m["per_stream_latency_cycles"], _overhead_share(c))
        if objective == "lowest_cost":
            return (m["vmem_bytes"], _overhead_share(c))
        raise ValueError(f"unknown objective {objective!r}")
    if objective == "min_latency":
        primary = lm.predict(i_dim, h_dim, c.p, c.compute_unit, c.dtype_bytes)
        return (primary, _overhead_share(c))
    if objective == "lowest_cost":
        primary = cm.predict(i_dim, h_dim, c.p, c.compute_unit, c.dtype_bytes)
        return (primary, float(vmem_bytes(c)), _overhead_share(c))
    raise ValueError(f"unknown objective {objective!r}")


def pareto_front(cands: Sequence[Candidate],
                 latency_model: LatencyModel | None = None,
                 cost_model: CostModel | None = None) -> List[Tuple[Candidate, float, float]]:
    """Non-dominated (cost, latency) set, using the *estimators* (the paper's
    DSE runs entirely on Eq. 8/9 estimates; synthesis happens after).

    Candidates tied on (cost, latency) — the estimators ignore (t_block,
    unroll) — are represented by the lowest-overhead one (same tie-break as
    ``select``/``select_config``), not by enumeration order.
    """
    scored = []
    for c in cands:
        if latency_model is not None:
            lat = latency_model.predict(c.i_dim, c.h_dim, c.p, c.compute_unit, c.dtype_bytes)
            cost = cost_model.predict(c.i_dim, c.h_dim, c.p, c.compute_unit, c.dtype_bytes)
        else:
            m = measure_candidate(c)
            lat, cost = m["per_stream_latency_cycles"], m["vmem_bytes"]
        scored.append((c, cost, lat))
    front = []
    for c, cost, lat in sorted(scored,
                               key=lambda t: (t[1], t[2], _overhead_share(t[0]))):
        if all(not (fc <= cost and fl <= lat) for _, fc, fl in front):
            front.append((c, cost, lat))
    return front


def select(i_dim: int, h_dim: int, mode: str = "pareto", p: int | None = None,
            latency_model: LatencyModel | None = None,
            cost_model: CostModel | None = None,
            n_nodes: int = 1) -> Candidate:
    """Paper's three user options: 'min_latency', 'lowest_cost', or
    'pareto' with requested parallelism P."""
    lm = latency_model or LatencyModel.fit()
    cm = cost_model or CostModel.fit()
    cands = enumerate_candidates(i_dim, h_dim, n_nodes=n_nodes)
    if mode in ("min_latency", "lowest_cost"):
        return min(cands,
                   key=lambda c: _objective_score(c, i_dim, h_dim, lm, cm, mode))
    if mode == "pareto":
        front = pareto_front(cands, lm, cm)
        if p is not None:
            match = [c for c, _, _ in front if c.p == p]
            if match:
                return match[0]
            return min((c for c, _, _ in front), key=lambda c: abs(c.p - p))
        return front[len(front) // 2][0]
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# Autotuner: the DSE output driving the hot path (per-process cached)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "f32": 4, "bf16": 2, 4: 4, 2: 2}


@functools.lru_cache(maxsize=None)
def _fitted_models() -> Tuple[LatencyModel, CostModel]:
    """Eq. 8/9 estimators, fitted once per process (~ms; pure numpy)."""
    return LatencyModel.fit(), CostModel.fit()


@functools.lru_cache(maxsize=None)
def select_config(i_dim: int, h_dim: int, s_total: Optional[int] = None,
                  dtype: object = "float32", unit: Optional[str] = None,
                  objective: str = "min_latency",
                  n_nodes: int = 1) -> Candidate:
    """Pick (s_block, t_block, unroll, compute_unit) for a kernel launch.

    The autotuned replacement for hand-picked per-call-site defaults: scores
    the enumerated design space with the *fitted* Eq. 8/9 estimators (the
    paper's DSE runs on estimates, not measurements), breaking ties between
    same-(P, unit) candidates with the analytic per-step overhead terms that
    the estimators normalize away.

    Args:
      s_total: number of streams the caller will actually launch; candidates
        whose stream block exceeds the padded stream count are dropped (they
        would only compute padding lanes).
      dtype: 'float32' | 'bfloat16' (or 4 | 2 byte widths, or a jnp dtype).
      unit: restrict to 'vpu' or 'mxu'; None searches both.
      objective: 'min_latency' | 'lowest_cost'.
    """
    key = dtype if isinstance(dtype, (str, int)) else np.dtype(dtype).name
    dt = _DTYPE_BYTES.get(key)
    if dt is None:
        raise ValueError(f"unknown dtype {dtype!r}")
    units = (unit,) if unit else ("vpu", "mxu")
    cands = enumerate_candidates(i_dim, h_dim, units=units, dtypes=(dt,),
                                 n_nodes=n_nodes)
    if s_total is not None:
        # p=0 (s_block=128) always fits the cap, so this never empties cands.
        s_cap = max(LANES, _pad(s_total, LANES))
        cands = [c for c in cands if c.s_block <= s_cap]
    if not cands:
        raise ValueError(f"no feasible candidate for I={i_dim} H={h_dim}")
    lm, cm = _fitted_models()
    return min(cands,
               key=lambda c: _objective_score(c, i_dim, h_dim, lm, cm, objective))
