"""Chaotic ODE systems and the RK-4 reference integrator (paper Eqs. 1-5).

The paper generates its training data by numerically solving a chaotic system
(Chen by default) with ``scipy.integrate.odeint``.  Here the integrator is a
pure-JAX fixed-step RK-4 (``lax.scan``), which is (a) the method the paper's
op-count analysis is built on (Eqs. 2-4) and (b) jit/vmap-able so the dataset
pipeline itself scales.  SciPy remains available in tests as an independent
oracle.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Callable, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ChaoticSystem:
    """A system of N autonomous ODEs dX/dt = f(X) (paper Eq. 1).

    ``n_mul_dynamic`` / ``n_add_dynamic`` are the dynamic-term operation
    counts of ``f`` used by the paper's Eq. 4 RK-4 cost model.
    """

    name: str
    dim: int
    f: Callable[[Array], Array]
    n_mul_dynamic: int
    n_add_dynamic: int
    # A point near the attractor, used as the default trajectory seed.
    x0: Tuple[float, ...] = ()
    # Integration step that keeps RK-4 stable on the attractor.
    dt: float = 0.01

    def __post_init__(self):
        if not self.x0:
            object.__setattr__(self, "x0", tuple([0.1] * self.dim))


def _chen(a: float = 35.0, b: float = 3.0, c: float = 28.0) -> ChaoticSystem:
    """Chen system (paper Eq. 5): 6 muls, 5 adds in f (paper counts)."""

    def f(x: Array) -> Array:
        x1, x2, x3 = x[..., 0], x[..., 1], x[..., 2]
        d1 = a * (x2 - x1)                      # 1 mul, 1 add
        d2 = (c - a) * x1 - x1 * x3 + c * x2    # 3 mul, 2 add (c-a folded const)
        d3 = x1 * x2 - b * x3                   # 2 mul, 1 add
        return jnp.stack([d1, d2, d3], axis=-1)

    return ChaoticSystem("chen", 3, f, n_mul_dynamic=6, n_add_dynamic=5,
                         x0=(-0.1, 0.5, -0.6), dt=0.002)


def _lorenz(sigma: float = 10.0, rho: float = 28.0, beta: float = 8.0 / 3.0) -> ChaoticSystem:
    def f(x: Array) -> Array:
        x1, x2, x3 = x[..., 0], x[..., 1], x[..., 2]
        d1 = sigma * (x2 - x1)
        d2 = x1 * (rho - x3) - x2
        d3 = x1 * x2 - beta * x3
        return jnp.stack([d1, d2, d3], axis=-1)

    return ChaoticSystem("lorenz", 3, f, n_mul_dynamic=5, n_add_dynamic=5,
                         x0=(1.0, 1.0, 1.0), dt=0.005)


def _rossler(a: float = 0.2, b: float = 0.2, c: float = 5.7) -> ChaoticSystem:
    def f(x: Array) -> Array:
        x1, x2, x3 = x[..., 0], x[..., 1], x[..., 2]
        d1 = -x2 - x3
        d2 = x1 + a * x2
        d3 = b + x3 * (x1 - c)
        return jnp.stack([d1, d2, d3], axis=-1)

    return ChaoticSystem("rossler", 3, f, n_mul_dynamic=2, n_add_dynamic=5,
                         x0=(0.0, 1.0, 0.0), dt=0.02)


def _chua(alpha: float = 15.6, beta: float = 28.0,
          m0: float = -1.143, m1: float = -0.714) -> ChaoticSystem:
    """Chua's circuit with the piecewise-linear diode (ReLU-expressible)."""

    def f(x: Array) -> Array:
        x1, x2, x3 = x[..., 0], x[..., 1], x[..., 2]
        h = m1 * x1 + 0.5 * (m0 - m1) * (jnp.abs(x1 + 1.0) - jnp.abs(x1 - 1.0))
        d1 = alpha * (x2 - x1 - h)
        d2 = x1 - x2 + x3
        d3 = -beta * x2
        return jnp.stack([d1, d2, d3], axis=-1)

    return ChaoticSystem("chua", 3, f, n_mul_dynamic=4, n_add_dynamic=7,
                         x0=(0.7, 0.0, 0.0), dt=0.01)


def _hyperlorenz(sigma: float = 10.0, rho: float = 28.0,
                 beta: float = 8.0 / 3.0, r: float = -1.0) -> ChaoticSystem:
    """4-D hyperchaotic Lorenz (Wang 2007): Lorenz plus a feedback state w.

    Hyperchaotic (two positive Lyapunov exponents) for r in about
    [-1.52, -0.06].  The farm's only I=4 system — it exercises every
    ``i_dim != 3`` padding path downstream (kernels, DSE, codegen, serving).
    """

    def f(x: Array) -> Array:
        x1, x2, x3, x4 = x[..., 0], x[..., 1], x[..., 2], x[..., 3]
        d1 = sigma * (x2 - x1) + x4             # 1 mul, 2 add
        d2 = x1 * (rho - x3) - x2               # 1 mul, 2 add
        d3 = x1 * x2 - beta * x3                # 2 mul, 1 add
        d4 = -x2 * x3 + r * x4                  # 2 mul, 1 add
        return jnp.stack([d1, d2, d3, d4], axis=-1)

    return ChaoticSystem("hyperlorenz", 4, f, n_mul_dynamic=6, n_add_dynamic=6,
                         x0=(1.0, 1.0, 1.0, 1.0), dt=0.005)


SYSTEMS = {s.name: s for s in (_chen(), _lorenz(), _rossler(), _chua(),
                               _hyperlorenz())}


# ---------------------------------------------------------------------------
# Block-coupled oscillator lattices (ROADMAP "Coupled-oscillator lattices")
# ---------------------------------------------------------------------------

# Diffusive coupling strength used for name-addressed lattices
# ("chen@ring8").  Weak relative to the base dynamics: the lattice must
# stay chaotic (strong coupling synchronizes the nodes, collapsing the
# lattice back to one effective oscillator).
DEFAULT_LATTICE_COUPLING = 0.05

_TOPOLOGY_CODES = {"ring": 0, "grid": 1}


def _grid_shape(n_nodes: int) -> Tuple[int, int]:
    """Most-square P x Q factorization of ``n_nodes`` for grid topology."""
    p = max(1, int(np.sqrt(n_nodes)))
    while n_nodes % p:
        p -= 1
    return p, n_nodes // p


def lattice_coupling_matrix(n_nodes: int, base_dim: int, strength: float,
                            topology: str = "ring") -> np.ndarray:
    """The dense form of the block-sparse diffusive coupling operator.

    ``C = strength * (A - deg*I) (x) I_d`` for the ring/torus adjacency
    ``A`` — the (negated, scaled) graph Laplacian applied per component.
    Block-sparse by construction: only the diagonal and nearest-neighbour
    (d x d) blocks are nonzero, never a dense N^2 coupling.  The dense
    array form exists for the MXU contraction and the ODE-level matvec;
    the VPU kernels never materialize it (wrapped rolls instead).
    """
    if topology not in _TOPOLOGY_CODES:
        raise ValueError(f"unknown lattice topology {topology!r}; "
                         f"have {sorted(_TOPOLOGY_CODES)}")
    if n_nodes < 2:
        raise ValueError(f"a lattice needs n_nodes >= 2, got {n_nodes}")
    adj = np.zeros((n_nodes, n_nodes), np.float64)
    if topology == "ring":
        for n in range(n_nodes):
            adj[n, (n - 1) % n_nodes] += 1.0
            adj[n, (n + 1) % n_nodes] += 1.0
    else:
        pp, qq = _grid_shape(n_nodes)
        for n in range(n_nodes):
            p_i, q_i = divmod(n, qq)
            adj[n, ((p_i - 1) % pp) * qq + q_i] += 1.0
            adj[n, ((p_i + 1) % pp) * qq + q_i] += 1.0
            adj[n, p_i * qq + (q_i - 1) % qq] += 1.0
            adj[n, p_i * qq + (q_i + 1) % qq] += 1.0
    deg = adj.sum(axis=1)
    lap = adj - np.diag(deg)
    cpl = float(strength) * np.kron(lap, np.eye(base_dim))
    return cpl.astype(np.float32)


def lattice(base_system: Union[str, ChaoticSystem], n_nodes: int,
            coupling: float = DEFAULT_LATTICE_COUPLING,
            topology: str = "ring") -> ChaoticSystem:
    """Couple ``n_nodes`` copies of a base system into one high-dimensional
    chaotic system: state dim = n_nodes * base.dim, nearest-neighbour
    diffusive coupling on a ring or torus.

        dX_n/dt = f_base(X_n) + coupling * sum_{m ~ n} (X_m - X_n)

    The Jacobian is block-sparse (per-node blocks + neighbour identity
    blocks) — this is the oscillatory-NN paper's escape from quadratic
    hardware scaling, and what makes the MXU arm winnable: dims grow as
    n_nodes * d, not n_nodes^2.
    """
    base = get_system(base_system) if isinstance(base_system, str) \
        else base_system
    cpl_np = lattice_coupling_matrix(n_nodes, base.dim, coupling, topology)
    cpl = jnp.asarray(cpl_np)
    dim = n_nodes * base.dim

    def f(x: Array) -> Array:
        nodes = x.reshape(x.shape[:-1] + (n_nodes, base.dim))
        dyn = base.f(nodes).reshape(x.shape)
        return dyn + x @ cpl.T.astype(x.dtype)

    # Per-node perturbed seed: identical node seeds + symmetric coupling
    # would start the lattice fully synchronized (one effective node).
    x0 = tuple(v * (1.0 + 0.03 * n) + 0.01 * n
               for n in range(n_nodes) for v in base.x0)
    deg = 2 if topology == "ring" else 4
    return ChaoticSystem(
        name=f"{base.name}@{topology}{n_nodes}",
        dim=dim, f=f,
        # Block-sparse Eq. 4 counts: per-node dynamics plus one scale and
        # ``deg`` neighbour adds per component — O(n_nodes), never N^2.
        n_mul_dynamic=n_nodes * base.n_mul_dynamic + dim,
        n_add_dynamic=n_nodes * base.n_add_dynamic + dim * deg,
        x0=x0, dt=base.dt)


def parse_lattice_name(name: str) -> Tuple[str, str, int]:
    """Split a lattice system name into ``(base, topology, n_nodes)``.

    Lattices are name-addressed throughout the stack as
    ``<base>@<ring|grid><n>`` (e.g. ``chen@ring8``) — the weight registry,
    the serving farm, and codegen all key on this one spelling.
    """
    base_name, spec = name.split("@", 1)
    topo = spec.rstrip("0123456789")
    tail = spec[len(topo):]
    if topo not in _TOPOLOGY_CODES or not tail:
        raise KeyError(
            f"bad lattice system {name!r}; want <base>@<ring|grid><n>, "
            f"e.g. 'chen@ring8'")
    return base_name, topo, int(tail)


@functools.lru_cache(maxsize=None)
def _lattice_by_name(name: str) -> ChaoticSystem:
    base_name, topo, n_nodes = parse_lattice_name(name)
    return lattice(get_system(base_name), n_nodes, topology=topo)


def get_system(name: str) -> ChaoticSystem:
    if "@" in name:
        return _lattice_by_name(name)
    try:
        return SYSTEMS[name]
    except KeyError:
        raise KeyError(f"unknown chaotic system {name!r}; have {sorted(SYSTEMS)}")


# ---------------------------------------------------------------------------
# RK-4 (paper Eqs. 2-3)
# ---------------------------------------------------------------------------

def rk4_step(f: Callable[[Array], Array], x: Array, dt: float) -> Array:
    """One classical RK-4 step.  Shapes broadcast; works batched."""
    k1 = f(x)
    k2 = f(x + (dt / 2) * k1)
    k3 = f(x + (dt / 2) * k2)
    k4 = f(x + dt * k3)
    return x + (dt / 6) * (k1 + 2 * k2 + 2 * k3 + k4)


@partial(jax.jit, static_argnames=("system_name", "n_steps"))
def integrate(system_name: str, x0: Array, n_steps: int, dt: float | None = None) -> Array:
    """Integrate ``n_steps`` RK-4 steps.  Returns (n_steps+1, ...) trajectory.

    ``x0`` may be (dim,) or batched (B, dim); the trajectory keeps the batch.
    """
    sys_ = get_system(system_name)
    dt = sys_.dt if dt is None else dt

    def body(x, _):
        x_next = rk4_step(sys_.f, x, dt)
        return x_next, x_next

    _, traj = jax.lax.scan(body, x0, None, length=n_steps)
    return jnp.concatenate([x0[None], traj], axis=0)


# ---------------------------------------------------------------------------
# Op-count models (paper Eq. 4 and Eq. 7 / Table I)
# ---------------------------------------------------------------------------

def rk4_op_counts(system: ChaoticSystem) -> Tuple[int, int]:
    """Paper Eq. 4: static + dynamic multiplication/addition counts of RK-4."""
    n = system.dim
    n_mul = (3 * n * n + 3 * n) + 4 * system.n_mul_dynamic
    n_add = (3 * n * n + 4 * n) + 4 * system.n_add_dynamic
    return n_mul, n_add


def ann_op_counts(layer_sizes: Tuple[int, ...]) -> Tuple[int, int]:
    """Paper Eq. 7 for a feed-forward net given (n_1, ..., n_L) neuron counts.

    For 3-8-3: 48 muls, 59 adds (Table I).
    """
    n_mul = sum(layer_sizes[i] * layer_sizes[i - 1] for i in range(1, len(layer_sizes)))
    n_add = sum(layer_sizes[i] * (layer_sizes[i - 1] + 1) for i in range(1, len(layer_sizes)))
    return n_mul, n_add


# ---------------------------------------------------------------------------
# Dataset generation (paper §III-A)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChaoticDataset:
    """Labelled one-step pairs: model learns X_t -> X_{t+1} (paper §III-A)."""

    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    # Per-dimension affine normalizer mapping attractor range into [-1, 1];
    # the hardware core runs in normalized space (bounded signals).
    scale: np.ndarray
    offset: np.ndarray
    system: str
    dt: float


def normalize(x: Array, scale: Array, offset: Array) -> Array:
    return (x - offset) / scale


def denormalize(x: Array, scale: Array, offset: Array) -> Array:
    return x * scale + offset


def make_dataset(system_name: str, n_samples: int = 100_000,
                 train_frac: float = 0.8, burn_in: int = 2_000,
                 dt: float | None = None, seed: int = 0) -> ChaoticDataset:
    """Generate the paper's dataset: sample a long RK-4 trajectory; each
    labelled point is (X_t, X_{t+1}) for consecutive time steps."""
    sys_ = get_system(system_name)
    dt = sys_.dt if dt is None else dt
    x0 = jnp.asarray(sys_.x0, dtype=jnp.float32)
    # Burn in so samples lie on the attractor, then collect n_samples + 1.
    traj = integrate(system_name, x0, burn_in + n_samples, dt)
    traj = np.asarray(traj[burn_in:], dtype=np.float32)       # (n_samples+1, dim)

    lo, hi = traj.min(axis=0), traj.max(axis=0)
    scale = ((hi - lo) / 2.0).astype(np.float32)
    scale = np.where(scale == 0, 1.0, scale)
    offset = ((hi + lo) / 2.0).astype(np.float32)
    norm = (traj - offset) / scale

    x_all, y_all = norm[:-1], norm[1:]
    # Shuffle pairs before splitting (trajectory order leaks time otherwise).
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(x_all))
    x_all, y_all = x_all[perm], y_all[perm]
    n_train = int(train_frac * len(x_all))
    return ChaoticDataset(
        x_train=x_all[:n_train], y_train=y_all[:n_train],
        x_test=x_all[n_train:], y_test=y_all[n_train:],
        scale=scale, offset=offset, system=system_name, dt=dt,
    )
