"""Chaotic ODE systems and the RK-4 reference integrator (paper Eqs. 1-5).

The paper generates its training data by numerically solving a chaotic system
(Chen by default) with ``scipy.integrate.odeint``.  Here the integrator is a
pure-JAX fixed-step RK-4 (``lax.scan``), which is (a) the method the paper's
op-count analysis is built on (Eqs. 2-4) and (b) jit/vmap-able so the dataset
pipeline itself scales.  SciPy remains available in tests as an independent
oracle.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ChaoticSystem:
    """A system of N autonomous ODEs dX/dt = f(X) (paper Eq. 1).

    ``n_mul_dynamic`` / ``n_add_dynamic`` are the dynamic-term operation
    counts of ``f`` used by the paper's Eq. 4 RK-4 cost model.
    """

    name: str
    dim: int
    f: Callable[[Array], Array]
    n_mul_dynamic: int
    n_add_dynamic: int
    # A point near the attractor, used as the default trajectory seed.
    x0: Tuple[float, ...] = ()
    # Integration step that keeps RK-4 stable on the attractor.
    dt: float = 0.01

    def __post_init__(self):
        if not self.x0:
            object.__setattr__(self, "x0", tuple([0.1] * self.dim))


def _chen(a: float = 35.0, b: float = 3.0, c: float = 28.0) -> ChaoticSystem:
    """Chen system (paper Eq. 5): 6 muls, 5 adds in f (paper counts)."""

    def f(x: Array) -> Array:
        x1, x2, x3 = x[..., 0], x[..., 1], x[..., 2]
        d1 = a * (x2 - x1)                      # 1 mul, 1 add
        d2 = (c - a) * x1 - x1 * x3 + c * x2    # 3 mul, 2 add (c-a folded const)
        d3 = x1 * x2 - b * x3                   # 2 mul, 1 add
        return jnp.stack([d1, d2, d3], axis=-1)

    return ChaoticSystem("chen", 3, f, n_mul_dynamic=6, n_add_dynamic=5,
                         x0=(-0.1, 0.5, -0.6), dt=0.002)


def _lorenz(sigma: float = 10.0, rho: float = 28.0, beta: float = 8.0 / 3.0) -> ChaoticSystem:
    def f(x: Array) -> Array:
        x1, x2, x3 = x[..., 0], x[..., 1], x[..., 2]
        d1 = sigma * (x2 - x1)
        d2 = x1 * (rho - x3) - x2
        d3 = x1 * x2 - beta * x3
        return jnp.stack([d1, d2, d3], axis=-1)

    return ChaoticSystem("lorenz", 3, f, n_mul_dynamic=5, n_add_dynamic=5,
                         x0=(1.0, 1.0, 1.0), dt=0.005)


def _rossler(a: float = 0.2, b: float = 0.2, c: float = 5.7) -> ChaoticSystem:
    def f(x: Array) -> Array:
        x1, x2, x3 = x[..., 0], x[..., 1], x[..., 2]
        d1 = -x2 - x3
        d2 = x1 + a * x2
        d3 = b + x3 * (x1 - c)
        return jnp.stack([d1, d2, d3], axis=-1)

    return ChaoticSystem("rossler", 3, f, n_mul_dynamic=2, n_add_dynamic=5,
                         x0=(0.0, 1.0, 0.0), dt=0.02)


def _chua(alpha: float = 15.6, beta: float = 28.0,
          m0: float = -1.143, m1: float = -0.714) -> ChaoticSystem:
    """Chua's circuit with the piecewise-linear diode (ReLU-expressible)."""

    def f(x: Array) -> Array:
        x1, x2, x3 = x[..., 0], x[..., 1], x[..., 2]
        h = m1 * x1 + 0.5 * (m0 - m1) * (jnp.abs(x1 + 1.0) - jnp.abs(x1 - 1.0))
        d1 = alpha * (x2 - x1 - h)
        d2 = x1 - x2 + x3
        d3 = -beta * x2
        return jnp.stack([d1, d2, d3], axis=-1)

    return ChaoticSystem("chua", 3, f, n_mul_dynamic=4, n_add_dynamic=7,
                         x0=(0.7, 0.0, 0.0), dt=0.01)


def _hyperlorenz(sigma: float = 10.0, rho: float = 28.0,
                 beta: float = 8.0 / 3.0, r: float = -1.0) -> ChaoticSystem:
    """4-D hyperchaotic Lorenz (Wang 2007): Lorenz plus a feedback state w.

    Hyperchaotic (two positive Lyapunov exponents) for r in about
    [-1.52, -0.06].  The farm's only I=4 system — it exercises every
    ``i_dim != 3`` padding path downstream (kernels, DSE, codegen, serving).
    """

    def f(x: Array) -> Array:
        x1, x2, x3, x4 = x[..., 0], x[..., 1], x[..., 2], x[..., 3]
        d1 = sigma * (x2 - x1) + x4             # 1 mul, 2 add
        d2 = x1 * (rho - x3) - x2               # 1 mul, 2 add
        d3 = x1 * x2 - beta * x3                # 2 mul, 1 add
        d4 = -x2 * x3 + r * x4                  # 2 mul, 1 add
        return jnp.stack([d1, d2, d3, d4], axis=-1)

    return ChaoticSystem("hyperlorenz", 4, f, n_mul_dynamic=6, n_add_dynamic=6,
                         x0=(1.0, 1.0, 1.0, 1.0), dt=0.005)


SYSTEMS = {s.name: s for s in (_chen(), _lorenz(), _rossler(), _chua(),
                               _hyperlorenz())}


def get_system(name: str) -> ChaoticSystem:
    try:
        return SYSTEMS[name]
    except KeyError:
        raise KeyError(f"unknown chaotic system {name!r}; have {sorted(SYSTEMS)}")


# ---------------------------------------------------------------------------
# RK-4 (paper Eqs. 2-3)
# ---------------------------------------------------------------------------

def rk4_step(f: Callable[[Array], Array], x: Array, dt: float) -> Array:
    """One classical RK-4 step.  Shapes broadcast; works batched."""
    k1 = f(x)
    k2 = f(x + (dt / 2) * k1)
    k3 = f(x + (dt / 2) * k2)
    k4 = f(x + dt * k3)
    return x + (dt / 6) * (k1 + 2 * k2 + 2 * k3 + k4)


@partial(jax.jit, static_argnames=("system_name", "n_steps"))
def integrate(system_name: str, x0: Array, n_steps: int, dt: float | None = None) -> Array:
    """Integrate ``n_steps`` RK-4 steps.  Returns (n_steps+1, ...) trajectory.

    ``x0`` may be (dim,) or batched (B, dim); the trajectory keeps the batch.
    """
    sys_ = get_system(system_name)
    dt = sys_.dt if dt is None else dt

    def body(x, _):
        x_next = rk4_step(sys_.f, x, dt)
        return x_next, x_next

    _, traj = jax.lax.scan(body, x0, None, length=n_steps)
    return jnp.concatenate([x0[None], traj], axis=0)


# ---------------------------------------------------------------------------
# Op-count models (paper Eq. 4 and Eq. 7 / Table I)
# ---------------------------------------------------------------------------

def rk4_op_counts(system: ChaoticSystem) -> Tuple[int, int]:
    """Paper Eq. 4: static + dynamic multiplication/addition counts of RK-4."""
    n = system.dim
    n_mul = (3 * n * n + 3 * n) + 4 * system.n_mul_dynamic
    n_add = (3 * n * n + 4 * n) + 4 * system.n_add_dynamic
    return n_mul, n_add


def ann_op_counts(layer_sizes: Tuple[int, ...]) -> Tuple[int, int]:
    """Paper Eq. 7 for a feed-forward net given (n_1, ..., n_L) neuron counts.

    For 3-8-3: 48 muls, 59 adds (Table I).
    """
    n_mul = sum(layer_sizes[i] * layer_sizes[i - 1] for i in range(1, len(layer_sizes)))
    n_add = sum(layer_sizes[i] * (layer_sizes[i - 1] + 1) for i in range(1, len(layer_sizes)))
    return n_mul, n_add


# ---------------------------------------------------------------------------
# Dataset generation (paper §III-A)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChaoticDataset:
    """Labelled one-step pairs: model learns X_t -> X_{t+1} (paper §III-A)."""

    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    # Per-dimension affine normalizer mapping attractor range into [-1, 1];
    # the hardware core runs in normalized space (bounded signals).
    scale: np.ndarray
    offset: np.ndarray
    system: str
    dt: float


def normalize(x: Array, scale: Array, offset: Array) -> Array:
    return (x - offset) / scale


def denormalize(x: Array, scale: Array, offset: Array) -> Array:
    return x * scale + offset


def make_dataset(system_name: str, n_samples: int = 100_000,
                 train_frac: float = 0.8, burn_in: int = 2_000,
                 dt: float | None = None, seed: int = 0) -> ChaoticDataset:
    """Generate the paper's dataset: sample a long RK-4 trajectory; each
    labelled point is (X_t, X_{t+1}) for consecutive time steps."""
    sys_ = get_system(system_name)
    dt = sys_.dt if dt is None else dt
    x0 = jnp.asarray(sys_.x0, dtype=jnp.float32)
    # Burn in so samples lie on the attractor, then collect n_samples + 1.
    traj = integrate(system_name, x0, burn_in + n_samples, dt)
    traj = np.asarray(traj[burn_in:], dtype=np.float32)       # (n_samples+1, dim)

    lo, hi = traj.min(axis=0), traj.max(axis=0)
    scale = ((hi - lo) / 2.0).astype(np.float32)
    scale = np.where(scale == 0, 1.0, scale)
    offset = ((hi + lo) / 2.0).astype(np.float32)
    norm = (traj - offset) / scale

    x_all, y_all = norm[:-1], norm[1:]
    # Shuffle pairs before splitting (trajectory order leaks time otherwise).
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(x_all))
    x_all, y_all = x_all[perm], y_all[perm]
    n_train = int(train_frac * len(x_all))
    return ChaoticDataset(
        x_train=x_all[:n_train], y_train=y_all[:n_train],
        x_test=x_all[n_train:], y_test=y_all[n_train:],
        scale=scale, offset=offset, system=system_name, dt=dt,
    )
