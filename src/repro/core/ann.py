"""The HENNC ANN oscillator model and its trainer (paper §III-A, Table II).

A fully-connected I-H-I regressor approximates the chaotic system's one-step
map in normalized space.  Keras -> pure JAX; Adam, lr 1e-4, MSE loss, and the
paper's four regression metrics (MSE/MAE/RMSE/R²).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chaotic import (ChaoticDataset, _TOPOLOGY_CODES, denormalize,
                                get_system, lattice_coupling_matrix, rk4_step)
from repro.train.optimizer import Adam

Array = jax.Array

ACTIVATIONS = {
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
}


@dataclasses.dataclass(frozen=True)
class AnnConfig:
    """I-H-I oscillator net.  The paper sweeps H in {4, 8, 16} (Table III)."""

    dim: int = 3              # I: input == output neurons (system dimension)
    hidden: int = 8           # H: hidden neurons (Zhang: no gain beyond 8)
    activation: str = "relu"  # Table II winner: ReLU
    dtype: jnp.dtype = jnp.float32

    @property
    def layer_sizes(self) -> Tuple[int, int, int]:
        return (self.dim, self.hidden, self.dim)


def init_params(cfg: AnnConfig, key: jax.Array) -> Dict[str, Array]:
    k1, k2 = jax.random.split(key)
    s1 = jnp.sqrt(2.0 / cfg.dim)
    s2 = jnp.sqrt(2.0 / cfg.hidden)
    return {
        "w1": (jax.random.normal(k1, (cfg.dim, cfg.hidden)) * s1).astype(cfg.dtype),
        "b1": jnp.zeros((cfg.hidden,), cfg.dtype),
        "w2": (jax.random.normal(k2, (cfg.hidden, cfg.dim)) * s2).astype(cfg.dtype),
        "b2": jnp.zeros((cfg.dim,), cfg.dtype),
    }


def apply(cfg: AnnConfig, params: Dict[str, Array], x: Array) -> Array:
    """One oscillator step: y = W2·phi(W1·x + b1) + b2 (paper Eq. 6)."""
    phi = ACTIVATIONS[cfg.activation]
    h = phi(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def iterate(cfg: AnnConfig, params: Dict[str, Array], x0: Array, n_steps: int) -> Array:
    """Autonomous oscillation: feed the output back as the next input (Fig. 1).
    Returns (n_steps, ...) trajectory, excluding x0."""

    def body(x, _):
        x_next = apply(cfg, params, x)
        return x_next, x_next

    _, traj = jax.lax.scan(body, x0, None, length=n_steps)
    return traj


# ---------------------------------------------------------------------------
# Metrics (paper Table II)
# ---------------------------------------------------------------------------

def regression_metrics(pred: Array, target: Array) -> Dict[str, float]:
    pred = jnp.asarray(pred, jnp.float32)
    target = jnp.asarray(target, jnp.float32)
    err = pred - target
    mse = jnp.mean(jnp.square(err))
    mae = jnp.mean(jnp.abs(err))
    ss_res = jnp.sum(jnp.square(err))
    ss_tot = jnp.sum(jnp.square(target - jnp.mean(target, axis=0, keepdims=True)))
    r2 = 1.0 - ss_res / jnp.maximum(ss_tot, 1e-12)
    return {
        "mse": float(mse),
        "mae": float(mae),
        "rmse": float(jnp.sqrt(mse)),
        "r2": float(r2),
    }


# ---------------------------------------------------------------------------
# Trainer (paper Table II hyperparameters: MSE loss, Adam, lr 1e-4)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "opt"))
def _train_epoch(cfg: AnnConfig, opt: Adam, params, opt_state, xb, yb):
    """One epoch over pre-batched data xb/yb: (n_batches, B, dim)."""

    def loss_fn(p, x, y):
        return jnp.mean(jnp.square(apply(cfg, p, x) - y))

    def step(carry, batch):
        params, opt_state = carry
        x, y = batch
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        params, opt_state = opt.update(grads, opt_state, params)
        return (params, opt_state), loss

    (params, opt_state), losses = jax.lax.scan(step, (params, opt_state), (xb, yb))
    return params, opt_state, jnp.mean(losses)


def train(cfg: AnnConfig, dataset: ChaoticDataset, *, epochs: int = 50,
          batch_size: int = 256, lr: float = 1e-4, seed: int = 0,
          target_mse: float | None = None, verbose: bool = False):
    """Train the oscillator net.  Returns (params, history dict).

    Matches the paper's recipe; ``target_mse`` implements the paper's
    "training terminates when the model achieves the desired accuracy".
    """
    opt = Adam(lr=lr)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = opt.init(params)

    x, y = dataset.x_train, dataset.y_train
    n_batches = len(x) // batch_size
    xb = jnp.asarray(x[: n_batches * batch_size].reshape(n_batches, batch_size, -1))
    yb = jnp.asarray(y[: n_batches * batch_size].reshape(n_batches, batch_size, -1))

    history = {"train_loss": []}
    for epoch in range(epochs):
        params, opt_state, loss = _train_epoch(cfg, opt, params, opt_state, xb, yb)
        history["train_loss"].append(float(loss))
        if verbose and (epoch % 10 == 0 or epoch == epochs - 1):
            print(f"  epoch {epoch:4d}  train_mse {float(loss):.6f}")
        if target_mse is not None and float(loss) <= target_mse:
            break

    test_pred = apply(cfg, params, jnp.asarray(dataset.x_test))
    history["test_metrics"] = regression_metrics(test_pred, jnp.asarray(dataset.y_test))
    return params, history


def extract_parameters(params: Dict[str, Array]) -> Dict[str, np.ndarray]:
    """Paper §III-A: 'the network parameters are extracted for the hardware
    phase'.  Plain float32 numpy, the hand-off format for DSE + codegen."""
    return {k: np.asarray(v, dtype=np.float32) for k, v in params.items()}


def expand_lattice_params(base_params: Dict[str, Array], *, n_nodes: int,
                          coupling: float, topology: str = "ring"
                          ) -> Dict[str, np.ndarray]:
    """Derive a block-coupled lattice core's parameters from ONE trained
    base oscillator — no dense N^2 training, the block-sparse scaling
    route.

    The returned dict keeps the standard ``w1/b1/w2/b2`` keys at lattice
    size (block-diagonal per-node weight blocks, tiled biases), so every
    downstream consumer — dim inference, gang weight stacking, codegen's
    npz round trip — works unchanged.  Two extra keys carry the lattice:

    * ``coupling`` — the dense (I, I) diffusive operator array (the MXU
      contraction operand; block-sparse by construction);
    * ``lattice_meta`` — ``[n_nodes, base_dim, topology_code, strength]``
      as a plain numeric array (npz-serializable), from which the VPU
      kernels rebuild the roll-based coupling without the matrix.

    The lattice state dim must land on a whole number of sublanes
    (``n_nodes * base_dim % 8 == 0``): the wrapped-roll coupling and the
    sublane-stacked gang layout both need the per-node blocks packed
    with no padding rows between nodes.
    """
    w1 = np.asarray(base_params["w1"], np.float32)
    b1 = np.asarray(base_params["b1"], np.float32)
    w2 = np.asarray(base_params["w2"], np.float32)
    b2 = np.asarray(base_params["b2"], np.float32)
    d, h = w1.shape
    if n_nodes < 2:
        raise ValueError(f"a lattice needs n_nodes >= 2, got {n_nodes}")
    if (n_nodes * d) % 8 != 0:
        raise ValueError(
            f"lattice state dim {n_nodes}*{d}={n_nodes * d} must be a "
            f"multiple of 8 sublanes (d={d}: n_nodes in "
            f"{[n for n in range(2, 65) if n * d % 8 == 0][:4]}...)")
    big_i, big_h = n_nodes * d, n_nodes * h
    w1_l = np.zeros((big_i, big_h), np.float32)
    w2_l = np.zeros((big_h, big_i), np.float32)
    for n in range(n_nodes):
        w1_l[n * d:(n + 1) * d, n * h:(n + 1) * h] = w1
        w2_l[n * h:(n + 1) * h, n * d:(n + 1) * d] = w2
    return {
        "w1": w1_l, "b1": np.tile(b1, n_nodes),
        "w2": w2_l, "b2": np.tile(b2, n_nodes),
        "coupling": lattice_coupling_matrix(n_nodes, d, coupling, topology),
        "lattice_meta": np.asarray(
            [n_nodes, d, _TOPOLOGY_CODES[topology], coupling], np.float32),
    }


def lattice_meta_tuple(meta) -> Tuple[int, int, str, float]:
    """Decode a ``lattice_meta`` array into the kernels' static lattice
    descriptor ``(n_nodes, base_dim, topology, strength)``."""
    m = np.asarray(meta, np.float32).reshape(-1)
    names = {v: k for k, v in _TOPOLOGY_CODES.items()}
    return (int(m[0]), int(m[1]), names[int(m[2])], float(m[3]))


def one_step_reference(system_name: str, dataset: ChaoticDataset, x_norm: Array) -> Array:
    """RK-4 oracle for the same one-step map in normalized space (testbench)."""
    sys_ = get_system(system_name)
    scale = jnp.asarray(dataset.scale)
    offset = jnp.asarray(dataset.offset)
    x = denormalize(x_norm, scale, offset)
    x_next = rk4_step(sys_.f, x, dataset.dt)
    return (x_next - offset) / scale
