"""Fault-tolerant checkpointing: atomic, keep-K, resume-latest, elastic
re-shard on restore.

Layout:  <dir>/step_<N>/  with one .npy per leaf + manifest.json holding the
flattened tree paths, dtypes and the saved step.  Writes go to a tmp dir
followed by an atomic rename, so a preemption mid-save never corrupts the
latest checkpoint.  Restore accepts a target sharding tree (possibly for a
*different* mesh than the one that saved) — checkpoints store logical,
unsharded arrays, so elastic re-scaling is a restore-time device_put.
"""
from __future__ import annotations

import json
import pathlib
import shutil
from typing import Any, Dict, List, Optional

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = []
    for path, _ in leaves:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        paths.append(".".join(parts))
    return paths, [l for _, l in leaves], treedef


def save(ckpt_dir: str | pathlib.Path, step: int, state: PyTree,
         keep: int = 3) -> pathlib.Path:
    """Atomic checkpoint save; prunes to the newest ``keep`` checkpoints."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:010d}"
    tmp = ckpt_dir / f".tmp_step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    paths, leaves, _ = _flatten(state)
    manifest: Dict[str, Any] = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":   # npy can't describe bf16: store bits
            arr = arr.view(np.uint16)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            {"path": path, "file": fname, "dtype": dtype_name,
             "shape": list(arr.shape)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic publish

    steps = sorted(all_steps(ckpt_dir))
    for old in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{old:010d}", ignore_errors=True)
    return final


def all_steps(ckpt_dir: str | pathlib.Path) -> List[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and (p / "manifest.json").exists():
            out.append(int(p.name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str | pathlib.Path) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str | pathlib.Path, like: PyTree, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``like``.  If ``shardings`` is given
    (tree of NamedSharding, matching ``like``), leaves are device_put with
    the new sharding — elastic re-scale across mesh shapes."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    src = ckpt_dir / f"step_{step:010d}"
    manifest = json.loads((src / "manifest.json").read_text())

    paths, like_leaves, treedef = _flatten(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    new_leaves = []
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda s: hasattr(s, "mesh"))
        if shardings is not None else [None] * len(like_leaves))
    for path, like_leaf, shd in zip(paths, like_leaves, shard_leaves):
        if path not in by_path:
            raise KeyError(f"checkpoint missing leaf {path}")
        entry = by_path[path]
        arr = np.load(src / entry["file"])
        if entry["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(like_leaf.shape):
            raise ValueError(
                f"shape mismatch for {path}: ckpt {arr.shape} vs {like_leaf.shape}")
        if str(arr.dtype) != str(like_leaf.dtype):
            arr = jax.numpy.asarray(arr).astype(like_leaf.dtype)
        new_leaves.append(jax.device_put(arr, shd) if shd is not None
                          else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
