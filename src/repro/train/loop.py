"""Fault-tolerant training loop: checkpoint/restart, preemption handling,
straggler watchdog, deterministic resume.

Designed for 1000+-node operation:
  - resume-from-latest on start (crash/preemption restart is a no-op rerun);
  - SIGTERM/SIGINT triggers an emergency checkpoint at the next step
    boundary (cooperative preemption, the TPU-pod eviction pattern);
  - a step-time watchdog flags stragglers: steps slower than
    ``straggler_factor`` x the trailing median are logged with the step
    index (on real pods this feeds the controller's replace-node decision);
  - data order is a pure function of step, so restart never replays or
    skips batches.
"""
from __future__ import annotations

import dataclasses
import signal
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.clock import Clock, SystemClock
from repro.train import checkpoint as ckpt

PyTree = Any


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


@dataclasses.dataclass
class LoopResult:
    final_state: Any
    metrics_history: List[Dict[str, float]]
    resumed_from: Optional[int]
    straggler_steps: List[int]
    preempted: bool


def run(state: PyTree, train_step: Callable, batch_at: Callable[[int], Dict],
        loop_cfg: LoopConfig, put_batch: Optional[Callable] = None,
        log_fn: Callable[[str], None] = print,
        clock: Optional[Clock] = None) -> LoopResult:
    # The straggler watchdog compares step durations, so the timer must be
    # monotonic; injecting a FakeClock makes watchdog behavior testable
    # without real multi-second steps.
    clock = clock or SystemClock()
    resumed_from = None
    if loop_cfg.ckpt_dir:
        latest = ckpt.latest_step(loop_cfg.ckpt_dir)
        if latest is not None:
            state = ckpt.restore(loop_cfg.ckpt_dir, state, step=latest)
            resumed_from = latest
            log_fn(f"[loop] resumed from checkpoint step {latest}")

    preempt = {"flag": False}

    def on_signal(signum, frame):
        preempt["flag"] = True
        log_fn(f"[loop] signal {signum}: emergency checkpoint at next step")

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, on_signal)
        except ValueError:           # non-main thread (tests)
            pass

    history: List[Dict[str, float]] = []
    stragglers: List[int] = []
    step_times: List[float] = []
    start = int(jax.device_get(state.step)) if hasattr(state, "step") else 0

    try:
        for step in range(start, loop_cfg.total_steps):
            t0 = clock.now()
            batch = batch_at(step)
            if put_batch is not None:
                batch = put_batch(batch)
            state, metrics = train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = clock.now() - t0

            if step_times:
                med = float(np.median(step_times[-20:]))
                if dt > loop_cfg.straggler_factor * med:
                    stragglers.append(step)
                    log_fn(f"[loop] straggler at step {step}: "
                           f"{dt:.3f}s vs median {med:.3f}s")
            step_times.append(dt)

            if step % loop_cfg.log_every == 0:
                m = {k: float(jax.device_get(v)) for k, v in metrics.items()}
                m["step"] = step
                m["step_time_s"] = dt
                history.append(m)
                log_fn(f"[loop] step {step} loss {m.get('loss', float('nan')):.4f} "
                       f"({dt * 1e3:.0f} ms)")

            should_ckpt = loop_cfg.ckpt_dir and (
                (step + 1) % loop_cfg.ckpt_every == 0 or preempt["flag"]
                or step + 1 == loop_cfg.total_steps)
            if should_ckpt:
                ckpt.save(loop_cfg.ckpt_dir, step + 1, state, keep=loop_cfg.keep)
            if preempt["flag"]:
                log_fn(f"[loop] preempted at step {step + 1}; state saved")
                break
    finally:
        for sig, h in old_handlers.items():
            signal.signal(sig, h)

    return LoopResult(final_state=state, metrics_history=history,
                      resumed_from=resumed_from, straggler_steps=stragglers,
                      preempted=preempt["flag"])
