"""Optimizers, schedules and gradient transforms (pure JAX, tree-based).

Self-contained optax-like substrate: Adam/AdamW with decoupled weight decay,
global-norm clipping, and warmup-cosine schedules.  Used by both the HENNC
oscillator trainer (paper Table II: Adam, lr 1e-4, MSE) and the LM training
loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class Adam:
    """Adam/AdamW.  ``lr`` may be a float or a step -> lr schedule callable."""

    lr: Any = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = None
    # dtype for the first/second moments (fp32 keeps 72B-scale training sane).
    state_dtype: Any = jnp.float32

    def init(self, params: PyTree) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, dtype=self.state_dtype)
        return AdamState(
            step=jnp.zeros((), dtype=jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def _lr_at(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return jnp.asarray(self.lr(step), dtype=jnp.float32)
        return jnp.asarray(self.lr, dtype=jnp.float32)

    def update(self, grads: PyTree, state: AdamState, params: PyTree):
        """Returns (new_params, new_state)."""
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
                          state.nu, grads)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr_at(step)

        def upd(p, m, v):
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(delta.dtype)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(step=step, mu=mu, nu=nu)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    """Linear warmup then cosine decay to ``final_frac * peak_lr``."""

    def schedule(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac * peak_lr + (1 - final_frac) * peak_lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule
