"""Train-step builder: microbatched gradient accumulation, remat, mixed
precision, optional gradient compression — one jit-able function per
(model config, shape, mesh) cell.

The returned ``train_step(state, batch)`` is pure and pjit-friendly:
  - grads accumulate in fp32 with the same sharding as the (FSDP) params,
  - gradient accumulation is a ``lax.scan`` over microbatches (each
    microbatch re-runs the remat'd forward),
  - optional int8 error-feedback compression before the optimizer.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.compression import compress_grads
from repro.models import transformer as tf
from repro.train.optimizer import Adam, AdamState, global_norm

PyTree = Any


class TrainState(NamedTuple):
    step: jax.Array
    params: PyTree
    opt: AdamState
    error_buf: Optional[PyTree] = None   # gradient-compression feedback


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    num_microbatches: int = 1
    compress_grads: bool = False
    lb_coef: float = 0.01
    z_coef: float = 1e-3


def init_train_state(cfg: ModelConfig, opt: Adam, key,
                     use_compression: bool = False) -> TrainState:
    params = tf.init(cfg, key)
    ebuf = None
    if use_compression:
        ebuf = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=opt.init(params), error_buf=ebuf)


def make_train_step(cfg: ModelConfig, opt: Adam, ts_cfg: TrainStepConfig,
                    shard_fn=None) -> Callable:
    """Build the train_step.  batch leaves have leading dim global_batch
    (per-process view); microbatching splits dim 0."""
    shard = shard_fn or (lambda tag, x: x)

    def loss(params, mb):
        return tf.loss_fn(cfg, params, mb, shard_fn=shard,
                          lb_coef=ts_cfg.lb_coef, z_coef=ts_cfg.z_coef)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        n_mb = ts_cfg.num_microbatches
        if n_mb == 1:
            (l, metrics), grads = grad_fn(state.params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            # (B, ...) -> (n_mb, B/n_mb, ...) with the *kept* batch dim
            # carrying the dp sharding: row r = i*n_mb + j maps to
            # microbatch j, so every microbatch spans all dp shards.
            mbs = jax.tree.map(
                lambda a: a.reshape(
                    (a.shape[0] // n_mb, n_mb) + a.shape[1:]).swapaxes(0, 1),
                batch)

            def accum(carry, mb):
                (l_acc, g_acc) = carry
                (l, metrics), g = grad_fn(state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / n_mb, g_acc, g)
                return (l_acc + l / n_mb, g_acc), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (l, grads), metrics_all = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zeros), mbs)
            metrics = jax.tree.map(lambda m: m[-1], metrics_all)

        error_buf = state.error_buf
        if ts_cfg.compress_grads and error_buf is not None:
            grads, error_buf = compress_grads(grads, error_buf)

        gnorm = global_norm(grads)
        params, opt_state = opt.update(grads, state.opt, state.params)
        metrics = dict(metrics, loss=l, grad_norm=gnorm)
        new_state = TrainState(step=state.step + 1, params=params,
                               opt=opt_state, error_buf=error_buf)
        return new_state, metrics

    return train_step


def batch_spec(cfg: ModelConfig, shape: ShapeConfig,
               dtype=jnp.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one global batch (dry-run inputs)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend != "text" and shape.kind in ("train", "prefill"):
        # modality stub: precomputed patch/frame embeddings
        specs = {
            "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype)),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    else:
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    return specs
