"""Invariant linter + asyncio race detector for the serving stack.

Run it::

    PYTHONPATH=src python -m repro.analysis            # human output
    PYTHONPATH=src python -m repro.analysis --format=json

See :mod:`repro.analysis.engine` for the rule/suppression/baseline
model and :mod:`repro.analysis.rules` for what is enforced.
"""
from repro.analysis.engine import (Finding, Report, Suppression,
                                   analyze_text, check_baseline,
                                   run_analysis)

__all__ = ["Finding", "Report", "Suppression", "analyze_text",
           "check_baseline", "run_analysis"]
