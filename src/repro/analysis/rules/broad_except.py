"""broad-except: ``except Exception`` / bare ``except`` needs a reason.

A broad catch is sometimes exactly right here — the flusher task must
survive any flush failure, a sweep cell must record its traceback and
let the other cells run.  But each such site is a place where a typo-
level bug (AttributeError, NameError) gets swallowed into a log nobody
reads, so the policy is: every broad catch either narrows to the
exceptions the code actually expects, or carries
``# repro: allow[broad-except] reason=...`` stating what is caught and
where the error is kept.  The suppression reason IS the documentation.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import FileContext, Finding, Rule

_BROAD = ("Exception", "BaseException")


def _is_broad(type_node) -> bool:
    if type_node is None:
        return True                      # bare except:
    if isinstance(type_node, ast.Name):
        return type_node.id in _BROAD
    if isinstance(type_node, ast.Tuple):
        return any(_is_broad(e) for e in type_node.elts)
    return False


class BroadExceptRule(Rule):
    name = "broad-except"
    doc = "broad exception handlers must narrow or carry a reasoned allow"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ExceptHandler) and _is_broad(node.type):
                what = ("bare except:" if node.type is None
                        else f"except {ast.unparse(node.type)}")
                yield self.finding(
                    ctx, node,
                    f"{what} swallows typo-level bugs (AttributeError, "
                    f"NameError) along with the expected failures: narrow "
                    f"to the exceptions this site really expects, or "
                    f"suppress with a reason saying what is caught and "
                    f"where the error is kept")
