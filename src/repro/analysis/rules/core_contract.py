"""core-contract: generated cores draw through the fused serving launch.

A generated core package (``results/generated_cores/<name>/``) is the
hand-off artifact between codegen and the serving stack: the farm
imports it and trusts that its ``generate_bits`` is bit-compatible with
gang serving.  That holds only if the core draws through the fused
``ops.chaotic_bits`` launch AND plumbs the resumability contract —
``word_offset`` in, ``(words, final_state)`` out — because the serving
tier resumes every tenant stream chunk-by-chunk from exactly those two
values.  A hand-edited or stale core that drops ``word_offset`` (or
draws via a raw trajectory + host-side fold) would serve words that
silently diverge from the solo path after the first flush boundary.

Checked per ``__init__.py``: a ``generate_bits`` function exists, takes
a ``word_offset`` parameter, and returns the ``ops.chaotic_bits(...)``
call directly with ``word_offset`` forwarded into it.

The rule guards the serving layer's side of the same contract too:
``src/repro/serve/`` must not wrap its own ``shard_map``.  Device
sharding is owned by the launch stack — ``ops.chaotic_bits_gang(...,
mesh=)`` / the sharded gang kernels and
``distributed.sharding.shard_stream_pool`` — which carry the proven
bit-identity and scalar-prefetch-slicing contracts
(tests/test_sharded_gang.py).  A serve-layer ``shard_map`` would bypass
the gang scheduler entirely: words from such a launch are outside every
equivalence suite, the planner cannot cost it, and the compat key /
plan caches would not know its topology.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis.engine import FileContext, Finding, Rule


def _params(fn: ast.FunctionDef):
    a = fn.args
    return [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]


class CoreContractRule(Rule):
    name = "core-contract"
    doc = ("every generated core exposes generate_bits(word_offset=...) "
           "returning the fused ops.chaotic_bits launch; serve/ never "
           "wraps its own shard_map around one")

    def applies(self, rel: str) -> bool:
        return ((rel.startswith("results/generated_cores/")
                 and rel.endswith("__init__.py"))
                or rel.startswith("src/repro/serve/"))

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.rel.startswith("src/repro/serve/"):
            yield from self._check_serve(ctx)
            return
        fn: Optional[ast.FunctionDef] = None
        for node in ctx.tree.body:
            if isinstance(node, ast.FunctionDef) and node.name == "generate_bits":
                fn = node
                break
        if fn is None:
            yield self.finding(
                ctx, 1,
                "no generate_bits() at module level: the serving farm "
                "cannot draw from this core (regenerate it with "
                "repro.core.codegen)")
            return
        if "word_offset" not in _params(fn):
            yield self.finding(
                ctx, fn,
                "generate_bits() lacks a word_offset parameter: chunked "
                "serving cannot resume the word sequence, tenant streams "
                "diverge from the solo path at the first flush boundary")
            return
        for ret in ast.walk(fn):
            if not isinstance(ret, ast.Return) or ret.value is None:
                continue
            v = ret.value
            if (isinstance(v, ast.Call)
                    and isinstance(v.func, ast.Attribute)
                    and v.func.attr.startswith("chaotic_bits")
                    and self._forwards_word_offset(v)):
                return
        yield self.finding(
            ctx, fn,
            "generate_bits() does not return a fused ops.chaotic_bits(...) "
            "call forwarding word_offset: the core is not bit-compatible "
            "with gang serving (host-side folds or a dropped offset "
            "change the emitted words)")

    def _forwards_word_offset(self, call: ast.Call) -> bool:
        for n in ast.walk(call):
            if isinstance(n, ast.Name) and n.id == "word_offset":
                return True
        return False

    _SERVE_MSG = (
        "serve/ must not wrap its own shard_map: sharded launches route "
        "through the gang path (ops.chaotic_bits_gang(..., mesh=) / "
        "shard_stream_pool), whose bit-identity to the 1-device and solo "
        "paths is proven — a direct shard_map bypasses the gang "
        "scheduler, the cost model, and the topology-keyed plan caches")

    def _check_serve(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom):
                if ((node.module and "shard_map" in node.module)
                        or any(a.name == "shard_map" for a in node.names)):
                    yield self.finding(ctx, node, self._SERVE_MSG)
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if "shard_map" in a.name:
                        yield self.finding(ctx, node, self._SERVE_MSG)
            elif isinstance(node, ast.Call):
                f = node.func
                name = (f.id if isinstance(f, ast.Name)
                        else f.attr if isinstance(f, ast.Attribute) else "")
                if name == "shard_map":
                    yield self.finding(ctx, node, self._SERVE_MSG)
