"""kernel-dtype: bit-pattern hygiene in the PRNG kernels.

Two bug classes, both from this repo's history:

* **astype-before-bitcast** — the PRNG folds the *low mantissa bits* of
  the chaotic trajectory.  bf16 has 7 mantissa bits; upcasting to f32
  before the bitcast zero-fills the low 16 bits of every word, so the
  fold emits a zero-entropy counter hash.  Numerically nothing fails —
  NIST just rejects the stream later.  The only legal shape is the
  width-guarded one (``if x.dtype.itemsize == 2: bitcast at own width
  else: bitcast f32``), so ``bitcast_convert_type(<x>.astype(...))`` is
  flagged unless an ancestor ``if`` tests ``itemsize``/``nmant``.

* **foreign ops inside Pallas kernel bodies** — a kernel body (a
  function named ``*_kernel`` or taking ``*_ref`` params) executes as a
  traced Mosaic program; ``np.*``, ``os.*``, ``print`` etc. either
  fail at trace time under exotic configs or, worse, silently constant-
  fold host-side values into the kernel.  Attribute calls must root in
  an import alias of a ``jax*`` module; plain-name calls must be
  module-local helpers or safe builtins.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, Set

from repro.analysis.engine import FileContext, Finding, Rule

_BAD_BUILTINS = frozenset({"print", "open", "input", "eval", "exec",
                           "breakpoint", "compile"})
_GUARD_TOKENS = ("itemsize", "nmant")


def _import_map(tree: ast.AST) -> Dict[str, str]:
    """alias -> fully qualified module name, for module-level imports."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def _module_defs(tree: ast.AST) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(node.name)
    return names


def _is_kernel(fn: ast.AST) -> bool:
    if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return False
    if fn.name.endswith("_kernel"):
        return True
    args = fn.args
    every = (args.posonlyargs + args.args + args.kwonlyargs)
    return any(a.arg.endswith("_ref") for a in every)


def _root_name(node: ast.AST):
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


class KernelDtypeRule(Rule):
    name = "kernel-dtype"
    doc = ("no entropy-zeroing astype-before-bitcast; Pallas kernel "
           "bodies call only jax-family ops")

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/kernels/")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        imports = _import_map(ctx.tree)
        local_defs = _module_defs(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            yield from self._check_bitcast(ctx, node)
            fn = ctx.enclosing_function(node)
            if _is_kernel(fn):
                yield from self._check_kernel_call(
                    ctx, node, imports, local_defs)

    def _check_bitcast(self, ctx, node: ast.Call):
        try:
            fname = ast.unparse(node.func)
        except (ValueError, RecursionError):   # pathological/deep tree
            return
        if not fname.endswith("bitcast_convert_type") or not node.args:
            return
        arg = node.args[0]
        if not (isinstance(arg, ast.Call)
                and isinstance(arg.func, ast.Attribute)
                and arg.func.attr == "astype"):
            return
        for anc in ctx.ancestors(node):
            if isinstance(anc, ast.If):
                try:
                    test = ast.unparse(anc.test)
                except (ValueError, RecursionError):
                    test = ""
                if any(tok in test for tok in _GUARD_TOKENS):
                    return
        yield self.finding(
            ctx, node,
            "astype() before bitcast_convert_type without a dtype-width "
            "guard: upcasting a half-width float zero-fills the low "
            "mantissa bits and the PRNG fold emits a zero-entropy "
            "counter hash — bitcast at the input's own width (guard on "
            "dtype.itemsize, see ops._fold_low16)")

    def _check_kernel_call(self, ctx, node: ast.Call, imports, local_defs):
        f = node.func
        if isinstance(f, ast.Name):
            if f.id in _BAD_BUILTINS:
                yield self.finding(
                    ctx, node,
                    f"{f.id}() inside a Pallas kernel body: host-side "
                    f"effects do not belong in a traced Mosaic program")
            return
        if not isinstance(f, ast.Attribute):
            return
        root = _root_name(f)
        if root is None or root not in imports:
            return          # method on a local value (x.astype, ref loads)
        module = imports[root]
        if not module.startswith("jax"):
            yield self.finding(
                ctx, node,
                f"{ast.unparse(f)}() inside a Pallas kernel body roots in "
                f"non-jax module {module!r}: host-side ops silently "
                f"constant-fold or fail at trace time — use the jnp/lax/"
                f"pl equivalent")
