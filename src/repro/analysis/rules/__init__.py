"""The enforced invariants, one module per rule.

Each rule encodes a contract the codebase has already paid for — either
a property the tests prove (and a later edit could silently break) or a
bug class that actually shipped here once:

========================  ==================================================
clock-discipline          every time read goes through the injectable Clock
async-blocking            no blocking work lexically on the event loop
lock-await-race           single-flight-lock state is await-safe
crash-safety              committed artifacts publish via tmp + os.replace;
                          journal appends are fsync-backed
kernel-dtype              no entropy-zeroing astype-before-bitcast; Pallas
                          kernel bodies call only jax-family ops
broad-except              except Exception/bare except needs a reason
core-contract             generated cores draw through fused ops.chaotic_bits
                          with word_offset + final-state plumbing; serve/
                          never wraps its own shard_map around a launch
                          (sharding is owned by the gang path)
backoff-discipline        serve/ retry/backoff delays route through the
                          injected Clock (clock.wait), never asyncio.sleep —
                          FakeClock must drive the whole resilience suite
========================  ==================================================
"""
from typing import List

from repro.analysis.engine import Rule
from repro.analysis.rules.async_blocking import AsyncBlockingRule
from repro.analysis.rules.backoff_discipline import BackoffDisciplineRule
from repro.analysis.rules.broad_except import BroadExceptRule
from repro.analysis.rules.clock_discipline import ClockDisciplineRule
from repro.analysis.rules.core_contract import CoreContractRule
from repro.analysis.rules.crash_safety import CrashSafetyRule
from repro.analysis.rules.kernel_dtype import KernelDtypeRule
from repro.analysis.rules.lock_race import LockAwaitRaceRule


def all_rules() -> List[Rule]:
    return [
        ClockDisciplineRule(),
        AsyncBlockingRule(),
        LockAwaitRaceRule(),
        CrashSafetyRule(),
        KernelDtypeRule(),
        BroadExceptRule(),
        CoreContractRule(),
        BackoffDisciplineRule(),
    ]
