"""clock-discipline: every time read goes through the injectable Clock.

The serving stack's determinism contract (tests/test_async_frontend.py)
and the dry-run duration measurements both depend on time being an
*injected* dependency: a ``FakeClock`` makes every deadline/coalescing
behavior testable with zero real sleeps, and ``SystemClock.now()`` is
monotonic where ``time.time()`` can step under NTP mid-measurement.
That only holds if nobody reaches around the seam — so ``repro.clock``
is the ONE module allowed to import ``time``, and this rule flags any
other ``import time`` / ``time.<read>`` in the project.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import FileContext, Finding, Rule

ALLOWED_MODULE = "src/repro/clock.py"

TIME_READS = frozenset({
    "time", "perf_counter", "perf_counter_ns", "monotonic", "monotonic_ns",
    "sleep", "process_time", "process_time_ns",
})


class ClockDisciplineRule(Rule):
    name = "clock-discipline"
    doc = ("time may only be read through an injected repro.clock.Clock; "
           "repro/clock.py is the sole module that touches time.*")

    def applies(self, rel: str) -> bool:
        return super().applies(rel) and rel != ALLOWED_MODULE

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "time" or alias.name.startswith("time."):
                        yield self.finding(
                            ctx, node,
                            "import time outside repro/clock.py: inject a "
                            "Clock (repro.clock) instead so tests can drive "
                            "time and measurements stay monotonic")
            elif isinstance(node, ast.ImportFrom):
                if node.module == "time":
                    yield self.finding(
                        ctx, node,
                        "from time import ... outside repro/clock.py: "
                        "inject a Clock (repro.clock) instead")
            elif (isinstance(node, ast.Attribute)
                  and isinstance(node.value, ast.Name)
                  and node.value.id == "time"
                  and node.attr in TIME_READS):
                yield self.finding(
                    ctx, node,
                    f"time.{node.attr} outside repro/clock.py: route this "
                    f"read through an injected Clock")
