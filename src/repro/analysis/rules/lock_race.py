"""lock-await-race: the single-flight lock's state is await-safe.

Asyncio's concurrency unit is the await: a coroutine owns the world
between awaits, and every ``await`` is a point where *other* coroutines
run — including ones touching the same object.  The serving front-end's
correctness proof (bit-identity under arbitrary interleaving) leans on
two structural facts this rule re-checks on every PR:

1. **Lock domination** — the flush pipeline's mutating phases
   (``absorb``, ``_commit``, ``_resolve``) interleaved from two
   coroutines corrupt farm word accounting.  Every call site inside an
   ``async def`` must sit lexically under ``async with <...lock...>``.

2. **Load-await-store races** — inside a lock body, reading shared
   state, awaiting, then writing a value derived from the stale read is
   the classic lost-update (the admission-gauge double-release bug
   class): the await let another coroutine change the state the write
   clobbers.  The detector linearizes each lock body in execution order
   (assignment values before targets, an await event after its operand)
   and flags any ``<base>.<attr>`` store preceded by a load of the same
   attribute with an ``await`` in between.  ``x.n += 1`` (AugAssign)
   re-reads at the write and is NOT flagged.

Heuristic by design: it reasons per-function and lexically.  It proves
nothing — it just makes the two known-fatal shapes impossible to commit
silently.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Tuple

from repro.analysis.engine import FileContext, Finding, Rule

#: Calls that mutate farm/flush accounting and must hold the lock.
LOCKED_CALLS = frozenset({"absorb", "_commit", "_resolve"})

_Event = Tuple[str, object, ast.AST]   # (kind, key, node)


def _attr_key(node: ast.Attribute):
    if isinstance(node.value, ast.Name):
        return (node.value.id, node.attr)
    return None


def _linearize(stmts, events: List[_Event]) -> None:
    """Append load/store/await events in (approximate) execution order."""
    for stmt in stmts:
        _visit(stmt, events)


def _visit(node: ast.AST, events: List[_Event]) -> None:
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        return        # deferred execution: not part of this block's timeline
    if isinstance(node, ast.Await):
        _visit(node.value, events)
        events.append(("await", None, node))
        return
    if isinstance(node, (ast.Assign, ast.AnnAssign)):
        # value executes before the target is stored
        if getattr(node, "value", None) is not None:
            _visit(node.value, events)
        targets = node.targets if isinstance(node, ast.Assign) else [node.target]
        for t in targets:
            _visit(t, events)
        return
    if isinstance(node, ast.AugAssign):
        # x.n += v re-reads x.n at the write: atomic between awaits, safe.
        _visit(node.value, events)
        if isinstance(node.target, ast.Attribute):
            key = _attr_key(node.target)
            if key is not None:
                events.append(("load", key, node.target))
        return
    if isinstance(node, ast.Attribute):
        key = _attr_key(node)
        if key is not None:
            kind = "store" if isinstance(node.ctx, ast.Store) else "load"
            events.append((kind, key, node))
        _visit(node.value, events)
        return
    for child in ast.iter_child_nodes(node):
        _visit(child, events)


def _mentions_lock(expr: ast.AST) -> bool:
    try:
        return "lock" in ast.unparse(expr).lower()
    except (ValueError, RecursionError):   # pathological/deep tree
        return False


class LockAwaitRaceRule(Rule):
    name = "lock-await-race"
    doc = ("flush-mutating calls must hold the single-flight lock; no "
           "load-await-store on shared attributes inside lock bodies")

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/serve/")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                yield from self._check_domination(ctx, node)
            elif isinstance(node, ast.AsyncWith):
                yield from self._check_lock_body(ctx, node)

    def _check_domination(self, ctx, call: ast.Call):
        f = call.func
        name = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else "")
        if name not in LOCKED_CALLS:
            return
        fn = ctx.enclosing_function(call)
        if not isinstance(fn, ast.AsyncFunctionDef):
            return
        for anc in ctx.ancestors(call):
            if isinstance(anc, ast.AsyncWith) and any(
                    _mentions_lock(item.context_expr) for item in anc.items):
                return
        yield self.finding(
            ctx, call,
            f"{name}() mutates flush accounting but is not under `async "
            f"with <single-flight lock>`: two coroutines can interleave "
            f"absorb/commit/resolve against one farm")

    def _check_lock_body(self, ctx, node: ast.AsyncWith):
        if not any(_mentions_lock(item.context_expr) for item in node.items):
            return
        events: List[_Event] = []
        _linearize(node.body, events)
        loaded = {}          # key -> earliest load index pre-latest-await
        last_await = -1
        flagged = set()
        for i, (kind, key, n) in enumerate(events):
            if kind == "await":
                last_await = i
            elif kind == "load":
                loaded.setdefault(key, i)
            elif kind == "store":
                first_load = loaded.get(key)
                if (first_load is not None and first_load < last_await
                        and key not in flagged):
                    flagged.add(key)
                    base, attr = key
                    yield self.finding(
                        ctx, n,
                        f"{base}.{attr} stored after an await that follows "
                        f"a load of the same attribute: the awaited-out "
                        f"coroutine may have changed it (lost update); "
                        f"re-read after the await or use an atomic "
                        f"augmented assignment")
