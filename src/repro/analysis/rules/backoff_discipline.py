"""backoff-discipline: serve/ retry delays go through the injected Clock.

The supervision layer (PR 9) retries failed launches with exponential
backoff *under the single-flight lock*.  If that delay is an
``asyncio.sleep``, the whole resilience suite needs real wall time — a
3-retry storm at 200 ms cap is seconds of sleeping per test, and a
``FakeClock`` cannot drive it at all (fake time advancing does not wake
a real sleep).  Every delay in ``serve/`` must route through the
injectable ``Clock`` seam instead — ``await clock.wait(event, timeout)``
— which a ``FakeClock.advance()`` wakes deterministically with zero real
sleeps.  (Blocking ``time.sleep`` on the loop thread is the
``async-blocking`` rule's beat; this rule covers the *async* sleep that
looks innocent but breaks fake-time drivability.)
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import FileContext, Finding, Rule


class BackoffDisciplineRule(Rule):
    name = "backoff-discipline"
    doc = "serve/ delays route through the injected Clock, not asyncio.sleep"

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/serve/")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if (isinstance(f, ast.Attribute) and f.attr == "sleep"
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "asyncio"):
                yield self.finding(
                    ctx, node,
                    "asyncio.sleep() is invisible to FakeClock — retry/"
                    "backoff delays in serve/ must `await clock.wait("
                    "asyncio.Event(), delay_s)` through the injected "
                    "Clock so fake-time tests drive them with zero real "
                    "sleeps")
