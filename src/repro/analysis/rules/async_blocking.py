"""async-blocking: no blocking work lexically inside ``async def`` bodies.

The production tier's whole point (PR 6) is that the event loop stays
live while a gang launch runs on the executor: ingress, cancellation and
deadline accounting proceed mid-launch.  One blocking call on the loop
thread silently re-serializes everything — no test fails, p99 just
collapses.  This rule flags the blocking calls this codebase actually
has, when they appear lexically inside an ``async def`` in ``serve/``:

* ``time.sleep`` / ``os.fsync`` — the classic loop-stallers;
* ``.record_flush(`` / ``.record_register(`` — journal appends are
  fsync-backed (``FlushJournal._append``), so each call is a disk
  barrier (allowed only with a reasoned suppression, e.g. the one
  durability-ordering site in ``_flush_cycle``);
* ``.flush(`` without ``deliver=False`` — a delivering farm flush runs
  the gang kernel launch on the caller's thread; async code must split
  commit / offloaded launch / resolve instead;
* direct ``chaotic_bits`` launches — same, the kernel belongs on the
  executor;
* ``draw_sync`` — blocks on a future only the flusher on this very
  loop can resolve: a guaranteed deadlock (also enforced at runtime).

The inverse misuse is flagged too: a front-end ``.submit(`` from *sync*
code (the foreign-thread queue race, PR 6's S4 bugfix class) — sync
callers go through the thread-safe ``draw_sync`` ingress.  Executor /
pool ``submit`` is exempt by receiver name.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import FileContext, Finding, Rule

_BLOCKING_METHODS = {
    "record_flush": "fsync-backed journal append",
    "record_register": "fsync-backed journal append",
}


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _dotted(call: ast.Call) -> str:
    try:
        return ast.unparse(call.func)
    except (ValueError, RecursionError):   # pathological/deep tree
        return ""


class AsyncBlockingRule(Rule):
    name = "async-blocking"
    doc = "no blocking calls lexically inside async def bodies in serve/"

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/serve/")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = ctx.enclosing_function(node)
            if not isinstance(fn, ast.AsyncFunctionDef):
                yield from self._check_sync_misuse(ctx, node)
                continue
            name = _call_name(node)
            dotted = _dotted(node)
            if dotted in ("time.sleep", "os.fsync"):
                yield self.finding(
                    ctx, node,
                    f"{dotted}() blocks the event loop; use `await "
                    f"clock.wait(...)` / run_in_executor instead")
            elif name in _BLOCKING_METHODS:
                yield self.finding(
                    ctx, node,
                    f".{name}() is a {_BLOCKING_METHODS[name]} — a disk "
                    f"barrier on the loop thread; offload it or suppress "
                    f"with the durability reason")
            elif name == "flush" and isinstance(node.func, ast.Attribute):
                if not any(k.arg == "deliver"
                           and isinstance(k.value, ast.Constant)
                           and k.value.value is False
                           for k in node.keywords):
                    yield self.finding(
                        ctx, node,
                        "delivering farm .flush() runs the gang launch on "
                        "the loop thread; async code must commit on-loop, "
                        "launch flush(deliver=False) on the executor, and "
                        "resolve on-loop")
            elif name == "draw_sync":
                yield self.finding(
                    ctx, node,
                    "draw_sync() from the loop thread deadlocks (it blocks "
                    "on a future only this loop's flusher resolves); use "
                    "`await draw(...)`")
            elif name.startswith("chaotic_bits"):
                yield self.finding(
                    ctx, node,
                    f"direct kernel launch {name}() inside async def: the "
                    f"launch belongs on the executor (offloaded flush), "
                    f"not the loop thread")

    def _check_sync_misuse(self, ctx, node: ast.Call):
        """Front-end .submit() from sync code: the foreign-thread queue
        race (asyncio futures and the queue are loop-thread-only)."""
        f = node.func
        if not (isinstance(f, ast.Attribute) and f.attr == "submit"):
            return
        recv = _dotted(node).rsplit(".", 1)[0].lower()
        if "executor" in recv or "pool" in recv:
            return            # ThreadPoolExecutor.submit is sync-safe
        yield self.finding(
            ctx, node,
            ".submit() outside the event loop's coroutines races the "
            "request queue unsynchronized (asyncio futures are loop-"
            "thread-only); sync callers use draw_sync(), the thread-safe "
            "ingress")
