"""crash-safety: committed artifacts publish atomically; journals fsync.

The crash-recovery story (PR 6) has two halves this rule keeps honest:

* **Atomic publish** — a reader (a concurrent serving process importing
  a generated core, a restarted trainer opening a checkpoint) must see
  the previous complete artifact or the new one, never a torn mix.  The
  discipline is: write a tmp sibling, then ``os.replace`` (or
  ``Path.rename``) it over the committed name.  ``repro.atomicio`` is
  the shared helper.  This rule flags any direct write to a committed
  path — ``open(..., "w"/"wb")``, ``.write_text(...)``,
  ``np.save/savez(...)`` — unless the target is visibly a tmp file or
  the enclosing scope performs the replace/rename publish itself.

* **Journal durability** — ``FlushJournal``'s guarantee is that a
  record exists on disk before the flush it describes is acted on, which
  requires the append path to fsync.  In journal modules, any
  ``<obj>.<fileattr>.write(...)`` must share its function with an
  ``os.fsync`` call.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis.engine import FileContext, Finding, Rule

_NP_WRITERS = frozenset({"save", "savez", "savez_compressed"})


def _unparse(node: Optional[ast.AST]) -> str:
    if node is None:
        return ""
    try:
        return ast.unparse(node)
    except (ValueError, RecursionError):   # pathological/deep tree
        return ""


def _scope(ctx: FileContext, node: ast.AST) -> ast.AST:
    fn = ctx.enclosing_function(node)
    return fn if fn is not None else ctx.tree


def _publishes_atomically(scope: ast.AST) -> bool:
    """Does this scope call os.replace(...) or <path>.rename(...)?"""
    for n in ast.walk(scope):
        if not isinstance(n, ast.Call):
            continue
        f = n.func
        if isinstance(f, ast.Attribute) and f.attr in ("replace", "rename"):
            root = _unparse(f.value)
            if f.attr == "rename" or root == "os" or root.endswith(".os"):
                return True
    return False


def _is_tmp(text: str) -> bool:
    return "tmp" in text.lower()


class CrashSafetyRule(Rule):
    name = "crash-safety"
    doc = ("committed artifacts must publish via tmp + os.replace; "
           "journal appends must be fsync-backed")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            target = self._write_target(node)
            if target is not None:
                scope = _scope(ctx, node)
                if not _is_tmp(target) and not _publishes_atomically(scope):
                    yield self.finding(
                        ctx, node,
                        f"non-atomic write to {target!r}: a crash (or a "
                        f"concurrent reader) sees a torn file; write a tmp "
                        f"sibling + os.replace — use repro.atomicio")
        if "journal" in ctx.rel.rsplit("/", 1)[-1]:
            yield from self._check_journal_fsync(ctx)

    def _write_target(self, node: ast.Call) -> Optional[str]:
        """The unparsed committed-path expression, or None if not a write."""
        f = node.func
        if isinstance(f, ast.Name) and f.id == "open" and len(node.args) >= 2:
            mode = node.args[1]
            if (isinstance(mode, ast.Constant) and isinstance(mode.value, str)
                    and any(c in mode.value for c in "wx")):
                return _unparse(node.args[0])
            return None
        if isinstance(f, ast.Attribute):
            if f.attr in ("write_text", "write_bytes"):
                return _unparse(f.value)
            if f.attr in _NP_WRITERS and node.args:
                root = _unparse(f.value)
                if root in ("np", "numpy") or root.endswith("numpy"):
                    return _unparse(node.args[0])
        return None

    def _check_journal_fsync(self, ctx: FileContext):
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            writes = [n for n in ast.walk(fn)
                      if isinstance(n, ast.Call)
                      and isinstance(n.func, ast.Attribute)
                      and n.func.attr == "write"
                      and isinstance(n.func.value, ast.Attribute)]
            if not writes:
                continue
            fsyncs = any(isinstance(n, ast.Call)
                         and _unparse(n.func) == "os.fsync"
                         for n in ast.walk(fn))
            if not fsyncs:
                for w in writes:
                    yield self.finding(
                        ctx, w,
                        f"journal append in {fn.name}() without os.fsync: "
                        f"the durability contract (record exists before "
                        f"the flush is acted on) does not survive a crash "
                        f"with the page cache unflushed")
