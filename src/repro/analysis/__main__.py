"""CLI: ``python -m repro.analysis`` — lint the repo, gate on the baseline.

Exit status:
  0  no unsuppressed findings and the tree matches the committed baseline
  1  findings (or baseline violations: new findings / new suppressions)
  2  usage / IO errors

``--update-baseline`` rewrites ``.repro-analysis-baseline.json`` from the
current tree (do this in the same PR that adds a finding or suppression,
so the growth is explicit and reviewed).  Baseline entries the tree no
longer needs are warnings, never errors — the file only shrinks quietly.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.analysis.engine import (BASELINE_NAME, baseline_from_report,
                                   check_baseline, format_human, repo_root,
                                   run_analysis)
from repro.atomicio import atomic_write_text


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="invariant linter + asyncio race detector")
    ap.add_argument("--root", default=None,
                    help="repo root to scan (default: auto-detected)")
    ap.add_argument("--format", choices=("human", "json"), default="human")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: <root>/{BASELINE_NAME}; "
                         f"'none' disables the baseline gate)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current tree")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this path")
    args = ap.parse_args(argv)

    root = pathlib.Path(args.root).resolve() if args.root else repo_root()
    if not root.is_dir():
        print(f"error: root {root} is not a directory", file=sys.stderr)
        return 2
    report = run_analysis(root)

    baseline_path = (root / BASELINE_NAME if args.baseline is None
                     else pathlib.Path(args.baseline))
    if args.update_baseline:
        atomic_write_text(baseline_path,
                          json.dumps(baseline_from_report(report), indent=2)
                          + "\n")
        print(f"wrote {baseline_path}")

    errors, warnings = [], []
    if args.baseline != "none":
        if baseline_path.is_file():
            try:
                baseline = json.loads(baseline_path.read_text())
            except json.JSONDecodeError as e:
                print(f"error: {baseline_path} is not valid JSON: {e}",
                      file=sys.stderr)
                return 2
            errors, warnings = check_baseline(report, baseline)
        elif not args.update_baseline:
            errors = [f"{BASELINE_NAME} missing at {baseline_path}; run "
                      f"with --update-baseline to create it"]

    payload = report.to_json()
    payload["baseline_errors"] = errors
    payload["baseline_warnings"] = warnings
    payload["ok"] = not report.findings and not errors
    if args.out:
        atomic_write_text(args.out, json.dumps(payload, indent=2) + "\n")
    if args.format == "json":
        print(json.dumps(payload, indent=2))
    else:
        print(format_human(report, errors, warnings))
    return 0 if payload["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
