"""Rule engine for the project linter (``python -m repro.analysis``).

HENNC ships every generated hardware core with a machine-checked
validation testbench: correctness is enforced by tooling, not review.
This package is the same discipline applied to the *software* contracts
the serving stack has already paid for in bugs — injectable-Clock time
discipline, no blocking work on the event loop, exactly-once admission
release, fsync-then-replace atomic publishes, half-width bf16 bitcasts —
each codified as an AST rule that runs on every file, every PR.

Deliberately stdlib-only (``ast`` + ``re`` + ``json``): the CI lint job
needs no jax install and finishes in seconds.

Vocabulary
----------
* A **rule** inspects one file's AST/text and yields findings.  Rules
  self-scope by repo-relative path (``Rule.applies``), so e.g. the
  kernel-dtype rule only reads ``src/repro/kernels/``.
* A **finding** is (rule, path, line, message).
* A **suppression** is an inline comment on the finding's line or the
  line above::

      # repro: allow[rule-name] reason=why this site is exempt

  The reason is REQUIRED: a reasonless ``allow`` does not suppress and
  is itself reported (``suppression-hygiene``).  A suppression that
  matches no finding is reported too (``unused-suppression``), so stale
  exemptions cannot accumulate.
* The **baseline** (``.repro-analysis-baseline.json``) pins the accepted
  state: the set of known findings (empty on a clean tree) plus the full
  suppression inventory.  The gate is subset-only — a new finding or a
  new suppression fails until the baseline file is explicitly edited in
  the same PR, and entries the tree no longer needs are reported so the
  file only ever shrinks silently, never grows.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import pathlib
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*allow\[([A-Za-z0-9_,-]+)\]\s*(?:reason=(.*?))?\s*$")

#: Scan roots, relative to the repo root.  ``results/generated_cores`` is
#: restricted to package ``__init__.py`` files (the generated-core
#: contract surface); everything under ``src/repro`` is in scope.
SCAN_SRC = "src/repro"
SCAN_CORES = "results/generated_cores"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # repo-relative, posix separators
    line: int
    message: str

    def ident(self) -> Tuple[str, str]:
        """Baseline identity: line numbers drift, (path, rule) does not."""
        return (self.path, self.rule)


@dataclasses.dataclass
class Suppression:
    rule: str
    path: str
    line: int          # line of the comment
    reason: str
    used: bool = False


class FileContext:
    """Everything a rule may inspect about one file."""

    def __init__(self, rel: str, text: str):
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree = ast.parse(text)
        except SyntaxError as e:
            self.parse_error = e
        self._parents: Optional[Dict[ast.AST, ast.AST]] = None

    def parents(self) -> Dict[ast.AST, ast.AST]:
        """Child -> parent map over the whole tree (computed lazily)."""
        if self._parents is None:
            self._parents = {}
            assert self.tree is not None
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    self._parents[child] = node
        return self._parents

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        p = self.parents()
        while node in p:
            node = p[node]
            yield node

    def enclosing_function(self, node: ast.AST):
        """Nearest enclosing (async) function, or None at module level."""
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None


class Rule:
    """Base class: subclasses set ``name``/``doc`` and implement check()."""

    name = "abstract"
    doc = ""

    def applies(self, rel: str) -> bool:
        return rel.startswith(SCAN_SRC)

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, ctx: FileContext, node_or_line, message: str) -> Finding:
        line = (node_or_line if isinstance(node_or_line, int)
                else getattr(node_or_line, "lineno", 1))
        return Finding(self.name, ctx.rel, line, message)


def default_rules() -> List[Rule]:
    from repro.analysis.rules import all_rules
    return all_rules()


def parse_suppressions(rel: str, text: str) -> List[Suppression]:
    """Extract ``allow[...]`` suppressions from real COMMENT tokens only
    (so the syntax can be *documented* in docstrings without registering
    as a stale suppression)."""
    try:
        comments = [(t.start[0], t.string)
                    for t in tokenize.generate_tokens(
                        io.StringIO(text).readline)
                    if t.type == tokenize.COMMENT]
    except (tokenize.TokenError, SyntaxError, IndentationError):
        # unparseable file: fall back to raw lines so the parse-error
        # finding cannot be accompanied by silently-dropped suppressions
        comments = list(enumerate(text.splitlines(), start=1))
    out = []
    for lineno, comment in comments:
        m = SUPPRESS_RE.search(comment)
        if not m:
            continue
        reason = (m.group(2) or "").strip()
        for rule in m.group(1).split(","):
            out.append(Suppression(rule=rule.strip(), path=rel, line=lineno,
                                   reason=reason))
    return out


@dataclasses.dataclass
class Report:
    findings: List[Finding] = dataclasses.field(default_factory=list)
    suppressed: List[Finding] = dataclasses.field(default_factory=list)
    suppressions: List[Suppression] = dataclasses.field(default_factory=list)
    files_scanned: int = 0

    def to_json(self) -> Dict:
        return {
            "files_scanned": self.files_scanned,
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "suppressed": [dataclasses.asdict(f) for f in self.suppressed],
            "suppressions": [dataclasses.asdict(s)
                             for s in self.suppressions],
        }


def analyze_text(rel: str, text: str,
                 rules: Optional[Sequence[Rule]] = None) -> Report:
    """Lint one file's source under a virtual repo-relative path.

    The public seam for the fixture tests: rules scope by ``rel``, so a
    fixture can impersonate e.g. ``src/repro/serve/fake.py``.
    """
    rules = list(rules) if rules is not None else default_rules()
    ctx = FileContext(rel, text)
    report = Report(files_scanned=1)
    raw: List[Finding] = []
    if ctx.parse_error is not None:
        raw.append(Finding("parse-error", rel,
                           ctx.parse_error.lineno or 1,
                           f"file does not parse: {ctx.parse_error.msg}"))
    else:
        for rule in rules:
            if rule.applies(rel):
                raw.extend(rule.check(ctx))
    # Dedupe (nested async defs make some walks overlap), stable order.
    seen = set()
    uniq = []
    for f in sorted(raw, key=lambda f: (f.line, f.rule, f.message)):
        key = (f.rule, f.line, f.message)
        if key not in seen:
            seen.add(key)
            uniq.append(f)

    sups = parse_suppressions(rel, ctx.text)
    by_key: Dict[Tuple[str, int], Suppression] = {}
    for s in sups:
        # A suppression covers its own line and the line below it.
        by_key[(s.rule, s.line)] = s
        by_key[(s.rule, s.line + 1)] = s
    for f in uniq:
        s = by_key.get((f.rule, f.line))
        if s is not None and s.reason:
            s.used = True
            report.suppressed.append(f)
        else:
            report.findings.append(f)
            if s is not None:
                s.used = True   # it matched; the missing reason is the bug
    for s in sups:
        if not s.reason:
            report.findings.append(Finding(
                "suppression-hygiene", rel, s.line,
                f"allow[{s.rule}] without reason=...: suppressions must "
                f"say why the site is exempt"))
        elif not s.used:
            report.findings.append(Finding(
                "unused-suppression", rel, s.line,
                f"allow[{s.rule}] matches no finding on line {s.line} or "
                f"{s.line + 1}; delete the stale exemption"))
    report.suppressions = sups
    return report


def repo_root() -> pathlib.Path:
    """The repo root, derived from this file (src/repro/analysis/...)."""
    return pathlib.Path(__file__).resolve().parents[3]


def iter_target_files(root: pathlib.Path) -> List[pathlib.Path]:
    targets: List[pathlib.Path] = []
    src = root / SCAN_SRC
    if src.is_dir():
        targets.extend(p for p in sorted(src.rglob("*.py"))
                       if "__pycache__" not in p.parts)
    cores = root / SCAN_CORES
    if cores.is_dir():
        targets.extend(sorted(cores.rglob("__init__.py")))
    return targets


def run_analysis(root: Optional[pathlib.Path] = None,
                 rules: Optional[Sequence[Rule]] = None) -> Report:
    """Lint the whole repo; returns the merged report."""
    root = root or repo_root()
    rules = list(rules) if rules is not None else default_rules()
    merged = Report()
    for path in iter_target_files(root):
        rel = path.relative_to(root).as_posix()
        rep = analyze_text(rel, path.read_text(encoding="utf-8"), rules)
        merged.findings.extend(rep.findings)
        merged.suppressed.extend(rep.suppressed)
        merged.suppressions.extend(rep.suppressions)
        merged.files_scanned += 1
    return merged


# ---------------------------------------------------------------------------
# Baseline: the committed accepted state (subset-only gate)
# ---------------------------------------------------------------------------

BASELINE_NAME = ".repro-analysis-baseline.json"
_BASELINE_VERSION = 1


def baseline_from_report(report: Report) -> Dict:
    return {
        "version": _BASELINE_VERSION,
        "findings": sorted(
            [{"path": f.path, "rule": f.rule} for f in report.findings],
            key=lambda d: (d["path"], d["rule"])),
        "suppressions": sorted(
            [{"path": s.path, "rule": s.rule, "reason": s.reason}
             for s in report.suppressions],
            key=lambda d: (d["path"], d["rule"], d["reason"])),
    }


def _counts(items: Iterable[Tuple[str, str]]) -> Dict[Tuple[str, str], int]:
    out: Dict[Tuple[str, str], int] = {}
    for k in items:
        out[k] = out.get(k, 0) + 1
    return out


def check_baseline(report: Report, baseline: Dict
                   ) -> Tuple[List[str], List[str]]:
    """Compare a report against the committed baseline.

    Returns (errors, warnings).  Errors — new findings or new
    suppressions beyond the baseline inventory — must fail CI; warnings
    flag baseline entries the tree no longer needs (shrink the file).
    """
    errors: List[str] = []
    warnings: List[str] = []
    base_f = _counts((d["path"], d["rule"])
                     for d in baseline.get("findings", []))
    cur_f = _counts(f.ident() for f in report.findings)
    for key, n in sorted(cur_f.items()):
        allowed = base_f.get(key, 0)
        if n > allowed:
            errors.append(
                f"{key[0]}: {n - allowed} new [{key[1]}] finding(s) not in "
                f"the baseline — fix them or suppress with a reason")
    base_s = _counts((d["path"], d["rule"])
                     for d in baseline.get("suppressions", []))
    cur_s = _counts((s.path, s.rule) for s in report.suppressions)
    for key, n in sorted(cur_s.items()):
        allowed = base_s.get(key, 0)
        if n > allowed:
            errors.append(
                f"{key[0]}: {n - allowed} new allow[{key[1]}] "
                f"suppression(s) beyond the baseline inventory — update "
                f"{BASELINE_NAME} in the same PR so the growth is explicit")
    for key, n in sorted(base_f.items()):
        if cur_f.get(key, 0) < n:
            warnings.append(
                f"{key[0]}: baseline lists {n} [{key[1]}] finding(s) but "
                f"the tree has {cur_f.get(key, 0)} — shrink {BASELINE_NAME}")
    for key, n in sorted(base_s.items()):
        if cur_s.get(key, 0) < n:
            warnings.append(
                f"{key[0]}: baseline lists {n} allow[{key[1]}] but the "
                f"tree has {cur_s.get(key, 0)} — shrink {BASELINE_NAME}")
    return errors, warnings


# ---------------------------------------------------------------------------
# Output formatting
# ---------------------------------------------------------------------------

def format_human(report: Report, errors: Sequence[str] = (),
                 warnings: Sequence[str] = ()) -> str:
    out: List[str] = []
    for f in sorted(report.findings,
                    key=lambda f: (f.path, f.line, f.rule)):
        out.append(f"{f.path}:{f.line}: [{f.rule}] {f.message}")
    for e in errors:
        out.append(f"BASELINE ERROR: {e}")
    for w in warnings:
        out.append(f"baseline warning: {w}")
    n_sup = len(report.suppressed)
    out.append(
        f"repro.analysis: {report.files_scanned} files, "
        f"{len(report.findings)} finding(s), {n_sup} suppressed "
        f"(all with reasons)" if not report.findings else
        f"repro.analysis: {report.files_scanned} files, "
        f"{len(report.findings)} finding(s), {n_sup} suppressed")
    return "\n".join(out)
