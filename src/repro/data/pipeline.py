"""Data pipeline: deterministic, resumable synthetic LM token stream with
chaotic-PRNG-driven shuffling (the paper's oscillator feeding the trainer).

Determinism + resumability: batch ``i`` is a pure function of (seed, i), so
restarting from a checkpoint at step N just resumes the iterator at N — the
fault-tolerance path needs no data-state checkpointing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticLMDataset:
    """Markov-chain token stream — enough structure that loss decreases."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    use_chaotic_shuffle: bool = False
    n_docs: int = 512

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse bigram transition table: each token has 8 likely successors
        self.successors = rng.integers(
            0, self.vocab_size, size=(self.vocab_size, 8), dtype=np.int32)
        if self.use_chaotic_shuffle:
            from repro.prng import default_stream
            self._stream = default_stream(n_streams=256, seed=self.seed)
        else:
            self._stream = None

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=b)
        choice = rng.integers(0, 8, size=(b, s))
        mix = rng.random((b, s)) < 0.1    # 10% noise tokens
        noise = rng.integers(0, self.vocab_size, size=(b, s), dtype=np.int32)
        for t in range(s):
            nxt = self.successors[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(mix[:, t], noise[:, t], nxt)
        if self._stream is not None:
            perm = np.asarray(self._stream.permutation(b))
            toks = toks[perm]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def make_dataset(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 chaotic_shuffle: bool = False) -> SyntheticLMDataset:
    return SyntheticLMDataset(
        vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=seed,
        use_chaotic_shuffle=chaotic_shuffle)
