"""Atomic file publication: the write-tmp-then-``os.replace`` discipline.

Readers of a committed artifact (weight registry entries, generated-core
sources, benchmark/report JSON) must never observe a torn file — the
serving stack learned this with the weight registry
(``repro.prng.stream.trained_oscillator``), which publishes its npz via a
tmp file + ``os.replace``.  This module is the shared helper for every
other writer, and the crash-safety rule of ``repro.analysis`` statically
enforces that committed-artifact writes go through this pattern (a plain
``open(path, "w")`` or ``write_text`` on a non-tmp path is a finding).

POSIX ``os.replace`` within one directory is atomic, so the tmp file is
created next to its destination (same filesystem).  A PID suffix keeps
concurrent writers from clobbering each other's tmp files; last replace
wins, and every reader sees one complete version or the other.
"""
from __future__ import annotations

import os
import pathlib


def atomic_write_text(path: str | os.PathLike, text: str, *,
                      encoding: str = "utf-8") -> pathlib.Path:
    """Write ``text`` to ``path`` atomically (tmp sibling + ``os.replace``).

    Parent directories are created if missing.  Returns the final path.
    """
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(f".{path.name}.tmp{os.getpid()}")
    with open(tmp, "w", encoding=encoding) as f:
        f.write(text)
    os.replace(tmp, path)
    return path


def atomic_write_bytes(path: str | os.PathLike, data: bytes) -> pathlib.Path:
    """Binary sibling of :func:`atomic_write_text`."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(f".{path.name}.tmp{os.getpid()}")
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)
    return path
