"""Transformer assembly: init / forward / loss / decode for every assigned
architecture family (dense, GQA, MoE, RWKV-6, Mamba-2, Zamba2-hybrid,
VLM/audio backbones).

Structure:
  - per-layer params are stacked on a leading L axis and the layer loop is a
    single ``lax.scan`` (tractable HLO for 80-layer models, natural remat
    boundary);
  - ``jax.checkpoint`` wraps the block body when cfg.remat;
  - Zamba2 hybrid: mamba2 backbone scanned; ONE shared attention+MLP block
    (unstacked params, closure-captured) applied every ``hybrid_shared_every``
    layers via ``lax.cond`` — weight reuse exactly as the paper describes;
  - decode threads per-layer caches through the same scan;
  - optional ``shard_fn(tag, x)`` lets the distribution layer inject
    ``with_sharding_constraint`` without the model knowing about meshes.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (embed_apply, embed_init, mlp_apply, mlp_init,
                                 rms_norm, unembed_apply)

Array = jax.Array
PyTree = Any
ShardFn = Callable[[str, Array], Array]

_IDENTITY: ShardFn = lambda tag, x: x


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _attn_dims(cfg: ModelConfig) -> attn_mod.AttnDims:
    return attn_mod.AttnDims(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias,
        qk_norm=cfg.qk_norm, window=cfg.attn_window, rope_theta=cfg.rope_theta)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(cfg: ModelConfig, key) -> Dict[str, PyTree]:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p: Dict[str, PyTree] = {"norm1": jnp.zeros((cfg.d_model,), dt)}
    if cfg.block_type == "attn":
        p["attn"] = attn_mod.attn_init(ks[0], cfg.d_model, _attn_dims(cfg), dt)
    elif cfg.block_type == "rwkv6":
        p["rwkv"] = rwkv_mod.rwkv_init(ks[0], cfg.d_model, cfg.ssm_head_dim, dt)
    elif cfg.block_type == "mamba2":
        p["mamba"] = ssm_mod.mamba_init(ks[0], cfg.d_model, cfg.ssm_state,
                                        cfg.ssm_head_dim, cfg.conv_width, dt)
    else:
        raise ValueError(cfg.block_type)

    if cfg.block_type != "mamba2":   # mamba2 blocks carry no separate FFN
        p["norm2"] = jnp.zeros((cfg.d_model,), dt)
        if cfg.is_moe:
            p["moe"] = moe_mod.moe_init(ks[1], cfg.d_model, cfg.d_ff,
                                        cfg.n_experts, cfg.glu, dt)
        elif cfg.block_type == "rwkv6":
            p["ffn"] = rwkv_mod.rwkv_channel_mix_init(ks[1], cfg.d_model, cfg.d_ff, dt)
        else:
            p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.glu, dt)
    return p


def init(cfg: ModelConfig, key) -> Dict[str, PyTree]:
    dt = _dtype(cfg)
    k_embed, k_blocks, k_shared, k_final = jax.random.split(key, 4)
    params: Dict[str, PyTree] = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model,
                            cfg.tie_embeddings, dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if cfg.scan_layers:
        block_keys = jax.random.split(k_blocks, cfg.n_layers)
        params["blocks"] = jax.vmap(lambda k: _block_init(cfg, k))(block_keys)
    else:
        params["blocks"] = [
            _block_init(cfg, k) for k in jax.random.split(k_blocks, cfg.n_layers)]

    if cfg.hybrid_shared_every:
        ks = jax.random.split(k_shared, 3)
        params["shared"] = {
            "norm1": jnp.zeros((cfg.d_model,), dt),
            "attn": attn_mod.attn_init(ks[0], cfg.d_model, _attn_dims(cfg), dt),
            "norm2": jnp.zeros((cfg.d_model,), dt),
            "ffn": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.glu, dt),
        }
    return params


def n_shared_invocations(cfg: ModelConfig) -> int:
    if not cfg.hybrid_shared_every:
        return 0
    return (cfg.n_layers + cfg.hybrid_shared_every - 1) // cfg.hybrid_shared_every


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _shared_block_apply(cfg: ModelConfig, sp, x: Array, shard: ShardFn,
                        return_kv: bool = False):
    h = rms_norm(x, sp["norm1"], cfg.norm_eps)
    if return_kv:
        y, kv = attn_mod.attn_apply_with_kv(sp["attn"], h, _attn_dims(cfg))
    else:
        y = attn_mod.attn_apply(sp["attn"], h, _attn_dims(cfg))
    x = x + shard("residual", y)
    h = rms_norm(x, sp["norm2"], cfg.norm_eps)
    x = x + shard("residual", mlp_apply(sp["ffn"], h, cfg.activation, cfg.glu))
    if return_kv:
        return x, kv
    return x


def _zero_kv_like(cfg: ModelConfig, x: Array):
    dims = _attn_dims(cfg)
    b, s, _ = x.shape
    z = jnp.zeros((b, s, dims.n_kv_heads, dims.head_dim), x.dtype)
    return {"k": z, "v": z}


def _block_apply(cfg: ModelConfig, bp, x: Array, layer_idx: Array,
                 shared_params, shard: ShardFn,
                 return_state: bool = False) -> Tuple[Array, Dict[str, Array]]:
    aux = {"lb_loss": jnp.zeros((), jnp.float32),
           "z_loss": jnp.zeros((), jnp.float32)}
    state = {}
    h = rms_norm(x, bp["norm1"], cfg.norm_eps)
    h = shard("activation", h)
    # return_state layouts mirror init_decode_state's per-layer cache keys,
    # so prefill output is directly usable as the decode state.
    if cfg.block_type == "attn":
        if return_state:
            y, kv = attn_mod.attn_apply_with_kv(bp["attn"], h, _attn_dims(cfg))
            state.update(kv)                      # {"k", "v"}
        else:
            y = attn_mod.attn_apply(bp["attn"], h, _attn_dims(cfg))
    elif cfg.block_type == "rwkv6":
        if return_state:
            y, st = rwkv_mod.rwkv_apply_with_state(bp["rwkv"], h, cfg.ssm_head_dim)
            state.update(st)                      # {"wkv", "shift"}
        else:
            y = rwkv_mod.rwkv_apply(bp["rwkv"], h, cfg.ssm_head_dim)
    else:
        if return_state:
            y, st = ssm_mod.mamba_apply_with_state(
                bp["mamba"], h, ssm_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim)
            state.update(st)                      # {"ssm", "conv"}
        else:
            y = ssm_mod.mamba_apply(bp["mamba"], h, ssm_state=cfg.ssm_state,
                                    head_dim=cfg.ssm_head_dim)
    x = x + shard("residual", y)

    if "norm2" in bp:
        h = rms_norm(x, bp["norm2"], cfg.norm_eps)
        h = shard("activation", h)
        if cfg.is_moe:
            y, moe_aux = moe_mod.moe_apply(
                bp["moe"], h, top_k=cfg.n_experts_per_tok,
                activation=cfg.activation, glu=cfg.glu,
                capacity_factor=cfg.capacity_factor,
                group_size=cfg.moe_group_size,
                dispatch_dtype=jnp.dtype(cfg.moe_dispatch_dtype))
            aux["lb_loss"] += moe_aux["lb_loss"]
            aux["z_loss"] += moe_aux["z_loss"]
        elif cfg.block_type == "rwkv6":
            y = rwkv_mod.rwkv_channel_mix(bp["ffn"], h)
            if return_state:
                state["ffn_shift"] = h[:, -1, :].astype(jnp.float32)
        else:
            y = mlp_apply(bp["ffn"], h, cfg.activation, cfg.glu)
        x = x + shard("residual", y)

    if cfg.hybrid_shared_every and shared_params is not None:
        every = cfg.hybrid_shared_every
        if return_state:
            x, shared_kv = jax.lax.cond(
                (layer_idx % every) == (every - 1),
                lambda v: _shared_block_apply(cfg, shared_params, v, shard,
                                              return_kv=True),
                lambda v: (v, _zero_kv_like(cfg, v)), x)
            state["shared_kv"] = shared_kv
        else:
            x = jax.lax.cond(
                (layer_idx % every) == (every - 1),
                lambda v: _shared_block_apply(cfg, shared_params, v, shard),
                lambda v: v, x)
    if return_state:
        return x, (aux, state)
    return x, aux


def forward(cfg: ModelConfig, params, tokens: Optional[Array] = None, *,
            embeds: Optional[Array] = None, shard_fn: ShardFn = _IDENTITY,
            last_only: bool = False, return_state: bool = False):
    """Full-sequence forward.

    Returns (logits, aux) or (logits, aux, layer_states) when
    ``return_state`` (prefill: per-layer KV / recurrent states stacked on L).
    ``last_only`` computes logits for the final position only — the serving
    prefill contract (avoids a (B, S, V) logits buffer at 32 k).
    """
    if embeds is not None:
        x = embeds.astype(_dtype(cfg))       # modality-stub path (vlm/audio)
    else:
        x = embed_apply(params["embed"], tokens, cfg.embed_scale)
    x = shard_fn("activation", x)
    shared = params.get("shared")

    def scan_body(x, inp):
        bp, idx = inp
        return _block_apply(cfg, bp, x, idx, shared, shard_fn,
                            return_state=return_state)

    if cfg.remat and not return_state:
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.scan_layers:
        x, ys = jax.lax.scan(
            scan_body, x, (params["blocks"], jnp.arange(cfg.n_layers)))
        if return_state:
            auxs, states = ys
        else:
            auxs, states = ys, None
        aux = jax.tree.map(jnp.sum, auxs)
    else:
        aux = {"lb_loss": jnp.zeros((), jnp.float32),
               "z_loss": jnp.zeros((), jnp.float32)}
        states_list = []
        for i, bp in enumerate(params["blocks"]):
            x, y = scan_body(x, (bp, jnp.asarray(i)))
            if return_state:
                a, st = y
                states_list.append(st)
            else:
                a = y
            aux = jax.tree.map(jnp.add, aux, a)
        states = (jax.tree.map(lambda *ls: jnp.stack(ls), *states_list)
                  if return_state else None)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:, :]
    logits = unembed_apply(params["embed"], x)
    logits = shard_fn("logits", logits)
    if return_state:
        return logits, aux, states
    return logits, aux


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, Array], *,
            shard_fn: ShardFn = _IDENTITY,
            lb_coef: float = 0.01, z_coef: float = 1e-3):
    """Next-token cross-entropy (+ MoE aux).  batch: tokens/embeds + labels."""
    logits, aux = forward(cfg, params, batch.get("tokens"),
                          embeds=batch.get("embeds"), shard_fn=shard_fn)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = ce
    if cfg.is_moe:
        total = total + lb_coef * aux["lb_loss"] + z_coef * aux["z_loss"]
    metrics = {"ce": ce, "lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"]}
    return total, metrics


# ---------------------------------------------------------------------------
# Decode (single-token, cached)
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, PyTree]:
    """Per-layer caches stacked on L (matching the scanned block params)."""
    dt = _dtype(cfg)
    dims = _attn_dims(cfg)
    L = cfg.n_layers

    def stack(make_one):
        one = make_one()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), one)

    state: Dict[str, PyTree] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.block_type == "attn":
        state["layers"] = stack(lambda: attn_mod.init_kv_cache(batch, max_len, dims, dt))
    elif cfg.block_type == "rwkv6":
        state["layers"] = stack(lambda: rwkv_mod.rwkv_init_state(
            batch, cfg.d_model, cfg.ssm_head_dim))
    else:
        state["layers"] = stack(lambda: ssm_mod.mamba_init_state(
            batch, cfg.d_model, cfg.ssm_state, cfg.ssm_head_dim, cfg.conv_width))
    if cfg.hybrid_shared_every:
        n_inv = n_shared_invocations(cfg)
        one = attn_mod.init_kv_cache(batch, max_len, dims, dt)
        state["shared_layers"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_inv,) + a.shape), one)
    return state


def decode_step(cfg: ModelConfig, params, state, tokens: Array, *,
                shard_fn: ShardFn = _IDENTITY):
    """tokens: (B, 1) -> (logits (B,1,V), new state)."""
    pos = state["pos"]
    x = embed_apply(params["embed"], tokens, cfg.embed_scale)
    x = shard_fn("activation", x)
    dims = _attn_dims(cfg)
    shared = params.get("shared")
    every = cfg.hybrid_shared_every

    def shared_apply(carry_x, shared_cache, inv_idx):
        h = rms_norm(carry_x, shared["norm1"], cfg.norm_eps)
        cache_i = jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(
            a, inv_idx, 0, keepdims=False), shared_cache)
        y, new_cache_i = attn_mod.attn_decode(shared["attn"], h, cache_i, pos, dims)
        carry_x = carry_x + y
        h = rms_norm(carry_x, shared["norm2"], cfg.norm_eps)
        carry_x = carry_x + mlp_apply(shared["ffn"], h, cfg.activation, cfg.glu)
        shared_cache = jax.tree.map(
            lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new.astype(full.dtype), inv_idx, 0),
            shared_cache, new_cache_i)
        return carry_x, shared_cache

    def scan_body(carry, inp):
        x, shared_cache = carry
        bp, layer_cache, idx = inp
        h = rms_norm(x, bp["norm1"], cfg.norm_eps)
        if cfg.block_type == "attn":
            y, new_cache = attn_mod.attn_decode(bp["attn"], h, layer_cache, pos, dims)
        elif cfg.block_type == "rwkv6":
            y, new_cache = rwkv_mod.rwkv_decode(
                bp["rwkv"], h,
                {"wkv": layer_cache["wkv"], "shift": layer_cache["shift"]},
                cfg.ssm_head_dim)
        else:
            y, new_cache = ssm_mod.mamba_decode(bp["mamba"], h, layer_cache,
                                                ssm_state=cfg.ssm_state,
                                                head_dim=cfg.ssm_head_dim)
        x = x + y
        if "norm2" in bp:
            h = rms_norm(x, bp["norm2"], cfg.norm_eps)
            if cfg.is_moe:
                # decode: capacity = top_k * batch (cf = E) => never drops,
                # exact top-k mixture (serving must not lose tokens)
                y, _ = moe_mod.moe_apply(
                    bp["moe"], h, top_k=cfg.n_experts_per_tok,
                    activation=cfg.activation, glu=cfg.glu,
                    capacity_factor=float(cfg.n_experts),
                    group_size=min(cfg.moe_group_size, h.shape[0]),
                    dispatch_dtype=jnp.dtype(cfg.moe_dispatch_dtype))
            elif cfg.block_type == "rwkv6":
                y = rwkv_mod.rwkv_channel_mix(
                    bp["ffn"], h, x_prev=layer_cache["ffn_shift"].astype(h.dtype))
                new_cache["ffn_shift"] = h[:, 0, :].astype(jnp.float32)
            else:
                y = mlp_apply(bp["ffn"], h, cfg.activation, cfg.glu)
            x = x + y
        if every and shared is not None:
            x, shared_cache = jax.lax.cond(
                (idx % every) == (every - 1),
                lambda args: shared_apply(args[0], args[1], idx // every),
                lambda args: args,
                (x, shared_cache))
        return (x, shared_cache), new_cache

    shared_cache = state.get("shared_layers")
    (x, shared_cache), new_layer_caches = jax.lax.scan(
        scan_body, (x, shared_cache),
        (params["blocks"], state["layers"], jnp.arange(cfg.n_layers)))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed_apply(params["embed"], x)
    new_state = {"pos": pos + 1, "layers": new_layer_caches}
    if shared_cache is not None:
        new_state["shared_layers"] = shared_cache
    return logits, new_state
