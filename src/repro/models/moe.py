"""Mixture-of-Experts layer: top-k router + GShard-style capacity dispatch.

Group-wise dispatch keeps the one-hot combine tensors bounded: tokens are
split into groups of ``group_size``; each group dispatches to per-group
expert capacity C = ceil(top_k * group_size * capacity_factor / E).  The
dispatch/combine einsums lower onto the MXU, and when the expert dim is
sharded over a mesh axis GSPMD inserts the canonical all-to-all pair.

Aux losses: load-balancing (Switch) + router z-loss, returned to the caller.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import activation_fn, dense_init

Array = jax.Array


def moe_init(key, d_model: int, d_ff: int, n_experts: int, glu: bool, dtype) -> Dict[str, Array]:
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], d_model, (d_model, n_experts), jnp.float32),
        "wi": dense_init(ks[1], d_model, (n_experts, d_model, d_ff), dtype),
        "wo": dense_init(ks[2], d_ff, (n_experts, d_ff, d_model), dtype),
    }
    if glu:
        p["wg"] = dense_init(ks[3], d_model, (n_experts, d_model, d_ff), dtype)
    return p


def moe_apply(p: Dict[str, Array], x: Array, *, top_k: int, activation: str,
              glu: bool, capacity_factor: float = 1.25,
              group_size: int = 1024,
              dispatch_dtype=jnp.float32) -> Tuple[Array, Dict[str, Array]]:
    """x: (B, S, D) -> (B, S, D), aux metrics dict.

    ``dispatch_dtype``: numeric type of the dispatch/combine einsums.  The
    one-hot dispatch tensors are exact in bf16 (0/1 and top-k gate values),
    so bf16 dispatch quarters the f32-MXU cost of the dispatch matmuls at
    <1e-2 output perturbation (validated in tests).
    """
    b, s, d = x.shape
    e = p["wi"].shape[0]
    n_tok = b * s
    g_sz = min(group_size, n_tok)
    assert n_tok % g_sz == 0, f"{n_tok} tokens not divisible by group {g_sz}"
    n_grp = n_tok // g_sz
    cap = max(int(top_k * g_sz * capacity_factor / e), 1)

    xt = x.reshape(n_grp, g_sz, d)
    logits = (xt.astype(jnp.float32) @ p["router"])          # (G, t, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # --- top-k gating with renormalization (Mixtral-style) ---
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (G, t, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- capacity assignment: position of each (token, choice) in its expert queue
    sel_onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)        # (G,t,k,E)
    # priority: iterate choices first (all 1st choices ranked before 2nd)
    sel_flat = sel_onehot.transpose(0, 2, 1, 3).reshape(n_grp, top_k * g_sz, e)
    pos_in_expert = jnp.cumsum(sel_flat, axis=1) - sel_flat            # (G,k*t,E)
    pos_in_expert = pos_in_expert.reshape(n_grp, top_k, g_sz, e).transpose(0, 2, 1, 3)
    within_cap = pos_in_expert < cap                                    # (G,t,k,E)
    kept = (sel_onehot * within_cap).sum(-1)                           # (G,t,k)

    # --- dispatch/combine tensors ---
    dd = dispatch_dtype
    cap_onehot = jax.nn.one_hot(
        jnp.clip(pos_in_expert, 0, cap - 1).astype(jnp.int32), cap, dtype=dd)
    dispatch = jnp.einsum("gtke,gtkec->gtec",
                          (sel_onehot * within_cap).astype(dd), cap_onehot)
    combine = jnp.einsum("gtk,gtke,gtkec->gtec",
                         (gate_vals * kept).astype(dd), sel_onehot.astype(dd),
                         cap_onehot)

    xe = jnp.einsum("gtd,gtec->gecd", xt.astype(dd), dispatch).astype(x.dtype)

    # --- expert FFN: (G,E,C,D) x (E,D,F) ---
    h = jnp.einsum("gecd,edf->gecf", xe, p["wi"])
    if glu:
        h = activation_fn(activation)(jnp.einsum("gecd,edf->gecf", xe, p["wg"])) * h
    else:
        h = activation_fn(activation)(h)
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])

    out = jnp.einsum("gecd,gtec->gtd", ye.astype(dd), combine)
    out = out.reshape(b, s, d).astype(x.dtype)

    # --- aux losses ---
    me = probs.mean(axis=(0, 1))                     # mean router prob per expert
    ce = sel_onehot.sum(2).mean(axis=(0, 1))         # fraction routed per expert
    lb_loss = e * jnp.sum(me * ce) / top_k
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - kept.mean()
    return out, {"lb_loss": lb_loss, "z_loss": z_loss, "dropped_frac": dropped}
