"""GQA attention: full-causal, sliding-window, chunked-flash and decode paths.

Design notes (TPU):
  - training / short prefill uses the plain (B, H, S, S) score path;
  - long prefill (S > FLASH_THRESHOLD) switches to a double-``lax.scan``
    online-softmax formulation (flash structure) so 32 k x 32 k score
    matrices are never materialized — O(S·blk) live memory;
  - decode attends one query against a (ring-buffered, for SWA) KV cache;
  - GQA is expressed by reshaping q to (B, S, KV, G, hd) and contracting
    k/v per KV head — XLA maps this onto the MXU without materializing
    repeated KV heads.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

Array = jax.Array

FLASH_THRESHOLD = 8192   # seq len beyond which the scan-flash path is used
FLASH_BLOCK_Q = 1024
FLASH_BLOCK_KV = 1024
MASK_VALUE = -1e30


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None
    rope_theta: float = 10_000.0


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, n, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    sin = jnp.sin(angles)[..., None, :]                # (..., S, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def attn_init(key, d_model: int, dims: AttnDims, dtype) -> Dict[str, Array]:
    ks = jax.random.split(key, 6)
    h, kv, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    p = {
        "wq": dense_init(ks[0], d_model, (d_model, h * hd), dtype),
        "wk": dense_init(ks[1], d_model, (d_model, kv * hd), dtype),
        "wv": dense_init(ks[2], d_model, (d_model, kv * hd), dtype),
        "wo": dense_init(ks[3], h * hd, (h * hd, d_model), dtype),
    }
    if dims.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if dims.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p, x: Array, dims: AttnDims, positions: Array):
    b, s, _ = x.shape
    h, kv, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if dims.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if dims.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, dims.rope_theta)
    k = apply_rope(k, positions, dims.rope_theta)
    return q, k, v


def _gqa_scores(q: Array, k: Array) -> Array:
    """q: (B,Sq,KV,G,hd), k: (B,Skv,KV,hd) -> (B,KV,G,Sq,Skv)."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k)


def _gqa_out(probs: Array, v: Array) -> Array:
    """probs: (B,KV,G,Sq,Skv), v: (B,Skv,KV,hd) -> (B,Sq,KV,G,hd)."""
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def _causal_mask(sq: int, skv: int, q_off: Array, window: Optional[int]) -> Array:
    qpos = q_off + jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def _plain_attention(q, k, v, dims: AttnDims) -> Array:
    b, s, h, hd = q.shape
    kv = dims.n_kv_heads
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = _gqa_scores(qg, k).astype(jnp.float32) / (hd ** 0.5)
    mask = _causal_mask(s, s, jnp.zeros((), jnp.int32), dims.window)
    scores = jnp.where(mask, scores, MASK_VALUE)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = _gqa_out(probs, v)
    return out.reshape(b, s, h, hd)


def _flash_attention(q, k, v, dims: AttnDims) -> Array:
    """Double-scan online-softmax attention (no S×S materialization)."""
    b, s, h, hd = q.shape
    kv = dims.n_kv_heads
    g = h // kv
    bq, bkv = FLASH_BLOCK_Q, FLASH_BLOCK_KV
    nq, nkv = s // bq, s // bkv
    assert s % bq == 0 and s % bkv == 0, f"seq {s} not divisible by flash blocks"

    qg = q.reshape(b, nq, bq, kv, g, hd).transpose(1, 0, 3, 4, 2, 5)  # (nq,b,kv,g,bq,hd)
    kb = k.reshape(b, nkv, bkv, kv, hd).transpose(1, 0, 3, 2, 4)      # (nkv,b,kv,bkv,hd)
    vb = v.reshape(b, nkv, bkv, kv, hd).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / (hd ** 0.5)

    def q_block(carry, qi_and_q):
        qi, qblk = qi_and_q   # qblk: (b,kv,g,bq,hd)

        def kv_block(acc, ki_and_kv):
            ki, kblk, vblk = ki_and_kv
            m_prev, l_prev, o_prev = acc
            s_blk = jnp.einsum("bkgqh,bksh->bkgqs", qblk, kblk).astype(jnp.float32) * scale
            q_off = qi * bq
            k_off = ki * bkv
            qpos = q_off + jnp.arange(bq)[:, None]
            kpos = k_off + jnp.arange(bkv)[None, :]
            mask = kpos <= qpos
            if dims.window is not None:
                mask &= kpos > qpos - dims.window
            s_blk = jnp.where(mask, s_blk, MASK_VALUE)
            m_new = jnp.maximum(m_prev, s_blk.max(axis=-1))
            p = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(axis=-1)
            o_new = o_prev * corr[..., None] + jnp.einsum(
                "bkgqs,bksh->bkgqh", p.astype(qblk.dtype), vblk).astype(jnp.float32)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((b, kv, g, bq), MASK_VALUE, jnp.float32)
        l0 = jnp.zeros((b, kv, g, bq), jnp.float32)
        o0 = jnp.zeros((b, kv, g, bq, hd), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            kv_block, (m0, l0, o0),
            (jnp.arange(nkv), kb, vb))
        o = o / jnp.maximum(l[..., None], 1e-30)
        return carry, o.astype(q.dtype)

    _, blocks = jax.lax.scan(q_block, None, (jnp.arange(nq), qg))
    # blocks: (nq, b, kv, g, bq, hd) -> (b, s, h, hd)
    out = blocks.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, h, hd)
    return out


def attn_apply(p, x: Array, dims: AttnDims, positions: Optional[Array] = None) -> Array:
    """Full-sequence (train / prefill) attention."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, dims, positions)
    if s > FLASH_THRESHOLD:
        out = _flash_attention(q, k, v, dims)
    else:
        out = _plain_attention(q, k, v, dims)
    return out.reshape(b, s, dims.n_heads * dims.head_dim) @ p["wo"]


def attn_apply_with_kv(p, x: Array, dims: AttnDims,
                       positions: Optional[Array] = None):
    """Prefill: also return the rotated k/v for KV-cache production.  For
    sliding-window attention only the last ``window`` positions are kept
    (the ring cache contents after a full prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, dims, positions)
    if s > FLASH_THRESHOLD:
        out = _flash_attention(q, k, v, dims)
    else:
        out = _plain_attention(q, k, v, dims)
    k_keep, v_keep = k, v
    if dims.window is not None and s > dims.window:
        k_keep = k[:, -dims.window:]
        v_keep = v[:, -dims.window:]
    return (out.reshape(b, s, dims.n_heads * dims.head_dim) @ p["wo"],
            {"k": k_keep, "v": v_keep})


# ---------------------------------------------------------------------------
# Decode with KV cache (full or ring/SWA)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, dims: AttnDims, dtype) -> Dict[str, Array]:
    cache_len = min(max_len, dims.window) if dims.window else max_len
    return {
        "k": jnp.zeros((batch, cache_len, dims.n_kv_heads, dims.head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, dims.n_kv_heads, dims.head_dim), dtype),
    }


def attn_decode(p, x: Array, cache: Dict[str, Array], pos: Array,
                dims: AttnDims) -> Tuple[Array, Dict[str, Array]]:
    """One-token decode. x: (B, 1, D); pos: scalar int32 (current index)."""
    b = x.shape[0]
    h, kv, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, dims, positions)

    cache_len = cache["k"].shape[1]
    slot = pos % cache_len if dims.window else pos
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))

    g = h // kv
    qg = q.reshape(b, 1, kv, g, hd)
    scores = _gqa_scores(qg, k_cache).astype(jnp.float32) / (hd ** 0.5)  # (b,kv,g,1,C)

    idx = jnp.arange(cache_len)
    if dims.window:
        # ring buffer: valid entries are the last min(pos+1, window) writes
        age = (slot - idx) % cache_len
        valid = age < jnp.minimum(pos + 1, cache_len)
    else:
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, MASK_VALUE)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v_cache).reshape(b, 1, h * hd)
    return out @ p["wo"], {"k": k_cache, "v": v_cache}
