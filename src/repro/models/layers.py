"""Shared neural building blocks (pure functional, dict-pytree params)."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def truncated_normal_init(key, shape, scale: float, dtype) -> Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in: int, shape, dtype) -> Array:
    return truncated_normal_init(key, shape, (1.0 / d_in) ** 0.5, dtype)


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: Array, scale: Array, bias: Optional[Array], eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x * (1.0 + scale.astype(jnp.float32))
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dtype)


def activation_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# ---------------------------------------------------------------------------
# MLP (optionally gated: SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, glu: bool, dtype) -> Dict[str, Array]:
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], d_model, (d_model, d_ff), dtype),
        "wo": dense_init(ks[1], d_ff, (d_ff, d_model), dtype),
    }
    if glu:
        p["wg"] = dense_init(ks[2], d_model, (d_model, d_ff), dtype)
    return p


def mlp_apply(p: Dict[str, Array], x: Array, activation: str, glu: bool) -> Array:
    act = activation_fn(activation)
    h = x @ p["wi"]
    if glu:
        h = act(x @ p["wg"]) * h
    else:
        h = act(h)
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, tie: bool, dtype) -> Dict[str, Array]:
    ks = jax.random.split(key, 2)
    p = {"embedding": truncated_normal_init(ks[0], (vocab, d_model), 1.0, dtype)}
    if not tie:
        p["unembed"] = dense_init(ks[1], d_model, (d_model, vocab), dtype)
    return p


def embed_apply(p: Dict[str, Array], tokens: Array, scale: bool = False) -> Array:
    x = jnp.take(p["embedding"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(x.shape[-1] ** 0.5, x.dtype)
    return x


def unembed_apply(p: Dict[str, Array], x: Array) -> Array:
    if "unembed" in p:
        return x @ p["unembed"]
    return x @ p["embedding"].T.astype(x.dtype)
