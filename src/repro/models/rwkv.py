"""RWKV-6 "Finch": token-shift with data-dependent interpolation and the WKV
linear-attention recurrence with data-dependent per-channel decay
(arXiv:2404.05892), adapted for TPU.

Three execution paths over the same parameters:
  - ``wkv_recurrent``: exact per-step ``lax.scan`` (oracle; O(S) sequential)
  - ``wkv_chunked``:  chunk-parallel form — within a chunk the decay products
    are bounded (cumulative log-decays are monotone decreasing), so the
    intra-chunk part is two MXU matmuls; chunks are linked by a short scan.
    Default for training/prefill.
  - ``wkv_step``: single-token state update for decode.

State per head: s in R^{K x V} plus the token-shift buffer.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

Array = jax.Array

# per-step log-decay is clamped to [-DECAY_CLAMP, ~0); with chunk length
# CHUNK, intra-chunk exp() arguments are bounded by CHUNK*DECAY_CLAMP < 88
# (f32 exp overflow threshold).
CHUNK = 16
DECAY_CLAMP = 5.0


def rwkv_init(key, d_model: int, head_dim: int, dtype) -> Dict[str, Array]:
    ks = jax.random.split(key, 12)
    n_heads = d_model // head_dim
    lora = max(d_model // 16, 32)
    p = {
        # data-dependent token-shift mixers (r,k,v,w,g)
        "mix_base": (jax.random.uniform(ks[0], (5, d_model)) * 0.5).astype(dtype),
        "mix_lora_a": dense_init(ks[1], d_model, (d_model, lora), dtype),
        "mix_lora_b": dense_init(ks[2], lora, (5, lora, d_model), dtype),
        # projections
        "wr": dense_init(ks[3], d_model, (d_model, d_model), dtype),
        "wk": dense_init(ks[4], d_model, (d_model, d_model), dtype),
        "wv": dense_init(ks[5], d_model, (d_model, d_model), dtype),
        "wg": dense_init(ks[6], d_model, (d_model, d_model), dtype),
        "wo": dense_init(ks[7], d_model, (d_model, d_model), dtype),
        # data-dependent decay lora: w_t = exp(-exp(decay_base + lora(x)))
        "decay_base": (jax.random.normal(ks[8], (d_model,)) * 0.5 - 4.0).astype(jnp.float32),
        "decay_lora_a": dense_init(ks[9], d_model, (d_model, lora), dtype),
        "decay_lora_b": dense_init(ks[10], lora, (lora, d_model), dtype),
        # per-channel bonus for the current token
        "bonus": (jax.random.normal(ks[11], (n_heads, head_dim)) * 0.1).astype(jnp.float32),
        "ln_out": jnp.zeros((d_model,), dtype),
    }
    return p


def _token_shift(x: Array, x_prev: Array) -> Array:
    """Shift sequence right by one; x_prev fills position 0. (B,S,D)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p, x: Array, xs: Array):
    """RWKV6 data-dependent interpolation producing (r,k,v,w,g) inputs."""
    base = p["mix_base"]                               # (5, D)
    lora = jnp.tanh(x @ p["mix_lora_a"])               # (B,S,L)
    delta = jnp.einsum("bsl,mld->mbsd", lora, p["mix_lora_b"])  # (5,B,S,D)
    mix = jnp.clip(base[:, None, None, :] + delta, 0.0, 1.0)
    return x[None] + (xs - x)[None] * mix              # (5,B,S,D)


def _project(p, x: Array, head_dim: int):
    """Returns r,k,v,g: (B,S,H,hd); log_w: (B,S,H,hd) fp32 (clamped)."""
    b, s, d = x.shape
    h = d // head_dim
    xs = _token_shift(x, jnp.zeros((b, d), x.dtype))
    xr, xk, xv, xw, xg = _ddlerp(p, x, xs)
    r = (xr @ p["wr"]).reshape(b, s, h, head_dim)
    k = (xk @ p["wk"]).reshape(b, s, h, head_dim)
    v = (xv @ p["wv"]).reshape(b, s, h, head_dim)
    g = jax.nn.silu(xg @ p["wg"])
    dec = p["decay_base"] + (jnp.tanh(xw @ p["decay_lora_a"]) @ p["decay_lora_b"]).astype(jnp.float32)
    log_w = -jnp.exp(jnp.clip(dec, -10.0, jnp.log(DECAY_CLAMP)))   # in [-CLAMP, ~0)
    log_w = log_w.reshape(b, s, h, head_dim)
    return r, k, v, g, log_w


def wkv_recurrent(r, k, v, log_w, bonus, s0=None):
    """Exact recurrence. r/k/v/log_w: (B,S,H,K); bonus: (H,K).
    Returns out (B,S,H,K[v-dim]) and final state (B,H,K,V)."""
    b, s, h, kd = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, kd, kd), jnp.float32)

    def step(state, inp):
        rt, kt, vt, lwt = inp   # (B,H,K) each
        kv = kt[..., :, None] * vt[..., None, :]             # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         state + jnp.exp(bonus)[None, :, :, None] * kv)
        state = jnp.exp(lwt)[..., None] * state + kv
        return state, out

    xs = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          log_w.transpose(1, 0, 2, 3))
    state, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 0, 2, 3), state


def wkv_chunked(r, k, v, log_w, bonus, s0=None, chunk: int = CHUNK):
    """Chunk-parallel WKV.  Equivalent to wkv_recurrent (tested)."""
    b, s, h, kd = r.shape
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    n = s // chunk
    if s0 is None:
        s0 = jnp.zeros((b, h, kd, kd), jnp.float32)

    f32 = lambda x: x.astype(jnp.float32)
    # (n, B, H, L, K)
    rc = f32(r).reshape(b, n, chunk, h, kd).transpose(1, 0, 3, 2, 4)
    kc = f32(k).reshape(b, n, chunk, h, kd).transpose(1, 0, 3, 2, 4)
    vc = f32(v).reshape(b, n, chunk, h, kd).transpose(1, 0, 3, 2, 4)
    wc = log_w.reshape(b, n, chunk, h, kd).transpose(1, 0, 3, 2, 4)

    cum = jnp.cumsum(wc, axis=3)                 # S_t: inclusive cumsum per chunk
    cum_prev = cum - wc                          # S_{t-1} (exclusive)
    total = cum[:, :, :, -1:, :]                 # (n,B,H,1,K)

    # intra-chunk pairwise decay matrix via bounded factors:
    #   A[t,τ] = Σ_c r~[t,c]·k~[τ,c],  r~ = r·exp(S_{t-1}),  k~ = k·exp(-S_τ)
    # |S| ≤ chunk·DECAY_CLAMP < 88 keeps both exps finite in fp32.
    r_in = rc * jnp.exp(cum_prev)
    k_in = kc * jnp.exp(-cum)
    att = jnp.einsum("nbhtk,nbhsk->nbhts", r_in, k_in)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    att = jnp.where(mask, att, 0.0)
    # current-token bonus (diagonal)
    diag = jnp.einsum("nbhtk,nbhtk->nbht", rc * jnp.exp(bonus)[None, None, :, None, :], kc)
    intra = jnp.einsum("nbhts,nbhsv->nbhtv", att, vc) + diag[..., None] * vc

    # cross-chunk: contribution of carried state + state update per chunk
    k_out = kc * jnp.exp(total - cum)            # k scaled to chunk end

    def link(state, inp):
        r_in_c, k_out_c, v_c, total_c, intra_c = inp
        inter = jnp.einsum("bhtk,bhkv->bhtv", r_in_c, state)
        new_state = jnp.exp(total_c[:, :, 0, :])[..., None] * state \
            + jnp.einsum("bhtk,bhtv->bhkv", k_out_c, v_c)
        return new_state, intra_c + inter

    state, outs = jax.lax.scan(link, s0, (r_in, k_out, vc, total, intra))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, kd)
    return out, state


def wkv_step(r, k, v, log_w, bonus, state):
    """Decode: r/k/v/log_w (B,H,K); state (B,H,K,V)."""
    kv = k[..., :, None] * v[..., None, :]
    out = jnp.einsum("bhk,bhkv->bhv", r, state + jnp.exp(bonus)[None, :, :, None] * kv)
    state = jnp.exp(log_w)[..., None] * state + kv
    return out, state


def rwkv_apply(p, x: Array, head_dim: int, *, chunked: bool = True) -> Array:
    """Full-sequence time-mix block (B,S,D) -> (B,S,D)."""
    return _rwkv_apply(p, x, head_dim, chunked, False)[0]


def rwkv_apply_with_state(p, x: Array, head_dim: int, *, chunked: bool = True):
    """Prefill variant: also return {'wkv', 'shift'} final state."""
    return _rwkv_apply(p, x, head_dim, chunked, True)


def _rwkv_apply(p, x: Array, head_dim: int, chunked: bool, want_state: bool):
    b, s, d = x.shape
    r, k, v, g, log_w = _project(p, x, head_dim)
    fn = wkv_chunked if (chunked and s % CHUNK == 0) else wkv_recurrent
    out, state = fn(r, k, v, log_w, p["bonus"])
    out = rms_norm(out.reshape(b, s, d).astype(x.dtype), p["ln_out"]) * g
    y = out @ p["wo"]
    if want_state:
        return y, {"wkv": state, "shift": x[:, -1, :].astype(jnp.float32)}
    return y, None


def rwkv_init_state(batch: int, d_model: int, head_dim: int) -> Dict[str, Array]:
    h = d_model // head_dim
    return {
        "wkv": jnp.zeros((batch, h, head_dim, head_dim), jnp.float32),
        "shift": jnp.zeros((batch, d_model), jnp.float32),
        "ffn_shift": jnp.zeros((batch, d_model), jnp.float32),
    }


def rwkv_decode(p, x: Array, state: Dict[str, Array], head_dim: int
                ) -> Tuple[Array, Dict[str, Array]]:
    """x: (B, 1, D) single token."""
    b, _, d = x.shape
    h = d // head_dim
    xs = state["shift"].astype(x.dtype)[:, None, :]
    xr, xk, xv, xw, xg = _ddlerp(p, x, xs)
    r = (xr @ p["wr"]).reshape(b, h, head_dim).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(b, h, head_dim).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(b, h, head_dim).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])[:, 0]
    dec = p["decay_base"] + (jnp.tanh(xw @ p["decay_lora_a"]) @ p["decay_lora_b"]).astype(jnp.float32)
    log_w = -jnp.exp(jnp.clip(dec, -10.0, jnp.log(DECAY_CLAMP))).reshape(b, h, head_dim)
    out, wkv = wkv_step(r, k, v, log_w, p["bonus"], state["wkv"])
    out = rms_norm(out.reshape(b, d).astype(x.dtype), p["ln_out"]) * g
    y = (out @ p["wo"])[:, None, :]
    return y, {"wkv": wkv, "shift": x[:, 0, :].astype(jnp.float32)}


def rwkv_channel_mix_init(key, d_model: int, d_ff: int, dtype) -> Dict[str, Array]:
    ks = jax.random.split(key, 3)
    return {
        "mix_k": (jax.random.uniform(ks[0], (d_model,)) * 0.5).astype(dtype),
        "wk": dense_init(ks[1], d_model, (d_model, d_ff), dtype),
        "wv": dense_init(ks[2], d_ff, (d_ff, d_model), dtype),
    }


def rwkv_channel_mix(p, x: Array, x_prev: Array | None = None) -> Array:
    """RWKV FFN with token shift and squared-relu (full-sequence form)."""
    b = x.shape[0]
    if x_prev is None:
        x_prev = jnp.zeros((b, x.shape[-1]), x.dtype)
    xs = _token_shift(x, x_prev)
    xk = x + (xs - x) * p["mix_k"]
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return h @ p["wv"]
