"""Mamba-2 (SSD) block: chunked state-space dual form + recurrent oracle +
single-step decode (arXiv:2405.21060 as used by Zamba2, arXiv:2411.15242).

Mamba-2 uses a *scalar* decay per head (a_t = exp(-Δ_t·A_h)), which makes the
chunked form exact with plain matmuls: the intra-chunk pairwise decay matrix
L[t,τ] = exp(cum_t - cum_τ) is a bounded (chunk × chunk) tensor per head.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

Array = jax.Array

CHUNK = 64


def mamba_init(key, d_model: int, ssm_state: int, head_dim: int,
               conv_width: int, dtype) -> Dict[str, Array]:
    d_inner = 2 * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [x, z, B, C, dt]
        "in_proj": dense_init(ks[0], d_model,
                              (d_model, d_inner * 2 + 2 * ssm_state + n_heads), dtype),
        "conv": (jax.random.normal(ks[1], (conv_width, d_inner + 2 * ssm_state))
                 * 0.1).astype(dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(ks[2], d_inner, (d_inner, d_model), dtype),
    }


def _split_proj(p, x: Array, ssm_state: int, head_dim: int):
    d_model = x.shape[-1]
    d_inner = 2 * d_model
    n_heads = d_inner // head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * ssm_state], axis=-1)
    return z, xbc, dt, d_inner, n_heads


def _causal_conv(xbc: Array, w: Array, state: Array | None = None):
    """Depthwise causal conv over (B,S,C) with width-k filter (k,C)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(out), new_state


def ssd_chunked(xh, dt, a_log, b, c, chunk: int = CHUNK, h0=None):
    """Chunked SSD.  xh: (B,S,H,P); dt: (B,S,H); b,c: (B,S,N).
    Returns (out (B,S,H,P), final state (B,H,P,N))."""
    bsz, s, h, pdim = xh.shape
    n = b.shape[-1]
    assert s % chunk == 0
    nc = s // chunk

    dt = jax.nn.softplus(dt.astype(jnp.float32))                    # (B,S,H)
    loga = -jnp.exp(a_log)[None, None, :] * dt                      # (B,S,H) ≤ 0
    xdt = xh.astype(jnp.float32) * dt[..., None]                    # x·Δ

    # reshape to chunks: (nc, B, H, L, ...)
    loga_c = loga.reshape(bsz, nc, chunk, h).transpose(1, 0, 3, 2)          # (nc,B,H,L)
    x_c = xdt.reshape(bsz, nc, chunk, h, pdim).transpose(1, 0, 3, 2, 4)     # (nc,B,H,L,P)
    b_c = b.astype(jnp.float32).reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)  # (nc,B,L,N)
    c_c = c.astype(jnp.float32).reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    cum = jnp.cumsum(loga_c, axis=-1)                               # (nc,B,H,L)
    total = cum[..., -1:]

    # intra-chunk: out[t] = Σ_{τ≤t} exp(cum_t - cum_τ)·(c_t·b_τ)·x_τ
    # mask INSIDE the exponent: upper-triangle entries are positive and can
    # overflow exp(); 0*inf would poison gradients through jnp.where.
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    gap = cum[..., :, None] - cum[..., None, :]                     # (nc,B,H,L,L)
    decay = jnp.exp(jnp.where(mask, gap, -jnp.inf))
    cb = jnp.einsum("nbtq,nbsq->nbts", c_c, b_c)                    # (nc,B,L,L)
    att = cb[:, :, None] * decay                                    # (nc,B,H,L,L)
    intra = jnp.einsum("nbhts,nbhsp->nbhtp", att, x_c)

    # chunk-state: S_c = Σ_τ exp(total - cum_τ)·b_τ ⊗ x_τ
    b_scaled = jnp.einsum("nbsq,nbhs->nbhsq", b_c, jnp.exp(total - cum))
    chunk_states = jnp.einsum("nbhsq,nbhsp->nbhpq", b_scaled, x_c)  # (nc,B,H,P,N)

    if h0 is None:
        h0 = jnp.zeros((bsz, h, pdim, n), jnp.float32)

    def link(state, inp):
        cum_i, total_i, c_i, cs_i, intra_i = inp
        # inter-chunk contribution: c_t · exp(cum_t) · state
        inter = jnp.einsum("btq,bhpq,bht->bhtp", c_i, state, jnp.exp(cum_i))
        new_state = jnp.exp(total_i[..., 0])[..., None, None] * state + cs_i
        return new_state, intra_i + inter

    state, outs = jax.lax.scan(
        link, h0, (cum, total, c_c, chunk_states, intra))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(bsz, s, h, pdim)
    return out, state


def ssd_recurrent(xh, dt, a_log, b, c, h0=None):
    """Exact per-step recurrence (oracle)."""
    bsz, s, h, pdim = xh.shape
    n = b.shape[-1]
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    loga = -jnp.exp(a_log)[None, None, :] * dt
    xdt = xh.astype(jnp.float32) * dt[..., None]
    if h0 is None:
        h0 = jnp.zeros((bsz, h, pdim, n), jnp.float32)

    def step(state, inp):
        x_t, la_t, b_t, c_t = inp
        state = jnp.exp(la_t)[..., None, None] * state \
            + jnp.einsum("bhp,bq->bhpq", x_t, b_t)
        out = jnp.einsum("bhpq,bq->bhp", state, c_t)
        return state, out

    xs = (xdt.transpose(1, 0, 2, 3), loga.transpose(1, 0, 2),
          b.astype(jnp.float32).transpose(1, 0, 2), c.astype(jnp.float32).transpose(1, 0, 2))
    state, outs = jax.lax.scan(step, h0, xs)
    return outs.transpose(1, 0, 2, 3), state


def mamba_apply(p, x: Array, *, ssm_state: int, head_dim: int,
                chunked: bool = True) -> Array:
    """Full-sequence Mamba-2 block (B,S,D) -> (B,S,D)."""
    return _mamba_apply(p, x, ssm_state, head_dim, chunked, False)[0]


def mamba_apply_with_state(p, x: Array, *, ssm_state: int, head_dim: int,
                           chunked: bool = True):
    """Prefill variant: also return {'ssm', 'conv'} final state."""
    return _mamba_apply(p, x, ssm_state, head_dim, chunked, True)


def _mamba_apply(p, x: Array, ssm_state: int, head_dim: int,
                 chunked: bool, want_state: bool):
    bsz, s, d_model = x.shape
    z, xbc_raw, dt, d_inner, n_heads = _split_proj(p, x, ssm_state, head_dim)
    xbc, _ = _causal_conv(xbc_raw, p["conv"])
    xh, b, c = jnp.split(xbc, [d_inner, d_inner + ssm_state], axis=-1)
    xh = xh.reshape(bsz, s, n_heads, head_dim)
    dt = dt + p["dt_bias"]
    fn = ssd_chunked if (chunked and s % CHUNK == 0) else ssd_recurrent
    out, final = fn(xh, dt, p["a_log"], b, c)
    out = out + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    out = out.reshape(bsz, s, d_inner).astype(x.dtype)
    out = rms_norm(out, p["norm"]) * jax.nn.silu(z)
    y = out @ p["out_proj"]
    if want_state:
        kw = p["conv"].shape[0]
        conv_state = xbc_raw[:, -(kw - 1):, :].astype(jnp.float32)
        return y, {"ssm": final, "conv": conv_state}
    return y, None


def mamba_init_state(batch: int, d_model: int, ssm_state: int, head_dim: int,
                     conv_width: int) -> Dict[str, Array]:
    d_inner = 2 * d_model
    n_heads = d_inner // head_dim
    return {
        "ssm": jnp.zeros((batch, n_heads, head_dim, ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_inner + 2 * ssm_state), jnp.float32),
    }


def mamba_decode(p, x: Array, state: Dict[str, Array], *, ssm_state: int,
                 head_dim: int) -> Tuple[Array, Dict[str, Array]]:
    """x: (B,1,D) single-token decode."""
    bsz, _, d_model = x.shape
    z, xbc, dt, d_inner, n_heads = _split_proj(p, x, ssm_state, head_dim)
    xbc, conv_state = _causal_conv(xbc, p["conv"], state["conv"])
    xh, b, c = jnp.split(xbc[:, 0], [d_inner, d_inner + ssm_state], axis=-1)
    xh = xh.reshape(bsz, n_heads, head_dim)
    dtv = jax.nn.softplus((dt[:, 0] + p["dt_bias"]).astype(jnp.float32))
    loga = -jnp.exp(p["a_log"])[None, :] * dtv
    s_new = jnp.exp(loga)[..., None, None] * state["ssm"] + jnp.einsum(
        "bhp,bq->bhpq", xh.astype(jnp.float32) * dtv[..., None], b.astype(jnp.float32))
    out = jnp.einsum("bhpq,bq->bhp", s_new, c.astype(jnp.float32))
    out = out + p["d_skip"][None, :, None] * xh.astype(jnp.float32)
    out = out.reshape(bsz, d_inner).astype(x.dtype)
    out = rms_norm(out, p["norm"]) * jax.nn.silu(z[:, 0])
    return (out @ p["out_proj"])[:, None, :], {"ssm": s_new, "conv": conv_state}
