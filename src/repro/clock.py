"""Injectable clocks: every time read in the project goes through one seam.

This is the ONE module allowed to touch ``time.*`` directly — the
clock-discipline rule of ``repro.analysis`` enforces that everywhere else
(serving deadlines, training step timers, DSE calibration, dry-run
compile timing) reads time through an injected ``Clock``.

The stack has two kinds of time dependence: *telemetry* (profile/step
timers) and *behavior* (the async front-end's wall-clock flush
deadlines).  Both route through a ``Clock`` so tier-1 tests never sleep
and never read real time: ``SystemClock`` is the
production implementation, ``FakeClock`` a manually-advanced test double
whose ``advance()`` also wakes any asyncio waiter parked on it — a
deadline test advances fake time and the flusher fires deterministically,
with zero real ``sleep`` calls anywhere (tests/test_async_frontend.py).

``Clock.wait(event, timeout)`` is the one blocking primitive the async
front-end uses: "sleep until ``event`` is set or ``timeout`` seconds of
*this clock's* time pass".  With ``timeout=None`` it waits on the event
alone.  It never raises on timeout — callers re-derive what to do from
``now()`` — so flusher logic is identical under either clock.
"""
from __future__ import annotations

import asyncio
import time
from typing import List, Optional, Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    """Monotonic seconds + an awaitable event-or-timeout wait.

    ``time()`` is the epoch-seconds sibling of ``now()``: monotonic time
    is meaningless across process restarts, so anything that persists
    timestamps (the flush journal, ``repro.serve.journal``) stamps with
    ``time()`` instead.  ``FakeClock`` advances both together, so
    journaled timestamps stay deterministic in tests.
    """

    def now(self) -> float:
        ...

    def time(self) -> float:
        ...

    async def wait(self, event: "asyncio.Event",
                   timeout: Optional[float]) -> None:
        ...


class SystemClock:
    """Real monotonic time; ``wait`` is ``asyncio.wait_for`` on the event."""

    def now(self) -> float:
        return time.perf_counter()

    def time(self) -> float:
        return time.time()

    async def wait(self, event: asyncio.Event,
                   timeout: Optional[float]) -> None:
        try:
            await asyncio.wait_for(asyncio.ensure_future(event.wait()),
                                   timeout)
        except asyncio.TimeoutError:
            pass


class FakeClock:
    """Manual-advance clock: time moves only when the test says so.

    ``advance(dt)`` moves ``now()`` forward and wakes every ``wait()``
    currently parked on this clock, whether or not its timeout has
    expired — the waiter re-checks its own deadline and goes back to
    sleep if it is still in the future.  That makes deadline semantics
    exact: a waiter with 100 ms left wakes (and its caller re-decides)
    at every advance, and returns for good only once fake time actually
    passes the deadline.

    Not thread-safe: ``advance()`` must run on the event-loop thread
    (marshal with ``loop.call_soon_threadsafe`` from elsewhere).
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._ticks: List[asyncio.Event] = []

    def now(self) -> float:
        return self._now

    def time(self) -> float:
        return self._now

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"cannot advance time backwards ({dt})")
        self._now += float(dt)
        for tick in self._ticks:
            tick.set()

    async def wait(self, event: asyncio.Event,
                   timeout: Optional[float]) -> None:
        deadline = None if timeout is None else self._now + timeout
        while not event.is_set():
            if deadline is not None and self._now >= deadline:
                return
            tick = asyncio.Event()
            self._ticks.append(tick)
            ev_w = asyncio.ensure_future(event.wait())
            tk_w = asyncio.ensure_future(tick.wait())
            try:
                await asyncio.wait({ev_w, tk_w},
                                   return_when=asyncio.FIRST_COMPLETED)
            finally:
                self._ticks.remove(tick)
                for w in (ev_w, tk_w):
                    if not w.done():
                        w.cancel()
                await asyncio.gather(ev_w, tk_w, return_exceptions=True)
