"""Roofline analysis over the dry-run artifacts (deliverable g).

Terms per (arch x shape x mesh), TPU v5e constants:
  compute    = HLO_FLOPs_global / (chips * 197e12)
  memory     = HLO_bytes_global / (chips * 819e9)
  collective = weighted_link_bytes_per_device / 50e9
               (per-device link traffic with ring factors AR:2, AG/RS/CP/A2A:1
                — see dryrun.parse_collectives; equivalent to the global form
                collective_bytes/(chips*link_bw) since traffic is uniform
                across chips)

``compiled.cost_analysis()`` reports the PER-DEVICE partitioned module, so
flops/bytes are multiplied by the device count for the global numerators.

MODEL_FLOPS uses 6*N*D for training (N params, D tokens) and 2*N_active*D
for inference (fwd only); the ratio MODEL_FLOPS/HLO_FLOPs exposes remat and
padding waste (remat recompute makes HLO > model for training).
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List, Optional

from repro.atomicio import atomic_write_text

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link


def model_flops(rec: Dict) -> float:
    """Paper-style useful-FLOPs for the cell."""
    n_active = rec["n_active_params"]
    shape = rec["shape"]
    if shape == "train_4k":
        tokens = 256 * 4096
        return 6.0 * n_active * tokens
    if shape == "prefill_32k":
        tokens = 32 * 32768
        return 2.0 * n_active * tokens
    if shape == "decode_32k":
        return 2.0 * n_active * 128          # one token x batch 128
    if shape == "long_500k":
        return 2.0 * n_active * 1
    raise ValueError(shape)


def analyze(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["devices"]
    static = rec.get("hlo_static")
    if static:  # loop-aware static analysis (preferred source)
        flops_dev = static["flops"]
        bytes_dev = static["bytes_accessed"]
        link_dev = static["weighted_link_bytes_per_device"]
    else:       # fallback: XLA cost analysis (undercounts while bodies)
        ca = rec.get("cost_analysis", {})
        if "flops" not in ca:
            return None
        flops_dev = ca["flops"]
        bytes_dev = ca.get("bytes accessed", 0.0)
        link_dev = rec["collectives"]["weighted_link_bytes_per_device"]

    compute_s = flops_dev / PEAK_FLOPS            # = global/(chips*peak)
    memory_s = bytes_dev / HBM_BW
    collective_s = link_dev / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]

    mf = model_flops(rec)
    mf_dev = mf / chips
    useful_ratio = mf_dev / flops_dev if flops_dev else 0.0
    # roofline fraction: useful model FLOP/s achieved at the bound, vs peak
    step_s = max(compute_s, memory_s, collective_s)
    roofline_frac = (mf / (chips * PEAK_FLOPS)) / step_s if step_s else 0.0

    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips, **{k: float(v) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "bound_s": float(bound_s),
        "model_flops": mf, "hlo_flops_global": flops_dev * chips,
        "useful_flop_ratio": float(useful_ratio),
        "roofline_frac": float(roofline_frac),
        "collective_counts": rec["collectives"]["per_kind_count"],
    }


def load_all(dryrun_dir: str | pathlib.Path) -> List[Dict]:
    out = []
    for p in sorted(pathlib.Path(dryrun_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        a = analyze(rec)
        if a is not None:
            a["file"] = p.name
            out.append(a)
    return out


def to_markdown(rows: List[Dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {r['useful_flop_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = load_all(args.dir)
    if args.json_out:
        atomic_write_text(args.json_out, json.dumps(rows, indent=2))
    print(to_markdown(rows, args.mesh))
    worst = [r for r in rows if r["mesh"] == args.mesh]
    worst.sort(key=lambda r: r["roofline_frac"])
    print("\nworst roofline fractions:")
    for r in worst[:5]:
        print(f"  {r['arch']}/{r['shape']}: {r['roofline_frac']:.4f} "
              f"({r['dominant']}-bound)")
    coll = [r for r in rows if r["mesh"] == args.mesh
            and r["dominant"] == "collective"]
    print(f"\ncollective-bound cells: {[(r['arch'], r['shape']) for r in coll]}")


if __name__ == "__main__":
    main()
