"""Production training launcher: wires configs, mesh, sharding planner, data
pipeline and the fault-tolerant loop for any assigned arch.

On a real pod:
  python -m repro.launch.train --arch qwen2-72b --shape train_4k \
      --mesh single --steps 1000 --ckpt-dir gs://.../ckpts

On this CPU container use --smoke: the same code path at reduced config on a
2x2 debug mesh (this is exercised by tests/test_launch_train.py).
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", choices=("single", "multi", "debug"), default="single")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + debug mesh + tiny batch (CPU)")
    ap.add_argument("--sequence-parallel", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--chaotic-shuffle", action="store_true")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config, get_smoke_config
    from repro.data.pipeline import SyntheticLMDataset
    from repro.distributed.sharding import (MeshSpec, make_shard_fn, named,
                                            plan_batch, plan_params)
    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    from repro.train.loop import LoopConfig, run
    from repro.train.optimizer import Adam, warmup_cosine
    from repro.train.train_step import (TrainStepConfig, init_train_state,
                                        make_train_step)

    shape = SHAPES[args.shape]
    if args.smoke:
        cfg = get_smoke_config(args.arch)
        mesh = make_debug_mesh(2, 2)
        global_batch, seq_len, n_mb = 8, 64, 2
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        global_batch, seq_len = shape.global_batch, shape.seq_len
        from repro.launch.dryrun import MICROBATCHES
        spec0 = MeshSpec.from_mesh(mesh)
        n_mb = min(MICROBATCHES.get(cfg.name, 1),
                   max(global_batch // spec0.dp_size, 1))

    spec = MeshSpec.from_mesh(mesh, sequence_parallel=args.sequence_parallel)
    shard_fn = make_shard_fn(spec)
    opt = Adam(lr=warmup_cosine(args.lr, min(100, args.steps // 10 + 1), args.steps),
               clip_norm=1.0, weight_decay=0.01)
    ts_cfg = TrainStepConfig(num_microbatches=n_mb,
                             compress_grads=args.compress_grads)
    step_fn = make_train_step(cfg, opt, ts_cfg, shard_fn=shard_fn)

    state = init_train_state(cfg, opt, jax.random.PRNGKey(0),
                             use_compression=args.compress_grads)
    with mesh:
        pspec = plan_params(jax.eval_shape(lambda: state.params), spec,
                            n_layers_hint=cfg.n_layers)
        state = state._replace(
            params=jax.device_put(state.params, named(spec, pspec)),
            opt=state.opt._replace(
                mu=jax.device_put(state.opt.mu, named(spec, pspec)),
                nu=jax.device_put(state.opt.nu, named(spec, pspec))),
            error_buf=(jax.device_put(state.error_buf, named(spec, pspec))
                       if state.error_buf is not None else None))

        ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=seq_len,
                                global_batch=global_batch, seed=0,
                                use_chaotic_shuffle=args.chaotic_shuffle)
        bspec = named(spec, plan_batch(
            {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in ds.batch_at(0).items()}, spec))

        def put_batch(b):
            return {k: jax.device_put(jnp.asarray(v), bspec[k])
                    for k, v in b.items()}

        jitted = jax.jit(step_fn, donate_argnums=0)
        res = run(state, jitted, ds.batch_at,
                  LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                             ckpt_every=args.ckpt_every),
                  put_batch=put_batch)
    print(f"[launch.train] finished at step {int(res.final_state.step)}; "
          f"preempted={res.preempted} stragglers={len(res.straggler_steps)}")
    return res


if __name__ == "__main__":
    main()
