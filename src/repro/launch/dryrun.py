import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh (single-pod 16x16 = 256 chips; multi-pod 2x16x16 = 512) and
record memory/cost/collective analyses for the roofline.

The two lines above MUST run before any jax import (device count locks at
first init); do not set them globally — smoke tests and benches see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
      --shape train_4k --mesh single --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import pathlib           # noqa: E402
import re                # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.atomicio import atomic_write_text  # noqa: E402
from repro.clock import SystemClock  # noqa: E402
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shapes_for  # noqa: E402
from repro.configs.registry import ALIASES, ARCH_IDS, get_config  # noqa: E402
from repro.distributed.sharding import (MeshSpec, make_shard_fn, named,  # noqa: E402
                                        plan_batch, plan_decode_state,
                                        plan_params, strip_dp_axes)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.serve.engine import make_serve_step  # noqa: E402
from repro.train.optimizer import Adam  # noqa: E402
from repro.train.train_step import (TrainState, TrainStepConfig, batch_spec,  # noqa: E402
                                    make_train_step)

# desired gradient-accumulation microbatches per arch for train_4k
# (sized so per-device activations fit 16 GB HBM; see DESIGN.md §6)
MICROBATCHES = {
    "codeqwen1_5_7b": 4, "llama3_2_3b": 4, "gemma_7b": 4, "qwen2_72b": 16,
    "chameleon_34b": 8, "rwkv6_1_6b": 2, "zamba2_1_2b": 4, "mixtral_8x7b": 8,
    "qwen3_moe_30b_a3b": 8, "musicgen_large": 4,
}

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b")
SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}
# per-device link-traffic factor per collective kind (ring algorithms)
_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def parse_collectives(hlo_text: str):
    """Sum per-device payload bytes of collective ops in partitioned HLO."""
    totals = {k: 0.0 for k in _FACTOR}
    counts = {k: 0 for k in _FACTOR}
    for line in hlo_text.splitlines():
        if "= " not in line:
            continue
        m = COLLECTIVE_RE.search(line.split("= ", 1)[1].split("(", 1)[0])
        if not m:
            continue
        if "-done" in line:          # started payload already counted
            continue
        kind = m.group(1)
        best = 0
        for dt, dims in SHAPE_RE.findall(line):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            best = max(best, n * _DTYPE_BYTES[dt])
        totals[kind] += best
        counts[kind] += 1
    link_bytes = sum(_FACTOR[k] * v for k, v in totals.items())
    return {"per_kind_bytes": totals, "per_kind_count": counts,
            "weighted_link_bytes_per_device": link_bytes}


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               sequence_parallel: bool = False, compress_grads: bool = False,
               remat_policy: str = "none", num_microbatches: int = 0,
               params_fsdp: bool = True, moe_dispatch_bf16: bool = False,
               moe_group_size: int = 0, kv_shard_seq: bool = False):
    """Returns (fn, args_shape_structs, in_shardings, donate).

    Hillclimb levers (see EXPERIMENTS.md §Perf):
      num_microbatches: override the per-arch gradient-accumulation depth
      params_fsdp=False: TP-only param sharding (kills the per-step FSDP
        all-gather — the serving-appropriate layout)
      moe_dispatch_bf16 / moe_group_size: MoE dispatch cost levers
    """
    spec = MeshSpec.from_mesh(mesh, sequence_parallel=sequence_parallel)
    shard_fn = make_shard_fn(spec)
    if remat_policy != "none":
        cfg = dataclasses.replace(cfg, remat=(remat_policy != "off"))
    if moe_dispatch_bf16:
        cfg = dataclasses.replace(cfg, moe_dispatch_dtype="bfloat16")
    if moe_group_size:
        cfg = dataclasses.replace(cfg, moe_group_size=moe_group_size)

    params_shape = jax.eval_shape(lambda: tf.init(cfg, jax.random.PRNGKey(0)))
    params_spec = plan_params(params_shape, spec, n_layers_hint=cfg.n_layers)
    if not params_fsdp:
        params_spec = strip_dp_axes(params_spec, spec)

    if shape.kind == "train":
        opt = Adam(lr=1e-4, clip_norm=1.0)
        n_mb = num_microbatches or min(MICROBATCHES.get(cfg.name, 1),
                                       max(shape.global_batch // spec.dp_size, 1))
        ts_cfg = TrainStepConfig(num_microbatches=n_mb,
                                 compress_grads=compress_grads)
        step = make_train_step(cfg, opt, ts_cfg, shard_fn=shard_fn)

        def make_state():
            params = tf.init(cfg, jax.random.PRNGKey(0))
            return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                              opt=opt.init(params), error_buf=None)

        state_shape = jax.eval_shape(make_state)
        P = jax.sharding.PartitionSpec
        state_spec = TrainState(
            step=P(), params=params_spec,
            opt=type(state_shape.opt)(step=P(), mu=params_spec, nu=params_spec),
            error_buf=None)
        batch = batch_spec(cfg, shape)
        batch_sh = plan_batch(batch, spec)
        args = (state_shape, batch)
        in_sh = (named(spec, state_spec), named(spec, batch_sh))
        return step, args, in_sh, (0,)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, aux, states = tf.forward(
                cfg, params, batch.get("tokens"), embeds=batch.get("embeds"),
                shard_fn=shard_fn, last_only=True, return_state=True)
            return logits, states

        batch = dict(batch_spec(cfg, shape))
        batch.pop("labels")
        batch_sh = plan_batch(batch, spec)
        args = (params_shape, batch)
        in_sh = (named(spec, params_spec), named(spec, batch_sh))
        return prefill_step, args, in_sh, ()

    # decode: one new token against a seq_len-deep cache
    serve = make_serve_step(cfg, shard_fn=shard_fn)
    state_shape = jax.eval_shape(
        lambda: tf.init_decode_state(cfg, shape.global_batch, shape.seq_len))
    state_spec = plan_decode_state(
        state_shape, spec, n_layers_hint=cfg.n_layers,
        attn_kv_shard="seq" if kv_shard_seq else "head")
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_sh = plan_batch(tokens, spec)
    args = (params_shape, state_shape, tokens)
    in_sh = (named(spec, params_spec), named(spec, state_spec),
             named(spec, tok_sh))
    return serve, args, in_sh, (1,)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = "results/dryrun", clock=None, **build_kw):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    # Durations, not timestamps: a wall clock (time.time) can step under
    # NTP mid-compile; the injected Clock's now() is monotonic.
    clock = clock or SystemClock()
    t0 = clock.now()
    record = {
        "arch": cfg.name, "shape": shape_name, "mesh": mesh_kind,
        "devices": n_dev, "status": "error",
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
        "build_kw": {k: str(v) for k, v in build_kw.items()},
    }
    try:
        fn, args, in_sh, donate = build_cell(cfg, shape, mesh, **build_kw)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = clock.now()
            compiled = lowered.compile()
            t_compile = clock.now()

            try:
                mem = compiled.memory_analysis()
                record["memory_analysis"] = {
                    k: int(getattr(mem, k)) for k in (
                        "argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "generated_code_size_in_bytes",
                        "alias_size_in_bytes")
                    if hasattr(mem, k)}
            # repro: allow[broad-except] reason=XLA memory_analysis raises backend-specific types (CPU lacks fields); the error is recorded in the cell, not dropped
            except Exception as e:
                record["memory_analysis"] = {"error": str(e)}

            try:
                cost = compiled.cost_analysis()
                if isinstance(cost, (list, tuple)):
                    cost = cost[0]
                record["cost_analysis"] = {
                    k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float)) and (
                        k in ("flops", "transcendentals", "bytes accessed")
                        or k.startswith("bytes accessed"))}
            # repro: allow[broad-except] reason=XLA cost_analysis raises backend-specific types; the error is recorded in the cell, not dropped
            except Exception as e:
                record["cost_analysis"] = {"error": str(e)}

            hlo = compiled.as_text()
            record["collectives"] = parse_collectives(hlo)
            # loop-aware static analysis (cost_analysis visits while bodies
            # once, undercounting scan-over-layers models by the trip count)
            from repro.launch.hlo_analysis import analyze_hlo
            static = analyze_hlo(hlo)
            static["weighted_link_bytes_per_device"] = sum(
                _FACTOR[k] * v for k, v in static["collective_bytes"].items())
            record["hlo_static"] = static
            record["hlo_bytes"] = len(hlo)
            del hlo
        record["status"] = "ok"
        record["lower_s"] = round(t_lower - t0, 2)
        record["compile_s"] = round(t_compile - t_lower, 2)
    # repro: allow[broad-except] reason=sweep isolation: any one cell failure (OOM, lowering bug) is recorded with its traceback and the remaining cells still run
    except Exception as e:
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_s"] = round(clock.now() - t0, 2)

    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    tag = "_".join([cfg.name, shape_name, mesh_kind] +
                   [f"{k}-{v}" for k, v in sorted(build_kw.items())
                    if v or v is False])
    atomic_write_text(out / f"{tag}.json", json.dumps(record, indent=2))
    status = record["status"]
    err = ("" if status == "ok" else " :: " + record.get("error", ""))
    print(f"[dryrun] {tag}: {status} ({record['total_s']}s){err}", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(list(ALIASES) + list(ARCH_IDS)))
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true", help="run every assigned cell")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--sequence-parallel", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--num-microbatches", type=int, default=0)
    ap.add_argument("--no-params-fsdp", action="store_true")
    ap.add_argument("--moe-dispatch-bf16", action="store_true")
    ap.add_argument("--moe-group-size", type=int, default=0)
    ap.add_argument("--kv-shard-seq", action="store_true")
    args = ap.parse_args()

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    kw = dict(sequence_parallel=args.sequence_parallel,
              compress_grads=args.compress_grads,
              num_microbatches=args.num_microbatches,
              moe_dispatch_bf16=args.moe_dispatch_bf16,
              moe_group_size=args.moe_group_size,
              kv_shard_seq=args.kv_shard_seq)
    kw = {k: v for k, v in kw.items() if v}
    if args.no_params_fsdp:
        kw["params_fsdp"] = False

    if args.all:
        n_ok = n_fail = 0
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape in shapes_for(cfg):
                for mesh_kind in meshes:
                    rec = run_cell(arch, shape.name, mesh_kind, args.out, **kw)
                    n_ok += rec["status"] == "ok"
                    n_fail += rec["status"] != "ok"
        print(f"[dryrun] DONE: {n_ok} ok, {n_fail} failed")
        raise SystemExit(1 if n_fail else 0)

    if not (args.arch and args.shape):
        ap.error("--arch and --shape required (or --all)")
    recs = [run_cell(args.arch, args.shape, m, args.out, **kw) for m in meshes]
    raise SystemExit(0 if all(r["status"] == "ok" for r in recs) else 1)


if __name__ == "__main__":
    main()
