"""Loop-aware static analysis of optimized (post-SPMD) HLO text.

XLA's ``HloCostAnalysis`` (and thus ``compiled.cost_analysis()``) visits a
``while`` body ONCE, so for scan-over-layers models it undercounts FLOPs,
bytes and collective traffic by the trip count (80x for qwen2!).  This
module parses the printed HLO module, recovers while trip counts from the
loop condition, and aggregates:

  - dot FLOPs: 2 * prod(result dims) * prod(lhs contracting dims)
  - elementwise/reduce FLOPs (coarse: prod(result dims))
  - materialized-buffer traffic: for every top-level (post-fusion) op,
    unique operand bytes + result bytes — the analytical HBM-traffic model
  - collective payload bytes per kind (per-device, ring-factor-weighted by
    the caller)

All quantities are multiplied through nested fusion/call/while scopes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

# result def:  %name = type[dims]{layout} opcode(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?)([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"([a-z][\w\-]*)\((.*)$")
# tuple-result def: %name = (type[..], ...) opcode(...)
_TUPLE_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\((.*?)\)\s+([a-z][\w\-]*)\((.*)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_ATTR_COMP_RE = {
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
}
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "select",
    "compare", "and", "or", "xor", "clamp",
}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclasses.dataclass
class Instr:
    name: str
    dtype: str
    dims: Tuple[int, ...]
    opcode: str
    rest: str          # text after the opening paren (operands + attrs)
    tuple_shapes: Optional[List[Tuple[str, Tuple[int, ...]]]] = None

    @property
    def result_bytes(self) -> int:
        if self.tuple_shapes is not None:
            return sum(_nelem(d) * _DTYPE_BYTES.get(t, 4)
                       for t, d in self.tuple_shapes)
        return _nelem(self.dims) * _DTYPE_BYTES.get(self.dtype, 4)

    @property
    def result_elems(self) -> int:
        if self.tuple_shapes is not None:
            return sum(_nelem(d) for _, d in self.tuple_shapes)
        return _nelem(self.dims)


def _nelem(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _parse_dims(s: str) -> Tuple[int, ...]:
    return tuple(int(x) for x in s.split(",") if x) if s else ()


def parse_module(text: str) -> Dict[str, List[Instr]]:
    """computation name -> instruction list."""
    comps: Dict[str, List[Instr]] = {}
    current: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        stripped = line.strip()
        # computation headers: "%name (params...) -> type {"
        if stripped.endswith("{") and "->" in stripped and "=" not in stripped.split("(")[0]:
            m = _COMP_HDR_RE.match(stripped)
            if m:
                current = m.group(1)
                comps[current] = []
                continue
        if stripped == "}" or stripped.startswith("}"):
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(stripped)
        if m and not m.group(2):
            name, _, dtype, dims, opcode, rest = m.groups()
            comps[current].append(
                Instr(name, dtype, _parse_dims(dims), opcode, rest))
            continue
        mt = _TUPLE_INSTR_RE.match(stripped)
        if mt:
            name, shapes_s, opcode, rest = mt.groups()
            shapes = [(t, _parse_dims(d)) for t, d in _SHAPE_RE.findall(shapes_s)]
            comps[current].append(
                Instr(name, shapes[0][0] if shapes else "f32",
                      shapes[0][1] if shapes else (), opcode, rest,
                      tuple_shapes=shapes))
    return comps


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        for k in COLLECTIVES:
            self.collective_bytes[k] += other.collective_bytes[k] * mult
            self.collective_counts[k] += other.collective_counts[k] * mult


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.shapes: Dict[str, Instr] = {}
        for instrs in self.comps.values():
            for ins in instrs:
                self.shapes[ins.name] = ins
        self._memo: Dict[str, Totals] = {}
        # entry = last computation with ENTRY marker; fall back to the one
        # named like 'main' or the longest
        self.entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    self.entry = m.group(1)
        if self.entry is None:
            self.entry = max(self.comps, key=lambda c: len(self.comps[c]))

    # -- trip counts ---------------------------------------------------
    def trip_count(self, cond_comp: str) -> float:
        """Recover while trip count from its condition computation.

        XLA lowers scan conditions to ``compare(induction, constant(N))``,
        possibly wrapped in a kLoop fusion.  The condition computation is
        tiny and its only integer constant is the bound, so we take the max
        integer constant found in the condition and any computation it
        calls; direction LE adds one.
        """
        best = 0
        le = False
        stack = [cond_comp]
        seen = set()
        while stack:
            comp = stack.pop()
            if comp in seen:
                continue
            seen.add(comp)
            for ins in self.comps.get(comp, []):
                if ins.opcode == "constant":
                    mc = re.match(r"(\d+)\)", ins.rest)
                    if mc:
                        best = max(best, int(mc.group(1)))
                if "direction=LE" in ins.rest:
                    le = True
                called = _ATTR_COMP_RE["calls"].search(ins.rest) or \
                    _ATTR_COMP_RE["to_apply"].search(ins.rest)
                if called:
                    stack.append(called.group(1))
        if best == 0:
            return 1.0
        return float(best + 1 if le else best)

    # -- per-instruction costs ------------------------------------------
    def _operand_names(self, ins: Instr) -> List[str]:
        head = ins.rest.split("), ")[0] if "), " in ins.rest else ins.rest.rstrip(")")
        return _OPERAND_RE.findall(head)

    def _dot_flops(self, ins: Instr) -> float:
        """Raw MAC-based FLOPs (dtype-agnostic).

        NOTE: f32 dots run at ~1/4 MXU bf16 peak, but the CPU backend's
        float-normalization rewrites EVERY bf16 dot to f32 before this HLO
        is printed, so operand dtype here cannot distinguish genuine f32
        compute from normalized bf16.  dtype-efficiency claims (e.g. the
        bf16 MoE-dispatch lever) are therefore made analytically in
        EXPERIMENTS.md §Perf rather than from this count."""
        ops = self._operand_names(ins)
        contract = 1
        m = _LHS_CONTRACT_RE.search(ins.rest)
        if m and ops:
            lhs = self.shapes.get(ops[0])
            if lhs is not None:
                for idx in _parse_dims(m.group(1)):
                    if idx < len(lhs.dims):
                        contract *= lhs.dims[idx]
        return 2.0 * ins.result_elems * contract

    def _instr_totals(self, ins: Instr) -> Totals:
        t = Totals()
        op = ins.opcode
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all", "partition-id", "replica-id"):
            return t
        # nested computations
        if op == "while":
            body = _ATTR_COMP_RE["body"].search(ins.rest)
            cond = _ATTR_COMP_RE["condition"].search(ins.rest)
            trips = self.trip_count(cond.group(1)) if cond else 1.0
            if body:
                t.add(self.comp_totals(body.group(1)), trips)
            return t
        if op == "fusion":
            called = _ATTR_COMP_RE["calls"].search(ins.rest)
            if called:
                inner = self.comp_totals(called.group(1))
                t.flops += inner.flops     # fusion internals: flops only
            # In-place update fusions (scan-carried caches/stacked buffers):
            # XLA aliases the result onto the big operand, so real traffic is
            # the update window, not the whole buffer.  Count operands that
            # are NOT shape-aliased to the result, times 2 (window RMW).
            if "dynamic-update-slice" in ins.name or "dynamic-update-slice" in ins.rest[:40]:
                small = 0
                for o in self._operand_names(ins):
                    src = self.shapes.get(o)
                    if src is not None and src.result_bytes != ins.result_bytes:
                        small += src.result_bytes
                t.bytes_accessed += 2.0 * small
                return t
            # traffic: the fusion's materialized operands + result
            t.bytes_accessed += self._traffic(ins)
            return t
        if op in ("call", "custom-call", "conditional"):
            called = _ATTR_COMP_RE["to_apply"].search(ins.rest) or \
                _ATTR_COMP_RE["calls"].search(ins.rest)
            if called:
                t.add(self.comp_totals(called.group(1)))
            t.bytes_accessed += self._traffic(ins)
            return t
        # collectives
        for kind in COLLECTIVES:
            if op.startswith(kind):
                if op.endswith("-done"):
                    return t
                payload = max(ins.result_bytes, 0)
                t.collective_bytes[kind] += payload
                t.collective_counts[kind] += 1
                t.bytes_accessed += self._traffic(ins)
                return t
        # compute ops
        if op == "dot":
            t.flops += self._dot_flops(ins)
        elif op in ("convolution",):
            t.flops += 2.0 * ins.result_elems  # lower bound without kernel dims
        elif op in ELEMENTWISE or op in ("reduce", "reduce-window", "exponential-minus-one"):
            t.flops += float(ins.result_elems)

        # HBM-traffic model ("perfect layout fusion"): pure layout/copy ops
        # are assumed fused away on TPU (the CPU backend materializes them,
        # which would overstate TPU traffic several-fold); window ops count
        # only the window, not the full operand.
        if op in ("copy", "convert", "bitcast", "transpose", "reshape",
                  "broadcast", "iota", "reverse"):
            return t
        if op in ("slice", "dynamic-slice", "gather"):
            t.bytes_accessed += 2.0 * ins.result_bytes   # read window + write
            return t
        if op == "dynamic-update-slice":
            ops_ = self._operand_names(ins)
            upd = self.shapes.get(ops_[1]) if len(ops_) > 1 else None
            upd_bytes = upd.result_bytes if upd else ins.result_bytes
            t.bytes_accessed += 2.0 * upd_bytes          # in-place window RMW
            return t
        t.bytes_accessed += self._traffic(ins)
        return t

    def _traffic(self, ins: Instr) -> float:
        total = float(ins.result_bytes)
        seen = set()
        for o in self._operand_names(ins):
            if o in seen:
                continue
            seen.add(o)
            src = self.shapes.get(o)
            if src is not None:
                total += src.result_bytes
        return total

    def comp_totals(self, comp: str) -> Totals:
        if comp in self._memo:
            return self._memo[comp]
        t = Totals()
        self._memo[comp] = t          # break cycles defensively
        for ins in self.comps.get(comp, []):
            t.add(self._instr_totals(ins))
        return t

    def analyze(self) -> Dict:
        t = self.comp_totals(self.entry)
        return {
            "flops": t.flops,
            "bytes_accessed": t.bytes_accessed,
            "collective_bytes": dict(t.collective_bytes),
            "collective_counts": dict(t.collective_counts),
        }


    # -- diagnostics ----------------------------------------------------
    def _walk(self, comp: str, mult: float, out: List, depth: int = 0):
        if depth > 20:
            return
        for ins in self.comps.get(comp, []):
            op = ins.opcode
            if op == "while":
                body = _ATTR_COMP_RE["body"].search(ins.rest)
                cond = _ATTR_COMP_RE["condition"].search(ins.rest)
                trips = self.trip_count(cond.group(1)) if cond else 1.0
                if body:
                    self._walk(body.group(1), mult * trips, out, depth + 1)
                continue
            if op in ("call", "conditional"):
                called = _ATTR_COMP_RE["to_apply"].search(ins.rest) or \
                    _ATTR_COMP_RE["calls"].search(ins.rest)
                if called:
                    self._walk(called.group(1), mult, out, depth + 1)
            t = self._instr_totals(ins)
            coll = sum(t.collective_bytes.values())
            if t.bytes_accessed or t.flops or coll:
                out.append((mult * t.bytes_accessed, mult * t.flops,
                            mult * coll, ins.opcode, ins.name, mult))

    def top_contributors(self, n: int = 20, key: str = "bytes") -> List:
        """Largest per-instruction costs (scope-multiplied).  key: bytes|flops|coll."""
        out: List = []
        self._walk(self.entry, 1.0, out)
        idx = {"bytes": 0, "flops": 1, "coll": 2}[key]
        out.sort(key=lambda r: -r[idx])
        return out[:n]


def analyze_hlo(text: str) -> Dict:
    return HloAnalyzer(text).analyze()
