"""Production mesh construction (single-pod 16x16 and multi-pod 2x16x16).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes, devices):
    # jax >= 0.5 takes an axis_types positional; 0.4.x does not have AxisType.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, (axis_type.Auto,) * len(axes),
                             devices=devices)
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 512 if multi_pod else 256
    return _make_mesh(shape, axes, jax.devices()[:n])


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for subprocess-based distributed tests."""
    n = n_data * n_model
    return _make_mesh((n_data, n_model), ("data", "model"), jax.devices()[:n])
