"""MusicGen-large [arXiv:2306.05284]: decoder-only transformer over EnCodec
tokens (vocab 2048 per codebook).  Backbone only — the EnCodec frontend is a
stub: train/prefill input_specs provide precomputed frame embeddings.
MHA (kv=32), GELU non-GLU FFN (T5-style backbone)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen_large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    activation="gelu", glu=False, frontend="audio_stub",
)
