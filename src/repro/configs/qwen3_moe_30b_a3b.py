"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 128 experts top-8, expert
d_ff=768, GQA kv=4, head_dim=128, qk-norm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_moe_30b_a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936,
    n_experts=128, n_experts_per_tok=8, qk_norm=True,
    activation="silu", glu=True, rope_theta=1_000_000.0,
)
