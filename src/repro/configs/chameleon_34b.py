"""Chameleon-34B [arXiv:2405.09818]: early-fusion VLM backbone — VQ image
tokens share the 65536 vocab; qk-norm for stability.  Modality frontend is
a stub: train/prefill input_specs provide precomputed patch embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon_34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536,
    qk_norm=True, activation="silu", glu=True, frontend="vision_stub",
)
