"""Model and shape configuration dataclasses shared by the whole framework."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One LM-family architecture.  Field semantics follow the assignment
    table; every assigned arch maps onto this single config surface."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    block_type: str = "attn"           # 'attn' | 'rwkv6' | 'mamba2'
    activation: str = "silu"           # silu | gelu | relu | relu2
    glu: bool = True                   # gated MLP (SwiGLU / GeGLU)
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_window: Optional[int] = None  # sliding-window attention
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    embed_scale: bool = False          # gemma: scale embeddings by sqrt(d)
    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024         # GShard dispatch group (tokens)
    moe_dispatch_dtype: str = "float32"  # bf16 quarters f32-MXU dispatch cost
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    conv_width: int = 4
    hybrid_shared_every: int = 0       # zamba2: shared attn block period
    # modality frontend: 'text' | 'vision_stub' | 'audio_stub'
    frontend: str = "text"
    # numerics / structure
    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    # arch family tag for reporting
    family: str = "dense"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.block_type in ("rwkv6", "mamba2") and self.hybrid_shared_every == 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (see DESIGN.md §5)."""
        return self.block_type in ("rwkv6", "mamba2") or self.attn_window is not None

    def n_params(self) -> int:
        """Analytical parameter count (embedding included once if tied)."""
        d, f, hd = self.d_model, self.d_ff, self.resolved_head_dim
        per_layer = 0
        if self.block_type == "attn":
            per_layer += d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
            if self.qkv_bias:
                per_layer += (self.n_heads + 2 * self.n_kv_heads) * hd
        elif self.block_type == "rwkv6":
            per_layer += 4 * d * d + d * (d // 2)   # r,k,v,g,o-ish + decay lora
        elif self.block_type == "mamba2":
            d_in = 2 * d
            per_layer += d * (2 * d_in + 2 * self.ssm_state) + d_in * d \
                + d_in * self.conv_width
        if self.is_moe:
            per_layer += d * self.n_experts + self.n_experts * (
                (3 if self.glu else 2) * d * f)
        elif self.block_type != "mamba2":   # mamba2 blocks carry no FFN
            per_layer += (3 if self.glu else 2) * d * f
        per_layer += 2 * d  # norms
        total = self.n_layers * per_layer
        if self.hybrid_shared_every:
            # one shared attention+MLP block (weights reused)
            total += d * (self.n_heads * hd) * 2 + 2 * d * (self.n_kv_heads * hd) \
                + (3 if self.glu else 2) * d * self.d_ff
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.is_moe:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense_ffn = self.n_experts * ((3 if self.glu else 2) * d * f)
        active_ffn = self.n_experts_per_tok * ((3 if self.glu else 2) * d * f)
        return self.n_params() - self.n_layers * (dense_ffn - active_ffn)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape (workload cell)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                     # 'train' | 'prefill' | 'decode'
    num_microbatches: int = 1     # train-only: gradient accumulation

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The shape set for an arch, with the documented long_500k skip."""
    base = (TRAIN_4K, PREFILL_32K, DECODE_32K)
    if cfg.sub_quadratic:
        return base + (LONG_500K,)
    return base
