"""Llama-3.2-3B [hf:meta-llama/Llama-3.2-3B]: GQA kv=8, SwiGLU, tied
embeddings, rope theta 500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3_2_3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128256,
    activation="silu", glu=True, rope_theta=500_000.0, tie_embeddings=True,
)
