"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892]: attention-free, data-dependent
decay WKV, token-shift channel-mix FFN (relu^2)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_1_6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,  # heads = d/ssm_head_dim
    d_ff=7168, vocab_size=65536,
    block_type="rwkv6", ssm_head_dim=64, activation="relu2", glu=False,
)
