"""Architecture registry: full assigned configs + reduced smoke variants."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.configs.base import ModelConfig

ARCH_IDS = (
    "codeqwen1_5_7b", "llama3_2_3b", "gemma_7b", "qwen2_72b", "chameleon_34b",
    "rwkv6_1_6b", "zamba2_1_2b", "mixtral_8x7b", "qwen3_moe_30b_a3b",
    "musicgen_large",
)

# CLI-friendly aliases (the assignment table's ids)
ALIASES = {
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "llama3.2-3b": "llama3_2_3b",
    "gemma-7b": "gemma_7b",
    "qwen2-72b": "qwen2_72b",
    "chameleon-34b": "chameleon_34b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "zamba2-1.2b": "zamba2_1_2b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "musicgen-large": "musicgen_large",
}


def get_config(name: str) -> ModelConfig:
    key = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; have {list(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(name)
    reductions: Dict[str, object] = dict(
        n_layers=2,
        d_model=64,
        d_ff=128 if not cfg.is_moe else 32,
        vocab_size=256,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads * 4 // cfg.n_heads, 4)),
        head_dim=16,
        moe_group_size=64,
        remat=False,
    )
    if cfg.is_moe:
        reductions["n_experts"] = min(cfg.n_experts, 8)
        reductions["n_experts_per_tok"] = min(cfg.n_experts_per_tok, 2)
    if cfg.block_type in ("rwkv6", "mamba2"):
        reductions["ssm_head_dim"] = 16
        if cfg.ssm_state:
            reductions["ssm_state"] = 16
    if cfg.hybrid_shared_every:
        reductions["hybrid_shared_every"] = 1
    if cfg.attn_window:
        reductions["attn_window"] = 32
    return dataclasses.replace(cfg, **reductions)  # type: ignore[arg-type]


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
