"""Qwen2-72B [arXiv:2407.10671]: GQA kv=8, QKV bias, SwiGLU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True, activation="silu", glu=True, rope_theta=1_000_000.0,
)
