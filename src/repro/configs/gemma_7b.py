"""Gemma-7B [arXiv:2403.08295]: GeGLU, head_dim=256, kv=16, embeddings
scaled by sqrt(d_model), tied unembedding."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma_7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000,
    activation="gelu", glu=True, tie_embeddings=True, embed_scale=True,
)
