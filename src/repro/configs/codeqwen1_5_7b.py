"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B]: Qwen1.5 arch — MHA (kv=32),
QKV bias, SwiGLU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1_5_7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab_size=92416,
    qkv_bias=True, activation="silu", glu=True, rope_theta=1_000_000.0,
)
