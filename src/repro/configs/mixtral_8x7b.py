"""Mixtral-8x7B [arXiv:2401.04088]: 8 experts top-2, GQA kv=8,
sliding-window attention (4096)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral_8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    n_experts=8, n_experts_per_tok=2, attn_window=4096,
    activation="silu", glu=True, rope_theta=1_000_000.0,
)
