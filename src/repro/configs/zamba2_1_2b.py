"""Zamba2-1.2B [arXiv:2411.15242]: Mamba2 backbone (38 layers, state=64)
with ONE shared attention+MLP block applied every 6th layer (weight reuse)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2_1_2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    block_type="mamba2", ssm_state=64, ssm_head_dim=64, conv_width=4,
    hybrid_shared_every=6, activation="gelu", glu=True,
)
