"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-block quantization applied to gradients before the cross-pod
all-reduce (the lowest-bandwidth axis carries 4x fewer bytes), with an error
feedback accumulator so quantization error is re-injected next step —
convergence-neutral in expectation (Seide et al. 2014; Karimireddy 2019).

Stochastic rounding can be driven by the chaotic PRNG (``rounding='chaotic'``)
— the paper's oscillator used inside the training loop.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
BLOCK = 256


def _quantize_leaf(g: jax.Array, noise: Optional[jax.Array] = None):
    """int8 symmetric per-block quantization. Returns (q, scales)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    scaled = blocks / scale
    if noise is not None:
        scaled = scaled + noise.reshape(scaled.shape) - 0.5   # stochastic
    q = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jax.Array, scale: jax.Array, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_grads(grads: PyTree, error_buf: Optional[PyTree] = None,
                   noise_fn=None) -> Tuple[PyTree, PyTree]:
    """Quantize->dequantize each gradient leaf with error feedback.

    Returns (compensated_grads, new_error_buf).  In a real deployment the
    int8 payload crosses the pod axis; here the quantize/dequantize pair is
    applied in-graph so the optimizer sees exactly what compressed training
    would see (and the collective-bytes accounting in the roofline reads the
    int8 operand sizes when enabled in the train step).
    """
    if error_buf is None:
        error_buf = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        noise = noise_fn(g32.size) if noise_fn is not None else None
        q, s = _quantize_leaf(g32, noise)
        deq = _dequantize_leaf(q, s, g.shape, jnp.float32)
        new_e = g32 - deq
        return deq.astype(g.dtype), new_e

    pairs = jax.tree.map(one, grads, error_buf)
    comp = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    errs = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return comp, errs
