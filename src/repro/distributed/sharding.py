"""Deterministic sharding planner: TP + FSDP(+pod-DP) PartitionSpecs for any
param tree, divisibility-safe per architecture.

Axis roles on the production mesh (see launch/mesh.py):
  - 'model'          : tensor parallelism (Megatron column/row split)
  - 'data' (+ 'pod') : data parallelism for activations AND FSDP sharding of
                       params/optimizer state (ZeRO-3 via GSPMD: params carry
                       a data-axis dim in their spec; XLA inserts the
                       per-layer all-gather in fwd and reduce-scatter in bwd)

Rules are path-pattern based (Megatron conventions: column-parallel in
wq/wk/wv/wi/wg, row-parallel in wo), with a generic fallback; every axis
assignment is divisibility-checked against the actual dim and dropped when
it does not divide (e.g. mixtral's 8 experts never shard over a 16-way axis,
llama3.2's 24 q-heads are shared via the flattened 3072 dim instead).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Axis-role view of a mesh."""

    mesh: Mesh
    tp_axis: str = "model"
    dp_axes: Tuple[str, ...] = ("data",)      # includes 'pod' when present
    sequence_parallel: bool = False

    @classmethod
    def from_mesh(cls, mesh: Mesh, sequence_parallel: bool = False) -> "MeshSpec":
        names = mesh.axis_names
        dp = tuple(a for a in ("pod", "data") if a in names)
        return cls(mesh=mesh, tp_axis="model" if "model" in names else names[-1],
                   dp_axes=dp, sequence_parallel=sequence_parallel)

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    def dp_spec_for(self, dim: int) -> Optional[Tuple[str, ...]]:
        """Largest prefix-product combination of dp axes that divides dim."""
        # try full ('pod','data'), then single axes largest-first
        candidates: List[Tuple[str, ...]] = []
        if len(self.dp_axes) > 1:
            candidates.append(tuple(self.dp_axes))
        candidates.extend((a,) for a in sorted(
            self.dp_axes, key=lambda a: -self.mesh.shape[a]))
        for cand in candidates:
            size = int(np.prod([self.mesh.shape[a] for a in cand]))
            if dim % size == 0:
                return cand
        return None


# Param rules: (path regex, spec template applied to trailing dims).
# Template entries: 'tp', 'fsdp', None.  A leading layer-stack dim (when leaf
# ndim exceeds the template length) is always unsharded.
_PARAM_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    (r"embed/embedding$", ("tp", "fsdp")),          # (V, D) vocab-parallel
    (r"embed/unembed$", ("fsdp", "tp")),            # (D, V)
    (r"attn/w[qkv]$", ("fsdp", "tp")),              # column-parallel
    (r"attn/wo$", ("tp", "fsdp")),                  # row-parallel
    (r"attn/b[qkv]$", ("tp",)),
    (r"(ffn|mlp)/w[ig]$", ("fsdp", "tp")),
    (r"(ffn|mlp)/wo$", ("tp", "fsdp")),
    (r"ffn/w[kv]$", ("fsdp", "tp")),                # rwkv channel-mix
    (r"moe/router$", ("fsdp", None)),               # (D, E): E stays whole
    (r"moe/w[ig]$", ("exp", "fsdp", "tp")),         # (E, D, F)
    (r"moe/wo$", ("exp", "tp", "fsdp")),            # (E, F, D)
    (r"rwkv/w[rkvgo]$", ("fsdp", "tp")),
    (r"rwkv/(mix_lora_a|decay_lora_a)$", ("fsdp", None)),
    (r"rwkv/mix_lora_b$", (None, None, "tp")),
    (r"rwkv/decay_lora_b$", (None, "tp")),
    (r"rwkv/bonus$", (None, None)),
    (r"mamba/in_proj$", ("fsdp", "tp")),
    (r"mamba/out_proj$", ("tp", "fsdp")),
    (r"mamba/conv$", (None, "tp")),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _assign(template: Tuple[Optional[str], ...], shape: Tuple[int, ...],
            spec: MeshSpec, n_layers_hint: int) -> P:
    ndim = len(shape)
    # right-align the template; leading (layer-stack) dims unsharded
    lead = ndim - len(template)
    entries: List[Any] = [None] * ndim
    used_exp_axes: Tuple[str, ...] = ()
    for i, role in enumerate(template):
        dim = shape[lead + i]
        if role == "tp":
            if dim % spec.tp_size == 0:
                entries[lead + i] = spec.tp_axis
        elif role == "exp":
            # expert dim: shard over dp axes when divisible (expert parallel)
            axes = spec.dp_spec_for(dim)
            if axes:
                entries[lead + i] = axes if len(axes) > 1 else axes[0]
                used_exp_axes = axes
        elif role == "fsdp":
            axes = tuple(a for a in spec.dp_axes if a not in used_exp_axes)
            if axes:
                size = int(np.prod([spec.mesh.shape[a] for a in axes]))
                if dim % size == 0:
                    entries[lead + i] = axes if len(axes) > 1 else axes[0]
                else:  # fall back to single largest dividing axis
                    for a in sorted(axes, key=lambda a: -spec.mesh.shape[a]):
                        if dim % spec.mesh.shape[a] == 0:
                            entries[lead + i] = a
                            break
    return P(*entries)


def _generic_spec(shape: Tuple[int, ...], spec: MeshSpec,
                  n_layers_hint: int) -> P:
    """Fallback: TP on the last divisible of the trailing two dims, FSDP on
    the largest remaining divisible dim.  Vectors replicate."""
    ndim = len(shape)
    if ndim <= 1 or max(shape) < 128:
        return P()
    entries: List[Any] = [None] * ndim
    start = 1 if (ndim >= 3 and shape[0] == n_layers_hint) else 0
    for i in (ndim - 1, ndim - 2):
        if i >= start and shape[i] % spec.tp_size == 0:
            entries[i] = spec.tp_axis
            break
    remaining = [i for i in range(start, ndim) if entries[i] is None]
    for i in sorted(remaining, key=lambda i: -shape[i]):
        axes = spec.dp_spec_for(shape[i])
        if axes:
            entries[i] = axes if len(axes) > 1 else axes[0]
            break
    return P(*entries)


def plan_params(params_shape: PyTree, spec: MeshSpec,
                n_layers_hint: int = -1) -> PyTree:
    """PartitionSpec tree for a param tree (of ShapeDtypeStructs or arrays)."""

    def leaf_spec(path, leaf) -> P:
        pstr = _path_str(path)
        shape = tuple(leaf.shape)
        for pattern, template in _PARAM_RULES:
            if re.search(pattern, pstr):
                if len(shape) < len(template):
                    # unstacked variant (e.g. shared block, no L dim)
                    template = template[len(template) - len(shape):]
                return _assign(template, shape, spec, n_layers_hint)
        return _generic_spec(shape, spec, n_layers_hint)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def plan_batch(batch_shape: PyTree, spec: MeshSpec) -> PyTree:
    """Batch arrays: shard the leading (batch) dim over dp axes."""

    def leaf_spec(leaf) -> P:
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        axes = spec.dp_spec_for(shape[0])
        if axes is None:
            return P()
        first = axes if len(axes) > 1 else axes[0]
        return P(first, *([None] * (len(shape) - 1)))

    return jax.tree.map(leaf_spec, batch_shape)


def plan_decode_state(state_shape: PyTree, spec: MeshSpec,
                      n_layers_hint: int = -1,
                      attn_kv_shard: str = "head") -> PyTree:
    """Cache/state trees: dp on batch dim, tp on a trailing divisible dim.

    Leaves look like (L, B, S, KV, HD) / (L, B, H, K, V) / (L, B, W, C);
    the batch dim is index 1 when a leading layer-stack dim is present.

    ``attn_kv_shard``:
      'head': shard the KV cache on head_dim (naive; the attention einsum
        contracts head_dim, which forces the SPMD partitioner into a
        full-cache replication per layer — see EXPERIMENTS.md §Perf C-cell)
      'seq': shard the KV cache along the sequence dim over the tp axis —
        scores are computed shard-locally, softmax reduces with a small
        all-reduce, and the cache is never re-materialized.
    """

    def leaf_spec(path, leaf) -> P:
        pstr = _path_str(path)
        shape = tuple(leaf.shape)
        if not shape or leaf.dtype == np.int32 and not shape:
            return P()
        if len(shape) <= 1:
            return P()
        entries: List[Any] = [None] * len(shape)
        b_idx = 1 if len(shape) >= 3 else 0
        axes = spec.dp_spec_for(shape[b_idx])
        if axes:
            entries[b_idx] = axes if len(axes) > 1 else axes[0]
        is_attn_kv = re.search(r"(^|/)(k|v)$", pstr) and len(shape) >= 4
        if is_attn_kv and attn_kv_shard == "seq":
            s_idx = b_idx + 1                      # (L, B, S, KV, HD)
            if shape[s_idx] % spec.tp_size == 0:
                entries[s_idx] = spec.tp_axis
                return P(*entries)
        # tp on the last trailing dim (after batch) that divides; prefer
        # later dims (head_dim / channels)
        for i in range(len(shape) - 1, b_idx, -1):
            if shape[i] % spec.tp_size == 0:
                entries[i] = spec.tp_axis
                break
        return P(*entries)

    return jax.tree_util.tree_map_with_path(leaf_spec, state_shape)


# ---------------------------------------------------------------------------
# Activation constraint factory (the model's shard_fn)
# ---------------------------------------------------------------------------

def make_shard_fn(spec: MeshSpec):
    """Returns shard_fn(tag, x) applying with_sharding_constraint by tag."""
    dp = spec.dp_axes if len(spec.dp_axes) > 1 else (
        spec.dp_axes[0] if spec.dp_axes else None)

    def shard_fn(tag: str, x):
        if x.ndim == 3:
            if tag == "logits":
                s = P(dp, None, spec.tp_axis if x.shape[-1] % spec.tp_size == 0 else None)
            elif spec.sequence_parallel and tag in ("activation", "residual") \
                    and x.shape[1] % spec.tp_size == 0:
                s = P(dp, spec.tp_axis, None)
            else:
                s = P(dp, None, None)
        elif x.ndim == 2:
            s = P(dp, None)
        else:
            return x
        # drop dp if batch not divisible (e.g. long_500k batch=1)
        if dp is not None and s[0] is not None:
            dp_size = spec.dp_size if isinstance(dp, tuple) else spec.mesh.shape[dp]
            if x.shape[0] % dp_size != 0:
                s = P(None, *s[1:])
        return jax.lax.with_sharding_constraint(x, NamedSharding(spec.mesh, s))

    return shard_fn


def named(spec: MeshSpec, pspec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(spec.mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def shard_stream_pool(fn, mesh: Mesh, axis: str = "data"):
    """shard_map a PRNG stream-pool launch over the stream (lane) axis.

    ``fn(x, offsets) -> (words, state)`` with x (S, I), offsets (S,),
    words (rows, S), state (S, I).  Oscillator streams are embarrassingly
    parallel (each lane evolves independently), so partitioning S across
    devices is exact — each device runs the fused kernel on its shard and
    the words gather back on the lane axis.  The mesh axis size must
    divide S.
    """
    from jax.experimental.shard_map import shard_map

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis, None), P(axis)),
        out_specs=(P(None, axis), P(axis, None)),
        check_rep=False)


def strip_dp_axes(pspec_tree: PyTree, spec: MeshSpec) -> PyTree:
    """Remove dp (FSDP) axes from every PartitionSpec — TP-only layout.

    Serving wants this: FSDP params would be all-gathered on EVERY decode
    step; TP-only replicates each shard across the data axis once."""
    dp = set(spec.dp_axes)

    def strip(s: P) -> P:
        entries = []
        for e in tuple(s):
            if e is None:
                entries.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a not in dp)
                entries.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            else:
                entries.append(None if e in dp else e)
        return P(*entries)

    return jax.tree.map(strip, pspec_tree, is_leaf=lambda x: isinstance(x, P))
