"""Deterministic fault injection for the serving tier (chaos harness).

The paper's framework ships a validation testbench with every generated
core because a chaotic oscillator that drifts off its attractor silently
emits garbage; the serving-tier analogue is that a farm must be *driven*
through launch failures and quality collapses in tests, not just proven
correct on the happy path.  ``FaultPlan`` is that driver: a seeded,
replayable schedule of injected faults that hooks the farm's launch seam
(``OscillatorFarm(faults=...)``) and its quality-monitoring seam
(``attach_monitor``):

* **transient launch failures** — each group launch fails with
  probability ``transient_rate`` (seeded RNG, so a plan replays the
  identical schedule), raising a typed :class:`InjectedFault` carrying
  the affected core names *before* any kernel work or ``absorb()``
  bookkeeping runs.  A retried flush therefore re-launches the failed
  group at the same absolute stream rows — bit-identity is preserved by
  construction;
* **persistent launch failures** — cores in ``persistent_cores`` fail
  every launch until quarantined (the circuit-breaker drill);
* **poisoned quality** — cores in ``poison`` have the words *sampled
  for the health monitor* corrupted (low half of every word zeroed, a
  catastrophic monobit failure), modeling an attractor-drift quality
  collapse at the monitoring seam while delivery stays deterministic.
  Poisoning is bound to the physical service active when monitoring
  attached (``bind``): a standby rotated into the slot samples clean;
* **flush delays** — ``delay_flush_s`` advances an injected
  ``FakeClock`` at every flush, so duration-dependent accounting
  (adaptive ceilings, profile timers) is testable with zero real
  sleeps.  No-op under a real clock — benchmarks inject real latency
  with their own ``_SlowFlush`` wrapper instead.

Everything is pure bookkeeping on the caller's thread; a ``FaultPlan``
never sleeps and never reads wall time, so the whole chaos suite runs
under a ``FakeClock`` (tests/test_resilience.py).  ``active`` arms the
plan: benchmarks measure before/during/after a storm by toggling it.
"""
from __future__ import annotations

import random
from typing import Dict, Iterable, Optional, Sequence

import numpy as np

# low 16 bits of every sampled word zeroed: the monitor's monobit gate
# sees ~25% ones — a p-value far below ALPHA_HARD within one window
_POISON_MASK = np.uint32(0xFFFF0000)


class InjectedFault(RuntimeError):
    """A launch failed by plan.  ``cores`` names the affected group
    members (the supervision layer attributes the failure with it);
    ``persistent`` distinguishes the breaker drill from transient noise.
    """

    def __init__(self, message: str, *, cores: Sequence[str] = (),
                 persistent: bool = False):
        super().__init__(message)
        self.cores = tuple(cores)
        self.persistent = bool(persistent)


class FaultPlan:
    """A seeded, replayable fault schedule (see module docstring).

    Parameters
    ----------
    seed
        Seeds the transient-failure schedule; two plans with the same
        seed inject the identical fault sequence for the identical
        launch sequence.
    transient_rate
        Probability each group launch fails transiently (0 disables).
    transient_cores
        Restrict transient failures to launches containing one of these
        cores (``None`` = any launch is eligible).
    max_transients
        Cap on injected transient failures (``None`` = unbounded).
    persistent_cores
        Cores whose every launch fails until the farm quarantines them.
    poison
        Cores whose *monitor samples* are corrupted (delivered words are
        untouched — see module docstring).
    delay_flush_s
        Advance the bound ``FakeClock`` by this much at each flush.
    """

    def __init__(self, *, seed: int = 0, transient_rate: float = 0.0,
                 transient_cores: Optional[Iterable[str]] = None,
                 max_transients: Optional[int] = None,
                 persistent_cores: Iterable[str] = (),
                 poison: Iterable[str] = (),
                 delay_flush_s: float = 0.0,
                 clock=None):
        if not 0.0 <= float(transient_rate) <= 1.0:
            raise ValueError(
                f"transient_rate must be in [0, 1], got {transient_rate}")
        self._rng = random.Random(seed)
        self.transient_rate = float(transient_rate)
        self.transient_cores = (None if transient_cores is None
                                else frozenset(transient_cores))
        self.max_transients = (None if max_transients is None
                               else int(max_transients))
        self.persistent_cores = set(persistent_cores)
        self.poison = frozenset(poison)
        self.delay_flush_s = float(delay_flush_s)
        self.clock = clock
        self.active = True
        self._poisoned_id: Dict[str, int] = {}
        self.injected = {"transient": 0, "persistent": 0,
                         "corrupted_samples": 0, "delays": 0}

    # -- arming --------------------------------------------------------------

    def arm(self) -> None:
        self.active = True

    def disarm(self) -> None:
        self.active = False

    # -- launch seam ---------------------------------------------------------

    def on_launch(self, cores: Sequence[str]) -> None:
        """Called by the farm before each group/solo launch does any
        work; raises :class:`InjectedFault` when the plan says so."""
        if not self.active:
            return
        bad = sorted(self.persistent_cores.intersection(cores))
        if bad:
            self.injected["persistent"] += 1
            raise InjectedFault(
                f"injected persistent launch failure on {bad}",
                cores=bad, persistent=True)
        if self.transient_rate <= 0.0:
            return
        if (self.transient_cores is not None
                and not self.transient_cores.intersection(cores)):
            return
        if (self.max_transients is not None
                and self.injected["transient"] >= self.max_transients):
            return
        # one seeded draw per launch, whether or not it fails: the
        # schedule depends only on the launch sequence, not on outcomes
        if self._rng.random() < self.transient_rate:
            self.injected["transient"] += 1
            raise InjectedFault(
                f"injected transient launch failure on {sorted(cores)}",
                cores=sorted(cores), persistent=False)

    # -- flush seam ----------------------------------------------------------

    def on_flush(self) -> None:
        """Advance the bound FakeClock by ``delay_flush_s`` (no-op under
        a real clock — duration injection there is the caller's job)."""
        if (self.active and self.delay_flush_s > 0.0
                and self.clock is not None
                and hasattr(self.clock, "advance")):
            self.clock.advance(self.delay_flush_s)
            self.injected["delays"] += 1

    # -- quality seam --------------------------------------------------------

    def bind(self, core: str, service) -> None:
        """Pin poisoning to the physical service active when monitoring
        attached: the FIRST service bound to a poisoned core name is the
        bad one, and a standby rotated into the slot samples clean."""
        if core in self.poison and core not in self._poisoned_id:
            self._poisoned_id[core] = id(service)

    def corrupt_sample(self, core: str, service,
                       words: np.ndarray) -> np.ndarray:
        """Corrupt a monitor sample iff ``service`` is the poisoned
        physical core for ``core``.  Delivered words are never touched —
        only what the health monitor sees."""
        if not self.active or self._poisoned_id.get(core) != id(service):
            return words
        self.injected["corrupted_samples"] += 1
        return np.asarray(words, np.uint32) & _POISON_MASK

    def heal(self, core: str) -> None:
        """Drop all faults targeting ``core`` (storm-recovery phases)."""
        self.persistent_cores.discard(core)
        self._poisoned_id.pop(core, None)
