"""Crash recovery for the serving tier: an append-only flush journal.

``OscillatorFarm.snapshot()`` is the *explicit* resumability surface —
somebody has to call it, serialize it, and put it somewhere.  A crash
asks for the implicit version: the front-end appends one small record per
**completed flush** (plus one per client registration) to an append-only
JSONL file, and a restarted farm replays the journal to bit-exact stream
positions without any of the crashed process's memory.

What makes tiny records sufficient is the engine's determinism contract:
a client's word stream depends only on (weights, seed, lanes_per_client,
kernel config) plus its absolute word-row counter.  So the journal never
stores words or pool state — only each client's *position*:

    {"type": "flush", "seq": 7, "cores": {core: {client:
        [row, pending, buf_words, outbox_words]}}}

Recovery (:func:`replay_journal`) re-registers every journaled client
(same seed => same burn-in => same lane state), then recomputes each
client's lanes forward to ``row`` with the same fused kernel — the words
regenerated along the way rebuild the undelivered tail (service buffer +
outbox) bit-exactly, because chunk-invariant absolute-row indexing makes
one big replay launch identical to however many launches the crashed
process actually issued (``PRNGService.replay_client``).

Durability contract (tests/test_journal.py proves the kill window):

* a record is appended (and by default fsync'd) only *after* its flush
  fully absorbed — a crash mid-flush recovers to the previous flush
  boundary, and the words of the interrupted flush are regenerated, not
  lost and not double-served;
* requests queued in the front-end but not yet flushed are NOT journaled
  — they failed with the crash and the tenant retries (the same contract
  a deadline timeout gives);
* a torn final line (crash mid-append) is detected and ignored on
  replay;
* every record carries a CRC32; damage in the MIDDLE of the file (bit
  rot, an outside writer) raises :class:`JournalCorrupt` at the exact
  record instead of silently replaying a suspect suffix —
  ``python -m repro.serve.journal <path> --repair`` truncates to the
  last good prefix;
* one journal belongs to ONE live process: an exclusive flock on a
  ``<path>.lock`` sidecar (with a ``pid@host`` sentinel) makes a second
  writer fail fast with :class:`JournalLocked` instead of interleaving
  records.

The journal also records **topology events** — ``record_quarantine`` /
``record_rotation`` from the supervision layer (``repro.serve.health``)
— so a kill-and-replay reconstructs the crashed process's *degraded*
topology (quarantined cores, standbys rotated into slots), not just its
stream positions.

**Compaction/rotation** (``rotate_every=N``): replaying positions alone
recomputes every stream from row 0, so replay cost grows with absolute
position forever.  After every N journaled flushes the journal rotates:
the live JSONL is renamed aside (``<path>.<seq>``, an immutable audit
segment) and a fresh segment opens with a **checkpoint** record — the
full ``farm.snapshot()`` (pool states, client counters, buffers,
outboxes, device topology), ndarray-encoded.  Recovery then restores the
checkpoint directly and replays only the <= N flush deltas after it, so
``replay_journal`` cost is bounded by the rotation window no matter how
long the process ran.  The rotation itself is crash-safe: the new
segment (checkpoint included) is written and fsync'd to a temp file
before any rename, and both renames are atomic — a crash at any point
leaves either the old segment or the checkpointed new one discoverable.

Every flush record (and checkpoint) also carries the farm's device
topology, so replaying onto a different device count is an *explicit*
decision (``on_topology_mismatch``), never a silent reuse — positions
are device-count-invariant, but the operator must say so.
"""
from __future__ import annotations

import base64
import json
import os
import pathlib
import socket
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.clock import Clock, SystemClock

try:                         # POSIX advisory locks (single-writer fence)
    import fcntl
except ImportError:          # non-POSIX: the fence degrades to advisory-only
    fcntl = None

_VERSION = 1


class JournalLocked(RuntimeError):
    """Another live process holds this journal's writer lock.

    Two writers appending to one journal interleave records and corrupt
    the recovery story silently; the lock makes the second ``open`` fail
    fast instead.  ``holder`` is the ``pid@host`` sentinel the owning
    process wrote (stale-looking sentinels still mean a LIVE owner — the
    flock, not the sentinel, is the authority, and flocks die with their
    process)."""

    def __init__(self, message: str, *, holder: str = "unknown"):
        super().__init__(message)
        self.holder = holder


class JournalCorrupt(RuntimeError):
    """A record in the MIDDLE of the journal fails its CRC or does not
    parse — unlike a torn final line (a crash mid-append, expected and
    tolerated), mid-file damage means bit rot or an outside writer, and
    everything after the damage is suspect.  ``line_no`` (1-based) is
    the damaged line; ``seq`` is the last flush seq known good before
    it.  ``python -m repro.serve.journal <path> --repair`` truncates to
    the last good prefix."""

    def __init__(self, message: str, *, line_no: int, seq: int):
        super().__init__(message)
        self.line_no = int(line_no)
        self.seq = int(seq)


def _crc_of(rec: Dict) -> int:
    """CRC32 of a record's canonical form (sorted keys, no whitespace) —
    key order on disk never affects the checksum."""
    payload = json.dumps(rec, sort_keys=True, separators=(",", ":"))
    return zlib.crc32(payload.encode("utf-8"))


def _check_line(line: str):
    """Parse + CRC-verify one journal line; returns the record (crc field
    removed).  Raises ValueError/json.JSONDecodeError on damage.
    Records without a crc field (pre-PR-9 journals) are accepted."""
    rec = json.loads(line)
    if not isinstance(rec, dict):
        raise ValueError(f"journal line is not an object: {line[:80]!r}")
    crc = rec.pop("crc", None)
    if crc is not None and _crc_of(rec) != crc:
        raise ValueError("journal record crc mismatch")
    return rec


def _farm_topology(farm) -> Dict[str, object]:
    from repro.serve.farm import _topology
    return {core: _topology(svc) for core, svc in farm.services.items()}


def _encode(obj):
    """JSON-encode a snapshot tree: ndarrays become base64 blobs (exact
    bytes — bf16 pools and uint32 buffers round-trip bit-identically)."""
    if isinstance(obj, np.ndarray):
        return {"__nd__": str(obj.dtype), "shape": list(obj.shape),
                "b64": base64.b64encode(
                    np.ascontiguousarray(obj).tobytes()).decode("ascii")}
    if isinstance(obj, dict):
        return {k: _encode(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set)):
        return [_encode(v) for v in sorted(obj)] if isinstance(obj, set) \
            else [_encode(v) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    return obj


def _decode(obj):
    if isinstance(obj, dict):
        if "__nd__" in obj:
            a = np.frombuffer(base64.b64decode(obj["b64"]),
                              dtype=np.dtype(obj["__nd__"]))
            return a.reshape(obj["shape"]).copy()
        return {k: _decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v) for v in obj]
    return obj


def farm_positions(farm) -> Dict[str, Dict[str, List[int]]]:
    """Per-client stream positions of a farm right now:
    ``{core: {client: [row, pending, buf_words, outbox_words]}}``."""
    out: Dict[str, Dict[str, List[int]]] = {}
    for core, svc in farm.services.items():
        per = {}
        for c in svc.clients.values():
            per[c.name] = [int(c.row), int(c.pending), int(len(c.buf)),
                           int(svc.outbox_words(c.name))]
        out[core] = per
    return out


class FlushJournal:
    """Append-only JSONL journal of client registrations + flush positions.

    One journal belongs to one serving process; attach it to an
    ``AsyncOscillatorFarm(journal=...)`` and it records automatically.
    ``fsync=True`` (default) makes each record durable before the writer
    returns — the crash-recovery guarantee costs one fsync per flush, not
    per request.  An existing file is appended to (seq continues), so a
    recovered process can keep journaling into the same file.

    ``rotate_every=N`` bounds replay cost: after N flush records the
    live file is rotated aside and the new segment opens with a full
    ``farm.snapshot()`` checkpoint (see the module docstring).  The
    rotated segments (``<path>.<seq>``) are never read by recovery —
    they are the audit trail; delete them on whatever retention schedule
    suits.
    """

    def __init__(self, path: str | os.PathLike, *, fsync: bool = True,
                 clock: Optional[Clock] = None,
                 rotate_every: Optional[int] = None):
        self.path = pathlib.Path(path)
        self.fsync = bool(fsync)
        self.clock: Clock = clock or SystemClock()
        if rotate_every is not None and int(rotate_every) < 1:
            raise ValueError(f"rotate_every must be >= 1, got {rotate_every}")
        self.rotate_every = None if rotate_every is None else int(rotate_every)
        self.rotations = 0
        self.seq = 0
        self._segment_flushes = 0
        self._lock_f = None
        self._acquire_writer_lock()
        try:
            tmp = self._tmp_path()
            if not self.path.exists() and tmp.exists():
                # a crash landed between the two rotation renames: the
                # fsync'd checkpointed segment is complete — finish the
                # rotation
                os.replace(tmp, self.path)
            if self.path.exists():
                _, last_seq, _, _, ckpt = read_journal(self.path)
                self.seq = last_seq
                self._segment_flushes = last_seq - (
                    int(ckpt["seq"]) if ckpt is not None else 0)
            self._f = open(self.path, "a", encoding="utf-8")
        # repro: allow[broad-except] reason=release-and-reraise: the flock must not leak when the scan of an existing (possibly corrupt) journal fails; nothing is swallowed
        except BaseException:
            self._release_writer_lock()
            raise
        if self.seq == 0 and self._f.tell() == 0:
            self._append({"type": "open", "v": _VERSION})

    def _acquire_writer_lock(self) -> None:
        """Single-writer fence: an exclusive flock on a persistent
        ``<path>.lock`` sidecar, held for this journal's lifetime.  A
        second process opening the same journal fails fast with
        :class:`JournalLocked` naming the holder.  The flock (not the
        sidecar's existence) is the authority: it evaporates with the
        owning process, so a crashed writer never wedges recovery."""
        if fcntl is None:
            return
        lock_path = self.path.with_name(self.path.name + ".lock")
        f = open(lock_path, "a+", encoding="utf-8")
        try:
            fcntl.flock(f.fileno(), fcntl.LOCK_EX | fcntl.LOCK_NB)
        except OSError:
            f.seek(0)
            holder = f.read().strip() or "unknown"
            f.close()
            raise JournalLocked(
                f"journal {self.path} is already open for writing by "
                f"{holder}; one journal belongs to one serving process",
                holder=holder)
        f.seek(0)
        f.truncate()
        f.write(f"{os.getpid()}@{socket.gethostname()}\n")
        f.flush()
        self._lock_f = f

    def _release_writer_lock(self) -> None:
        if self._lock_f is not None:
            try:
                if fcntl is not None:
                    fcntl.flock(self._lock_f.fileno(), fcntl.LOCK_UN)
            finally:
                self._lock_f.close()
                self._lock_f = None

    def _tmp_path(self) -> pathlib.Path:
        return self.path.with_name(self.path.name + ".rotate-tmp")

    def _append(self, rec: Dict, f=None) -> None:
        f = f if f is not None else self._f
        rec["ts"] = self.clock.time()
        rec["crc"] = _crc_of(rec)    # over everything else, ts included
        f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        f.flush()
        if self.fsync:
            os.fsync(f.fileno())

    def record_register(self, core: str, client: str, seed: int) -> None:
        """Journal one client registration (the seed actually used, so
        replay re-derives the identical burn-in state)."""
        self._append({"type": "register", "core": core, "client": client,
                      "seed": int(seed)})

    def record_flush(self, farm) -> None:
        """Journal the post-flush position of every client (call only
        after the flush fully absorbed + delivered).  Triggers a rotation
        once ``rotate_every`` flushes accumulated in this segment."""
        self.seq += 1
        self._append({"type": "flush", "seq": self.seq,
                      "cores": farm_positions(farm),
                      "topology": _farm_topology(farm)})
        self._segment_flushes += 1
        if (self.rotate_every is not None
                and self._segment_flushes >= self.rotate_every):
            self._rotate(farm)

    def _rotate(self, farm) -> None:
        """Seal the live segment and start a new one from a checkpoint.

        Crash-safe ordering: the checkpoint is durably on disk in the temp
        segment BEFORE the live file is renamed aside, and both renames
        are atomic — at every instant either ``path`` or
        ``path.rotate-tmp`` holds a replayable journal (``__init__`` and
        ``replay_journal`` both pick up the temp file).
        """
        tmp = self._tmp_path()
        with open(tmp, "w", encoding="utf-8") as f:
            self._append({"type": "checkpoint", "seq": self.seq,
                          "v": _VERSION,
                          "snapshot": _encode(farm.snapshot())}, f=f)
        self._f.close()
        os.replace(self.path, self.path.with_name(
            f"{self.path.name}.{self.seq:08d}"))
        os.replace(tmp, self.path)
        self._f = open(self.path, "a", encoding="utf-8")
        self._segment_flushes = 0
        self.rotations += 1

    def record_quarantine(self, core: str, reason: str = "") -> None:
        """Journal a core quarantine (part of the degraded topology a
        replay must reconstruct)."""
        self._append({"type": "quarantine", "core": core,
                      "reason": str(reason)})

    def record_rotation(self, core: str) -> None:
        """Journal a standby rotation into ``core``'s routing slot (replay
        re-performs it — the recovering farm must attach the same
        standby)."""
        self._append({"type": "rotation", "core": core})

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()
        self._release_writer_lock()

    def __enter__(self) -> "FlushJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_journal(path: str | os.PathLike) -> Tuple[
        List[Tuple], int,
        Optional[Dict[str, Dict[str, List[int]]]], bool, Optional[Dict]]:
    """Parse one journal segment: (events in order, last flush seq, last
    flush positions or None, torn_tail, checkpoint or None).

    ``events`` is the ordered topology history replay must re-apply:
    ``("register", core, client, seed)``, ``("quarantine", core,
    reason)``, ``("rotation", core)`` — order matters (a client
    registered before a rotation rides the standby; one registered after
    starts there).

    A rotated segment opens with a checkpoint record; its decoded farm
    snapshot and seq come back as ``checkpoint``, and the events list
    then covers only what happened *after* it (earlier topology lives
    inside the snapshot, restored wholesale).

    Every record carries a CRC32 over its canonical JSON form.  A
    truncated or mismatching FINAL line (the crash landed mid-append) is
    ignored and reported via ``torn_tail`` — every complete record
    before it is still recovered.  A damaged MID-FILE record is a
    different animal (bit rot / outside writer — everything after it is
    suspect) and raises :class:`JournalCorrupt` naming the exact line
    and the last good flush seq; ``python -m repro.serve.journal <path>
    --repair`` truncates to the good prefix.
    """
    events: List[Tuple] = []
    last_seq, last_pos, torn, ckpt = 0, None, False, None
    rotated_since_flush: set = set()
    data = pathlib.Path(path).read_bytes().decode("utf-8", errors="replace")
    lines = data.split("\n")
    # a well-formed journal ends with "\n": the final split element is ""
    if lines and lines[-1] == "":
        lines.pop()
    elif lines:
        torn = True
        lines.pop()
    for i, line in enumerate(lines):
        try:
            rec = _check_line(line)
        except (json.JSONDecodeError, ValueError) as e:
            if i == len(lines) - 1:
                # damaged FINAL record: crash mid-append, tolerated
                torn = True
                break
            raise JournalCorrupt(
                f"journal {path} record {i + 1} is damaged mid-file "
                f"({e}); last good flush seq {last_seq} — run "
                f"`python -m repro.serve.journal {path} --repair` to "
                f"truncate to the good prefix",
                line_no=i + 1, seq=last_seq)
        t = rec.get("type")
        if t == "register":
            events.append(("register", rec["core"], rec["client"],
                           int(rec["seed"])))
        elif t == "quarantine":
            events.append(("quarantine", rec["core"],
                           str(rec.get("reason", ""))))
        elif t == "rotation":
            events.append(("rotation", rec["core"]))
            rotated_since_flush.add(rec["core"])
        elif t == "flush":
            last_seq = int(rec["seq"])
            last_pos = rec["cores"]
            rotated_since_flush.clear()
        elif t == "checkpoint":
            ckpt = {"seq": int(rec["seq"]),
                    "snapshot": _decode(rec["snapshot"])}
            last_seq = max(last_seq, ckpt["seq"])
    if last_pos is not None and rotated_since_flush:
        # a core rotated AFTER the last flush record: those positions
        # describe the replaced service, not the standby now in the slot
        # (whose re-registered clients sit at row 0) — drop them so
        # replay never advances the standby to the dead core's rows
        last_pos = {c: p for c, p in last_pos.items()
                    if c not in rotated_since_flush}
    return events, last_seq, last_pos, torn, ckpt


def repair_journal(path: str | os.PathLike) -> Dict[str, int]:
    """Truncate a journal to its last good prefix (the mid-file-damage
    recovery tool behind ``JournalCorrupt``).

    Validates every line's CRC in order and atomically rewrites the file
    to contain exactly the records before the first damaged one (via a
    temp file + ``os.replace`` — a crash mid-repair leaves the original
    untouched).  Returns ``{"kept": N, "dropped": M}`` in records.  A
    journal with no damage is left byte-identical (dropped == 0).
    """
    path = pathlib.Path(path)
    data = path.read_bytes().decode("utf-8", errors="replace")
    lines = data.split("\n")
    trailing_nl = bool(lines) and lines[-1] == ""
    if lines and lines[-1] == "":
        lines.pop()
    good = 0
    for line in lines:
        try:
            _check_line(line)
        except (json.JSONDecodeError, ValueError):
            break
        good += 1
    dropped = len(lines) - good
    if dropped == 0 and trailing_nl:
        return {"kept": good, "dropped": 0}
    tmp = path.with_name(path.name + ".repair-tmp")
    with open(tmp, "w", encoding="utf-8") as f:
        for line in lines[:good]:
            f.write(line + "\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return {"kept": good, "dropped": dropped}


def replay_journal(farm, path: str | os.PathLike,
                   chunk_rows: int = 4096, *,
                   on_topology_mismatch: str = "refuse"
                   ) -> Dict[str, object]:
    """Rebuild a crashed serving process's stream positions onto ``farm``.

    ``farm`` must have the same cores attached (same weights/configs —
    e.g. rebuilt via ``OscillatorFarm.from_generated`` or the weight
    registry) and **no clients registered yet**.  Every journaled client
    is re-registered with its journaled seed, then advanced to its last
    flushed position with ``PRNGService.replay_client`` — after which
    every stream continues bit-exactly where the crashed process left
    off, including words that were generated but still undelivered
    (service buffer + outbox).

    A rotated journal opens with a checkpoint: the farm snapshot is
    restored directly (``on_topology_mismatch`` passes through to
    ``OscillatorFarm.restore`` — a checkpoint taken on a different
    device count refuses unless you say ``"replan"``) and only the flush
    deltas after it are recomputed, so replay cost is bounded by the
    rotation window, not absolute stream position.

    Returns a summary: flushes recovered, clients replayed, word rows
    recomputed (post-checkpoint deltas only), the checkpoint seq (0 when
    the segment has none), and whether a torn tail record was discarded.
    """
    path = pathlib.Path(path)
    tmp = path.with_name(path.name + ".rotate-tmp")
    if not path.exists() and tmp.exists():
        path = tmp       # crash between the rotation renames: use the
        #                  fsync'd checkpointed segment
    events, last_seq, positions, torn, ckpt = read_journal(path)
    unknown = {ev[1] for ev in events} - set(farm.services)
    if unknown:
        raise ValueError(
            f"journal references cores not attached to this farm: "
            f"{sorted(unknown)} (attach the same core set before replay)")
    if ckpt is not None:
        farm.restore(ckpt["snapshot"],
                     on_topology_mismatch=on_topology_mismatch)
    # Re-apply the topology history IN ORDER: a client registered before
    # a rotation is carried onto the standby by the rotation itself; one
    # registered after starts there directly.  The recovering process
    # must attach the same standbys before replay (rotation re-performs
    # against them) — the crashed process's degraded topology
    # (quarantined set, rotated slots) is reconstructed exactly.
    quarantines = rotations = 0
    for ev in events:
        if ev[0] == "register":
            _, core, client, seed = ev
            farm.register(core, client, seed=seed)
        elif ev[0] == "quarantine":
            _, core, reason = ev
            if core not in farm.quarantined:
                farm.quarantine(core, reason=reason)
            quarantines += 1
        elif ev[0] == "rotation":
            farm.rotate(ev[1])
            rotations += 1
    rows_replayed = 0
    if positions:
        for core, per_client in positions.items():
            svc = farm.services[core]
            for client, (row, pending, buf, outbox) in per_client.items():
                if client not in svc.clients:
                    raise ValueError(
                        f"journal flush record names unregistered client "
                        f"{core}/{client} (journal corrupt?)")
                before = int(svc.clients[client].row)
                svc.replay_client(client, row=int(row), pending=int(pending),
                                  buf_words=int(buf),
                                  outbox_words=int(outbox),
                                  chunk_rows=chunk_rows)
                rows_replayed += int(row) - before
    clients = sum(len(svc.clients) for svc in farm.services.values())
    return {"flushes": last_seq, "clients": clients,
            "rows_replayed": rows_replayed, "torn_tail": torn,
            "quarantines": quarantines, "rotations": rotations,
            "checkpoint_seq": 0 if ckpt is None else int(ckpt["seq"])}


def main(argv=None) -> int:
    """CLI: inspect a journal segment, or ``--repair`` mid-file damage.

    ``python -m repro.serve.journal <path>`` prints a summary (and exits
    2 on mid-file corruption, naming the damaged line);
    ``--repair`` truncates to the last good prefix first.
    """
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.journal",
        description="Inspect or repair a serve-tier flush journal.")
    ap.add_argument("path", help="journal file (JSONL segment)")
    ap.add_argument("--repair", action="store_true",
                    help="truncate the journal to its last good prefix "
                         "(atomic; a crash mid-repair leaves the original)")
    args = ap.parse_args(argv)
    if args.repair:
        res = repair_journal(args.path)
        print(f"repair: kept {res['kept']} record(s), "
              f"dropped {res['dropped']}")
    try:
        events, last_seq, last_pos, torn, ckpt = read_journal(args.path)
    except JournalCorrupt as e:
        print(f"CORRUPT: {e}")
        return 2
    n_reg = sum(1 for ev in events if ev[0] == "register")
    n_q = sum(1 for ev in events if ev[0] == "quarantine")
    n_rot = sum(1 for ev in events if ev[0] == "rotation")
    print(f"flushes: {last_seq}  registrations: {n_reg}  "
          f"quarantines: {n_q}  rotations: {n_rot}  "
          f"checkpoint: {'none' if ckpt is None else ckpt['seq']}  "
          f"torn_tail: {torn}")
    if last_pos is not None:
        for core in sorted(last_pos):
            per = last_pos[core]
            rows = sum(int(p[0]) for p in per.values())
            print(f"  {core}: {len(per)} client(s), {rows} total rows")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
