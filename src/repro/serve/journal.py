"""Crash recovery for the serving tier: an append-only flush journal.

``OscillatorFarm.snapshot()`` is the *explicit* resumability surface —
somebody has to call it, serialize it, and put it somewhere.  A crash
asks for the implicit version: the front-end appends one small record per
**completed flush** (plus one per client registration) to an append-only
JSONL file, and a restarted farm replays the journal to bit-exact stream
positions without any of the crashed process's memory.

What makes tiny records sufficient is the engine's determinism contract:
a client's word stream depends only on (weights, seed, lanes_per_client,
kernel config) plus its absolute word-row counter.  So the journal never
stores words or pool state — only each client's *position*:

    {"type": "flush", "seq": 7, "cores": {core: {client:
        [row, pending, buf_words, outbox_words]}}}

Recovery (:func:`replay_journal`) re-registers every journaled client
(same seed => same burn-in => same lane state), then recomputes each
client's lanes forward to ``row`` with the same fused kernel — the words
regenerated along the way rebuild the undelivered tail (service buffer +
outbox) bit-exactly, because chunk-invariant absolute-row indexing makes
one big replay launch identical to however many launches the crashed
process actually issued (``PRNGService.replay_client``).

Durability contract (tests/test_journal.py proves the kill window):

* a record is appended (and by default fsync'd) only *after* its flush
  fully absorbed — a crash mid-flush recovers to the previous flush
  boundary, and the words of the interrupted flush are regenerated, not
  lost and not double-served;
* requests queued in the front-end but not yet flushed are NOT journaled
  — they failed with the crash and the tenant retries (the same contract
  a deadline timeout gives);
* a torn final line (crash mid-append) is detected and ignored on
  replay.
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Dict, List, Optional, Tuple

from repro.serve.clock import Clock, SystemClock

_VERSION = 1


def farm_positions(farm) -> Dict[str, Dict[str, List[int]]]:
    """Per-client stream positions of a farm right now:
    ``{core: {client: [row, pending, buf_words, outbox_words]}}``."""
    out: Dict[str, Dict[str, List[int]]] = {}
    for core, svc in farm.services.items():
        per = {}
        for c in svc.clients.values():
            per[c.name] = [int(c.row), int(c.pending), int(len(c.buf)),
                           int(svc.outbox_words(c.name))]
        out[core] = per
    return out


class FlushJournal:
    """Append-only JSONL journal of client registrations + flush positions.

    One journal belongs to one serving process; attach it to an
    ``AsyncOscillatorFarm(journal=...)`` and it records automatically.
    ``fsync=True`` (default) makes each record durable before the writer
    returns — the crash-recovery guarantee costs one fsync per flush, not
    per request.  An existing file is appended to (seq continues), so a
    recovered process can keep journaling into the same file.
    """

    def __init__(self, path: str | os.PathLike, *, fsync: bool = True,
                 clock: Optional[Clock] = None):
        self.path = pathlib.Path(path)
        self.fsync = bool(fsync)
        self.clock: Clock = clock or SystemClock()
        self.seq = 0
        if self.path.exists():
            _, last_seq, _, _ = read_journal(self.path)
            self.seq = last_seq
        self._f = open(self.path, "a", encoding="utf-8")
        if self.seq == 0 and self._f.tell() == 0:
            self._append({"type": "open", "v": _VERSION})

    def _append(self, rec: Dict) -> None:
        rec["ts"] = self.clock.time()
        self._f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def record_register(self, core: str, client: str, seed: int) -> None:
        """Journal one client registration (the seed actually used, so
        replay re-derives the identical burn-in state)."""
        self._append({"type": "register", "core": core, "client": client,
                      "seed": int(seed)})

    def record_flush(self, farm) -> None:
        """Journal the post-flush position of every client (call only
        after the flush fully absorbed + delivered)."""
        self.seq += 1
        self._append({"type": "flush", "seq": self.seq,
                      "cores": farm_positions(farm)})

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "FlushJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_journal(path: str | os.PathLike) -> Tuple[
        List[Tuple[str, str, int]], int,
        Optional[Dict[str, Dict[str, List[int]]]], bool]:
    """Parse a journal: (registrations in order, last flush seq, last
    flush positions or None, torn_tail).

    A truncated final line (the crash landed mid-append) is ignored and
    reported via ``torn_tail`` — every complete record before it is
    still recovered.
    """
    registrations: List[Tuple[str, str, int]] = []
    last_seq, last_pos, torn = 0, None, False
    data = pathlib.Path(path).read_bytes().decode("utf-8", errors="replace")
    lines = data.split("\n")
    # a well-formed journal ends with "\n": the final split element is ""
    if lines and lines[-1] == "":
        lines.pop()
    elif lines:
        torn = True
        lines.pop()
    for line in lines:
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            # torn line in the middle => everything after it is suspect;
            # stop at the last known-good prefix
            torn = True
            break
        t = rec.get("type")
        if t == "register":
            registrations.append((rec["core"], rec["client"],
                                  int(rec["seed"])))
        elif t == "flush":
            last_seq = int(rec["seq"])
            last_pos = rec["cores"]
    return registrations, last_seq, last_pos, torn


def replay_journal(farm, path: str | os.PathLike,
                   chunk_rows: int = 4096) -> Dict[str, object]:
    """Rebuild a crashed serving process's stream positions onto ``farm``.

    ``farm`` must have the same cores attached (same weights/configs —
    e.g. rebuilt via ``OscillatorFarm.from_generated`` or the weight
    registry) and **no clients registered yet**.  Every journaled client
    is re-registered with its journaled seed, then advanced to its last
    flushed position with ``PRNGService.replay_client`` — after which
    every stream continues bit-exactly where the crashed process left
    off, including words that were generated but still undelivered
    (service buffer + outbox).

    Returns a summary: flushes recovered, clients replayed, word rows
    recomputed, and whether a torn tail record was discarded.
    """
    registrations, last_seq, positions, torn = read_journal(path)
    unknown = {core for core, _, _ in registrations} - set(farm.services)
    if unknown:
        raise ValueError(
            f"journal references cores not attached to this farm: "
            f"{sorted(unknown)} (attach the same core set before replay)")
    for core, client, seed in registrations:
        farm.register(core, client, seed=seed)
    rows_replayed = 0
    if positions:
        for core, per_client in positions.items():
            svc = farm.services[core]
            for client, (row, pending, buf, outbox) in per_client.items():
                if client not in svc.clients:
                    raise ValueError(
                        f"journal flush record names unregistered client "
                        f"{core}/{client} (journal corrupt?)")
                svc.replay_client(client, row=int(row), pending=int(pending),
                                  buf_words=int(buf),
                                  outbox_words=int(outbox),
                                  chunk_rows=chunk_rows)
                rows_replayed += int(row)
    return {"flushes": last_seq, "clients": len(registrations),
            "rows_replayed": rows_replayed, "torn_tail": torn}
