"""Serving: prefill + batched decode over the model zoo's cached decode path."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf

PyTree = Any


def make_serve_step(cfg: ModelConfig, shard_fn=None):
    """Returns serve_step(params, state, tokens(B,1)) -> (logits, state).
    This is the function the decode_* dry-run cells lower."""
    shard = shard_fn or (lambda tag, x: x)

    def serve_step(params, state, tokens):
        return tf.decode_step(cfg, params, state, tokens, shard_fn=shard)

    return serve_step


def prefill(cfg: ModelConfig, params, tokens: jax.Array, max_len: int,
            shard_fn=None) -> Tuple[jax.Array, PyTree]:
    """Run the full-sequence forward, then replay KV into a decode state.

    For attention archs the cache is filled by re-projecting k/v per layer
    (one pass, no quadratic work); for SSM archs the final recurrent state is
    produced by the chunked scan.  Returns (last-token logits, decode state).
    """
    b, s = tokens.shape
    shard = shard_fn or (lambda tag, x: x)
    logits, _ = tf.forward(cfg, params, tokens, shard_fn=shard)
    state = tf.init_decode_state(cfg, b, max_len)
    # Feed tokens one-by-one to warm the cache exactly (reference
    # implementation; production prefill fills the cache inside forward).
    def body(carry, tok):
        st = carry
        lg, st = tf.decode_step(cfg, params, st, tok[:, None], shard_fn=shard)
        return st, lg
    state, _ = jax.lax.scan(body, state, tokens.T)
    return logits[:, -1], state


def greedy_generate(cfg: ModelConfig, params, prompt: jax.Array,
                    n_new: int, max_len: int) -> jax.Array:
    """Greedy decoding for the examples; returns (B, n_new) token ids."""
    last_logits, state = prefill(cfg, params, prompt, max_len)
    tok = jnp.argmax(last_logits, axis=-1)[:, None]

    def body(carry, _):
        state, tok = carry
        logits, state = tf.decode_step(cfg, params, state, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return (state, tok), tok[:, 0]

    (_, _), toks = jax.lax.scan(body, (state, tok), None, length=n_new)
    return toks.T
