"""Supervision for the serving tier: retry policy, breakers, quality.

The async front-end's flush cycle is the single choke point every served
word passes through; :class:`HealthMonitor` is the policy object wired
into it (``AsyncOscillatorFarm(health=...)``).  Three concerns, one
object:

* **retry/backoff policy** — a transiently failed launch is retried
  under the existing single-flight lock with capped exponential backoff
  plus seeded jitter (``backoff_ms``).  Because a failed launch never
  reached ``absorb()``, the committed demand is still parked in the
  services at the same absolute stream rows — a successful retry serves
  words bit-identical to a never-failed flush.  The backoff *delay*
  routes through the injected ``Clock`` (``clock.wait`` on a private
  event), never ``asyncio.sleep`` — enforced by the
  ``backoff-discipline`` rule of ``repro.analysis`` — so the whole
  retry schedule is drivable by a ``FakeClock`` with zero real sleeps;

* **per-core circuit breaker** — ``note_launch_failure`` counts
  *consecutive* failures per core (attributed via the ``cores`` field
  of the raised error, e.g. :class:`repro.serve.faults.InjectedFault`);
  at ``breaker_threshold`` the core trips and the front-end quarantines
  it: cached gang plans drop, the group re-plans without it, and its
  tenants get a typed :class:`CoreQuarantined` instead of hanging on a
  core that will never launch again.  ``note_launch_success`` resets
  the counters — only consecutive failures trip;

* **online quality windows** — ``ingest`` accumulates words *sampled
  off the delivery path* (the farm's ``attach_monitor`` hook calls it
  from the launch executor thread; it only appends under a lock), and
  ``evaluate`` — run on the executor, after delivery — gates one full
  window per core through ``repro.prng.quality.online_gate``.  A hard
  failure (p < ALPHA_HARD) quarantines immediately; soft failures need
  ``soft_strikes`` consecutive failing windows, so a healthy core's
  ~alpha-rate window flukes never quarantine it.

The monitor holds no farm references — the front-end asks it for
verdicts and performs quarantine/rotation itself (farm mutation stays
under the single-flight lock on the loop thread).
"""
from __future__ import annotations

import random
import threading
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.prng.quality import GATE_ALPHA, ONLINE_WINDOW_WORDS, online_gate


class CoreQuarantined(RuntimeError):
    """A core was quarantined (circuit breaker or quality gate).

    Raised to tenants whose requests can no longer be served by the
    quarantined physical core: requests already committed to the failed
    flush, queued requests when no standby exists, and new submits to an
    unrotated quarantined core.  ``rotated`` tells the tenant whether a
    standby already took over the routing slot (retry immediately) or
    the core is simply gone (back off / resubmit elsewhere).
    """

    def __init__(self, message: str, *, core: str, reason: str = "",
                 rotated: bool = False):
        super().__init__(message)
        self.core = core
        self.reason = reason
        self.rotated = bool(rotated)


class HealthMonitor:
    """Retry policy + per-core circuit breaker + online quality windows.

    Parameters
    ----------
    breaker_threshold
        Consecutive launch failures that trip a core's breaker.
    max_retries_per_flush
        Transient-failure retries one flush cycle may spend before the
        error propagates to the batch futures (bounds lock hold time).
    backoff_base_ms / backoff_cap_ms / backoff_jitter
        Retry ``attempt`` (1-based) backs off
        ``min(cap, base * 2**(attempt-1))`` ms, stretched by up to
        ``backoff_jitter`` fraction of seeded jitter (decorrelates
        retry storms across processes; seeded so tests replay exactly).
    window_words / soft_strikes / alpha
        Online gate: words per rolling window, consecutive failing
        windows before a soft quarantine, and the per-test alpha.
    """

    def __init__(self, *, breaker_threshold: int = 3,
                 max_retries_per_flush: int = 4,
                 backoff_base_ms: float = 5.0,
                 backoff_cap_ms: float = 200.0,
                 backoff_jitter: float = 0.25,
                 seed: int = 0,
                 window_words: int = ONLINE_WINDOW_WORDS,
                 soft_strikes: int = 3,
                 alpha: float = GATE_ALPHA):
        if breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {breaker_threshold}")
        if window_words < 256:
            raise ValueError(
                f"window_words must be >= 256 for a meaningful gate, "
                f"got {window_words}")
        self.breaker_threshold = int(breaker_threshold)
        self.max_retries_per_flush = int(max_retries_per_flush)
        self.backoff_base_ms = float(backoff_base_ms)
        self.backoff_cap_ms = float(backoff_cap_ms)
        self.backoff_jitter = float(backoff_jitter)
        self.window_words = int(window_words)
        self.soft_strikes = int(soft_strikes)
        self.alpha = float(alpha)
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._fails: Dict[str, int] = {}          # consecutive, per core
        self._samples: Dict[str, List[np.ndarray]] = {}
        self._sample_words: Dict[str, int] = {}
        self._strikes: Dict[str, int] = {}        # consecutive soft fails
        self.last_gate: Dict[str, Dict[str, object]] = {}
        self.stats = {"launch_failures": 0, "retries": 0, "breaker_trips": 0,
                      "windows_evaluated": 0, "windows_failed": 0,
                      "quality_quarantines": 0}

    # -- retry policy --------------------------------------------------------

    def backoff_ms(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based): capped exponential
        plus seeded jitter."""
        if attempt < 1:
            raise ValueError(f"attempt is 1-based, got {attempt}")
        base = min(self.backoff_cap_ms,
                   self.backoff_base_ms * (2.0 ** (attempt - 1)))
        return base * (1.0 + self.backoff_jitter * self._rng.random())

    # -- circuit breaker -----------------------------------------------------

    def note_launch_failure(self, cores: Iterable[str]) -> List[str]:
        """Record one failed launch against every core in ``cores``;
        returns the cores whose breaker just tripped (consecutive
        failures reached ``breaker_threshold``)."""
        tripped = []
        with self._lock:
            self.stats["launch_failures"] += 1
            for core in cores:
                n = self._fails.get(core, 0) + 1
                self._fails[core] = n
                if n == self.breaker_threshold:
                    tripped.append(core)
                    self.stats["breaker_trips"] += 1
        return tripped

    def note_launch_success(self, cores: Iterable[str]) -> None:
        """A launch served these cores: their failure streaks reset."""
        with self._lock:
            for core in cores:
                self._fails.pop(core, None)

    def consecutive_failures(self, core: str) -> int:
        return self._fails.get(core, 0)

    # -- online quality ------------------------------------------------------

    def ingest(self, core: str, words: np.ndarray) -> None:
        """Append served-word samples for ``core`` (called from the
        farm's sampling hook, possibly on the launch executor thread —
        this only copies a bounded slice under a lock; the NIST math
        happens later, in ``evaluate``)."""
        words = np.asarray(words, np.uint32).reshape(-1)
        if words.size == 0:
            return
        with self._lock:
            have = self._sample_words.get(core, 0)
            room = 2 * self.window_words - have   # bound memory per core
            if room <= 0:
                return
            chunk = words[:room].copy()
            self._samples.setdefault(core, []).append(chunk)
            self._sample_words[core] = have + chunk.size

    def buffered_words(self, core: str) -> int:
        return self._sample_words.get(core, 0)

    def reset(self, core: str) -> None:
        """Forget a core's samples, strikes, and failure streak (called
        on quarantine/rotation so a standby never inherits the bad
        physical core's history)."""
        with self._lock:
            self._samples.pop(core, None)
            self._sample_words.pop(core, None)
            self._strikes.pop(core, None)
            self._fails.pop(core, None)

    def evaluate(self) -> Dict[str, Dict[str, object]]:
        """Gate every core with a full sample window; returns
        ``{core: verdict}`` for cores that must be quarantined NOW.

        Each verdict carries the failing ``gate`` result and a
        human-readable ``reason``.  Runs the NIST math off-lock (the
        window is popped under the lock, evaluated outside it) — call
        from the serving executor, not the event loop.
        """
        windows: Dict[str, np.ndarray] = {}
        with self._lock:
            for core, n in list(self._sample_words.items()):
                if n < self.window_words:
                    continue
                buf = np.concatenate(self._samples.pop(core))
                windows[core] = buf[:self.window_words]
                rest = buf[self.window_words:]
                if rest.size:
                    self._samples[core] = [rest]
                    self._sample_words[core] = int(rest.size)
                else:
                    self._sample_words.pop(core, None)
        out: Dict[str, Dict[str, object]] = {}
        for core, words in windows.items():
            gate = online_gate(words, alpha=self.alpha)
            with self._lock:
                self.stats["windows_evaluated"] += 1
                self.last_gate[core] = gate
                if gate["hard_failed_tests"]:
                    self.stats["windows_failed"] += 1
                    self.stats["quality_quarantines"] += 1
                    self._strikes.pop(core, None)
                    out[core] = {
                        "gate": gate,
                        "reason": (f"online quality hard failure: "
                                   f"{gate['hard_failed_tests']} "
                                   f"p={min(gate['p_values'].values()):.2e}")}
                elif gate["failed_tests"]:
                    self.stats["windows_failed"] += 1
                    s = self._strikes.get(core, 0) + 1
                    self._strikes[core] = s
                    if s >= self.soft_strikes:
                        self.stats["quality_quarantines"] += 1
                        self._strikes.pop(core, None)
                        out[core] = {
                            "gate": gate,
                            "reason": (f"online quality: {s} consecutive "
                                       f"failing windows "
                                       f"({gate['failed_tests']})")}
                else:
                    self._strikes.pop(core, None)
        return out
