"""Streaming chaotic-PRNG serving engine (the HENNC end product at scale).

The paper's hardware engine serves one random stream from one synthesized
core; here the TPU analogue serves *many named client streams from one
kernel launch*: each client owns a contiguous block of lanes on the stream
axis of the fused bits kernel, so a single ``ops.chaotic_bits`` launch
advances every client at once (the batched-MAC-array idea, lifted to the
serving layer).  Multi-device scale-out shards the stream pool across
devices with ``distributed.sharding.shard_stream_pool`` — lanes are
embarrassingly parallel, so the partition is exact.

Determinism contract: a client's word stream depends only on (weights,
seed, lanes_per_client, kernel config) — never on which other clients are
registered, how requests interleave, or how the pool is sharded.  That
holds because (a) every lane evolves independently in the kernel, (b) each
client carries its own word-row (Weyl) counter, passed to the kernel as a
per-lane offset vector, and (c) overdraw from batched launches is buffered
per client, not dropped.  The same property makes the service resumable:
``snapshot()`` captures pool state + counters + buffers.

The kernel microarchitecture is not hand-picked: ``core.dse.select_config``
(the paper's DSE, Eqs. 8-9) chooses (s_block, t_block, unroll,
compute_unit) — the first place the explorer's output drives the hot path
end to end.  It is tuned for one client's lane block and pinned at
construction (not re-tuned as the pool grows), so a client's words never
depend on when it joined; pass ``config=`` to override.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.prng.stream import (_lineage_counter, _round_rows,
                               _splitmix_seeds, effective_burn_in)


@dataclasses.dataclass(eq=False)
class _Client:
    name: str
    slot: int                 # lane block index into the pool
    seed: int
    row: int = 0              # word rows emitted (per-lane Weyl counter)
    buf: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.uint32))
    pending: int = 0          # words requested but not yet delivered


class PRNGService:
    """Batches many named client streams onto one fused-kernel launch."""

    def __init__(self, params: Dict[str, jax.Array], *,
                 lanes_per_client: int = 128, burn_in: int = 16,
                 activation: str = "relu", backend: str = "auto",
                 config=None, mesh=None, mesh_axis: str = "data",
                 dtype=None):
        self.params = {k: jnp.asarray(v) for k, v in params.items()}
        self.dim = self.params["w1"].shape[0]
        self.lanes_per_client = int(lanes_per_client)
        self.burn_in = effective_burn_in(burn_in)
        self.activation = activation
        self.backend = backend
        # Kernel compute dtype: f32 unless serving a half-width (bf16) core.
        self.dtype = jnp.dtype(dtype) if dtype is not None else jnp.float32
        if config is None:
            from repro.core.dse import select_config
            n_nodes = 1
            if "lattice_meta" in self.params:
                from repro.core.ann import lattice_meta_tuple
                n_nodes = lattice_meta_tuple(self.params["lattice_meta"])[0]
            config = select_config(self.dim, self.params["w1"].shape[1],
                                   s_total=self.lanes_per_client,
                                   dtype=self.dtype, n_nodes=n_nodes)
        self.config = config
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.clients: Dict[str, _Client] = {}
        self.pool_x: Optional[jax.Array] = None       # (n_clients * L, I)
        self.launches = 0                             # batched pool launches
        # Optional observation hook: called with each launch's raw word
        # slab inside absorb(), off the delivery path (the farm's
        # health-monitoring seam, ``OscillatorFarm.attach_monitor``).
        # The hook must be cheap and thread-safe — under an offloaded
        # front-end, absorb() runs on the launch executor thread.
        self.sample_hook = None
        # Words already served by a flush but not yet returned to their
        # requester (a draw() for one client must not drop co-tenants'
        # flushed requests).
        self._outbox: Dict[str, np.ndarray] = {}

    # -- registration -------------------------------------------------------

    def register(self, name: str, seed: Optional[int] = None) -> None:
        """Add a named stream: seed its lane block, burn it in, join pool.

        With no explicit seed, one is derived from the client name so that
        distinct clients never silently share a stream; pass the same
        explicit seed to two clients only if identical streams are wanted.
        """
        if name in self.clients:
            raise ValueError(f"client {name!r} already registered")
        if seed is None:
            seed = zlib.crc32(name.encode())
        L = self.lanes_per_client
        counter = _lineage_counter(seed, ())
        x = _splitmix_seeds(jnp.asarray(counter, jnp.uint32), L,
                            self.dim).astype(self.dtype)
        if self.burn_in:
            # Dedicated small launch so a client's stream is independent of
            # when it registered (burn-in never advances other clients).
            _, x = ops.chaotic_bits(
                self.params, x, self.burn_in, jnp.uint32(0),
                activation=self.activation, backend=self.backend,
                config=self.config)
        slot = len(self.clients)
        self.clients[name] = _Client(name=name, slot=slot, seed=seed)
        self.pool_x = x if self.pool_x is None else jnp.concatenate(
            [self.pool_x, x], axis=0)

    # -- request/flush ------------------------------------------------------

    def request(self, name: str, n_words: int) -> None:
        """Queue a draw; all queued draws are served by one flush() launch."""
        if n_words < 0:
            raise ValueError(f"n_words must be >= 0, got {n_words}")
        self.clients[name].pending += int(n_words)

    def rows_needed(self) -> int:
        """Unrounded max word rows any pending request still needs (0 when
        no launch is required).  Cheap — safe to poll per request()."""
        return self.rows_needed_with(None)

    def rows_needed_with(self, extra: Optional[Dict[str, int]] = None) -> int:
        """``rows_needed()`` if ``extra`` words per client were also pending.

        Demand introspection for front-ends that hold requests of their own
        (the async flusher): a request coverable from a client's buffer
        contributes zero rows, so coalescing thresholds count launch work,
        not raw words.  No state changes.
        """
        L = self.lanes_per_client
        extra = extra or {}
        n_rows = 0
        for c in self.clients.values():
            need = c.pending + extra.get(c.name, 0) - len(c.buf)
            if need > 0:
                n_rows = max(n_rows, -(-need // L))
        return n_rows

    def pending_words(self, name: str) -> int:
        """Words this client has requested but not yet been served."""
        return self.clients[name].pending

    def outbox_words(self, name: str) -> int:
        """Words already served for this client but parked undelivered."""
        parked = self._outbox.get(name)
        return 0 if parked is None else int(parked.size)

    def prepare_rows(self) -> Tuple[int, Optional[np.ndarray]]:
        """Plan a pool launch without performing it: (rows needed, offsets).

        Rows needed is ``rows_needed()``; offsets is the (S_pool,) per-lane
        uint32 Weyl-counter vector a launch issued now must use (None when
        no launch is required).  This is the farm-facing half of
        ``flush()``: a gang scheduler calls ``prepare_rows()`` on every
        group member, launches once for the group (possibly with MORE rows
        than this service asked for — overdraw is buffered, so delivered
        words are chunk-invariant), and hands the result back through
        ``absorb()``.  No state changes.
        """
        n_rows = self.rows_needed()
        if n_rows == 0:
            return 0, None
        offsets = np.repeat(
            np.asarray([c.row for c in self._by_slot()], np.uint32),
            self.lanes_per_client)
        return n_rows, offsets

    def absorb(self, words: Optional[np.ndarray], new_pool_x,
               n_rows: int, *, deliver: bool = True) -> Dict[str, np.ndarray]:
        """Bookkeeping half of ``flush()``: fold one launch's output back in.

        ``words`` is the (n_rows, S_pool) uint32 slab of this service's
        lanes and ``new_pool_x`` the advanced (S_pool, I) state (both may be
        None with n_rows == 0 for a launch-free delivery pass).  Clients
        that needed words get them buffered and their Weyl counters
        advanced; idle clients are *frozen* — their lanes rode the launch
        but their state is rolled back to the current pool, so a client's
        stream never depends on co-tenant traffic.  Then every pending
        request that the buffers now cover is delivered (outbox first).
        With ``deliver=False`` served words are parked in the outbox
        instead (auto-flush path): nothing is lost, the next
        flush()/draw() returns them.
        """
        L = self.lanes_per_client
        if n_rows > 0:
            words = np.asarray(words)
            if self.sample_hook is not None:
                self.sample_hook(words)
            active = [c for c in self._by_slot() if c.pending - len(c.buf) > 0]
            for c in active:
                mine = words[:, c.slot * L:(c.slot + 1) * L].reshape(-1)
                c.buf = np.concatenate([c.buf, mine])
                c.row += n_rows
            active_slots = {c.slot for c in active}
            idle_lanes = np.concatenate(
                [np.arange(c.slot * L, (c.slot + 1) * L)
                 for c in self._by_slot() if c.slot not in active_slots]
            ) if len(active_slots) < len(self.clients) else None
            if idle_lanes is not None:
                new_pool_x = new_pool_x.at[idle_lanes].set(
                    self.pool_x[idle_lanes])
            self.pool_x = new_pool_x
        out: Dict[str, np.ndarray] = {}
        for name, parked in self._outbox.items():
            out[name] = parked
        self._outbox = {}
        for c in self.clients.values():
            if c.pending:
                served = c.buf[:c.pending]
                out[c.name] = (np.concatenate([out[c.name], served])
                               if c.name in out else served)
                c.buf = c.buf[c.pending:]
                c.pending = 0
        if deliver:
            return out
        for name, served in out.items():
            self._park(name, served)
        return {}

    def flush(self) -> Dict[str, np.ndarray]:
        """One batched kernel launch serving every pending request.

        Every client that needs words advances by the same number of word
        rows (the max any pending request needs) with overdraw buffered, so
        per-client sequences stay independent of batching.  Clients that
        need nothing are *frozen* — their lanes are computed (they ride the
        launch) but their state/counters are rolled back — so idle clients
        neither advance nor accumulate buffer memory.  Implemented as
        ``prepare_rows()`` -> launch -> ``absorb()``; the farm's gang
        scheduler drives the same two halves around a shared launch.
        """
        n_need, offsets = self.prepare_rows()
        # Whole time-blocks for big launches, next-pow2 for small ones
        # (overdraw is buffered anyway; see stream._round_rows).
        n_rows = _round_rows(n_need, self.config.t_block) if n_need else 0
        if n_rows > 0:
            words, new_x = self._launch(n_rows, jnp.asarray(offsets))
            return self.absorb(words, new_x, n_rows)
        return self.absorb(None, None, 0)

    def draw(self, name: str, n_words: int) -> np.ndarray:
        """Convenience: request + flush for one client.

        The flush may also serve other clients' queued requests (and any
        earlier request for this client); those words are parked in the
        outbox and delivered by the next flush() — never dropped.
        """
        self.request(name, n_words)  # validates the client name
        if n_words == 0:
            return np.empty(0, np.uint32)
        prior = self.clients[name].pending - n_words
        out = self.flush()
        mine = out.pop(name)
        if prior > 0:                      # earlier request for this client
            self._park(name, mine[:prior])
            mine = mine[prior:]
        for other, words in out.items():
            self._park(other, words)
        return mine

    def park(self, name: str, words: np.ndarray) -> None:
        """Append already-served words to this client's outbox (delivered,
        outbox-first, by the next flush()/draw()).  Public for front-ends
        that receive a flush()'s words on behalf of other callers: words a
        front-end cannot route to one of its own requests are parked back
        here — never dropped — and surface on the sync path."""
        if words.size == 0:
            return
        self._outbox[name] = (np.concatenate([self._outbox[name], words])
                              if name in self._outbox else words)

    _park = park

    def _by_slot(self) -> List[_Client]:
        return sorted(self.clients.values(), key=lambda c: c.slot)

    def _launch(self, n_rows: int, offsets: jax.Array):
        """The one batched pool launch: ((n_rows, S_pool) words, new state).

        Does NOT assign ``pool_x`` — ``absorb()`` owns that, because idle
        lanes must be rolled back against the pre-launch pool.
        """
        n_steps = 2 * n_rows

        def run(x, off):
            return ops.chaotic_bits(
                self.params, x, n_steps, off, activation=self.activation,
                backend=self.backend, config=self.config)

        s_pool = self.pool_x.shape[0]
        if self.mesh is not None and s_pool % self.mesh.shape[self.mesh_axis] == 0:
            from repro.distributed.sharding import shard_stream_pool
            run = shard_stream_pool(run, self.mesh, self.mesh_axis)
        words, new_x = run(self.pool_x, offsets)
        self.launches += 1
        return np.asarray(words), new_x

    # -- resumability -------------------------------------------------------

    def replay_client(self, name: str, *, row: int, pending: int = 0,
                      buf_words: int = 0, outbox_words: int = 0,
                      chunk_rows: int = 4096) -> None:
        """Advance a client to an absolute stream position (crash
        recovery, ``repro.serve.journal``).

        Recomputes the client's lanes forward from their *current* row —
        0 for a freshly-registered client (full replay), or a
        checkpoint-restored position (delta replay bounded by the journal
        rotation window) — with the same fused kernel the crashed process
        used.  Chunk-invariant absolute-row indexing makes the replay
        bit-identical to however many launches originally produced the
        stream, so the final ``buf_words + outbox_words`` regenerated
        words rebuild the undelivered tail exactly: the stream order is
        always [delivered][outbox][buffer] (outbox words were served from
        the buffer head before the buffer's current contents
        accumulated), and a tail that reaches back before the checkpoint
        row is covered by the checkpoint's own undelivered words.
        ``chunk_rows`` bounds replay memory — only the owed tail is kept.
        """
        c = self.clients[name]
        row, buf_words, outbox_words = int(row), int(buf_words), int(outbox_words)
        if row < c.row:
            raise ValueError(
                f"replay_client({name!r}) cannot rewind: client is at row "
                f"{c.row}, journal says {row}")
        L = self.lanes_per_client
        if row * L < buf_words + outbox_words:
            raise ValueError(
                f"inconsistent position for {name!r}: {row} rows emit "
                f"{row * L} words < buf {buf_words} + outbox {outbox_words}")
        tail_need = buf_words + outbox_words
        # undelivered words at the starting position seed the tail: a
        # final tail reaching behind the start row must come from them
        held = np.concatenate([self._outbox.pop(name, np.empty(0, np.uint32)),
                               c.buf])
        if tail_need > held.size + (row - c.row) * L:
            raise ValueError(
                f"inconsistent position for {name!r}: owed tail "
                f"{tail_need} exceeds held {held.size} + "
                f"{(row - c.row) * L} replayable words")
        tail = held[-tail_need:] if tail_need else np.empty(0, np.uint32)
        if row > c.row:
            lanes = slice(c.slot * L, (c.slot + 1) * L)
            x = self.pool_x[lanes]
            done = c.row
            while done < row:
                n = min(int(chunk_rows), row - done)
                words, x = ops.chaotic_bits(
                    self.params, x, 2 * n, jnp.uint32(done),
                    activation=self.activation, backend=self.backend,
                    config=self.config)
                if tail_need:
                    tail = np.concatenate(
                        [tail, np.asarray(words).reshape(-1)])[-tail_need:]
                done += n
            self.pool_x = self.pool_x.at[lanes].set(x)
            c.row = row
        if outbox_words:
            self._park(name, tail[:outbox_words])
        c.buf = tail[outbox_words:]
        c.pending = int(pending)

    def snapshot(self) -> Dict[str, object]:
        """Serializable state: restore() continues every stream bit-exactly.

        ``pending`` (words requested but not yet flushed) is part of the
        in-flight contract: a snapshot taken between request() and flush()
        must not silently lose the queued draws on restore.
        """
        return {
            "pool_x": np.asarray(self.pool_x) if self.pool_x is not None else None,
            "clients": {
                c.name: {"slot": c.slot, "seed": c.seed, "row": c.row,
                         "buf": c.buf.copy(), "pending": c.pending}
                for c in self.clients.values()
            },
            "launches": self.launches,
            "outbox": {k: v.copy() for k, v in self._outbox.items()},
            # Effective burn-in is part of every stream's identity: a
            # restore under a different burn-in would silently continue
            # from stream positions the new engine can never reproduce.
            "burn_in": self.burn_in,
        }

    def restore(self, snap: Dict[str, object]) -> None:
        snap_burn = snap.get("burn_in")
        if snap_burn is not None and int(snap_burn) != self.burn_in:
            raise ValueError(
                f"snapshot was taken with effective burn_in {snap_burn}, "
                f"this service runs {self.burn_in}; streams would resume "
                f"at positions the engine cannot reproduce")
        self.pool_x = (jnp.asarray(snap["pool_x"], self.dtype)
                       if snap["pool_x"] is not None else None)
        self.clients = {
            name: _Client(name=name, slot=st["slot"], seed=st["seed"],
                          row=st["row"], buf=np.asarray(st["buf"], np.uint32),
                          pending=int(st.get("pending", 0)))
            for name, st in snap["clients"].items()
        }
        self.launches = int(snap["launches"])
        self._outbox = {k: np.asarray(v, np.uint32)
                        for k, v in snap.get("outbox", {}).items()}
