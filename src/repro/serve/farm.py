"""Heterogeneous oscillator farm: many generated cores, one serving API.

The paper emits ONE hardware core per run; the serving-scale analogue is a
*farm* of generated cores — different chaotic systems, system dimensions,
dtypes, and DSE-autotuned kernel configs — multiplexed behind a single
register/request/flush/snapshot surface.  Each core is backed by its own
``PRNGService`` pool (its clients share one fused-kernel launch per flush),
and every determinism/resumability guarantee of ``PRNGService`` carries
over unchanged: a client's words are identical whether served standalone
or through the farm.

**Gang scheduling** (the launch-overhead killer): compatible cores — same
(i_dim, h_dim, dtype, activation, kernel config) — do not each pay their
own kernel launch per flush.  ``GangScheduler`` stacks their weights along
a leading core axis, concatenates their lane pools, and issues ONE
``ops.chaotic_bits_gang`` launch for the whole group, then scatters words
and final states back to each ``PRNGService`` via its
``prepare_rows()/absorb()`` halves.  Lanes evolve independently and word
emission is defined in absolute word-row space, so per-client words are
bit-identical to the per-core path (gang overdraw is buffered exactly like
batching overdraw).  Incompatible cores fall back to their own per-core
launch.  Mesh-sharded pools gang too: cores on the SAME mesh share one
shard_map'd gang launch whose stream axis (and scalar-prefetch maps) are
partitioned across the named device axis — see
``kernels.chaotic_ann.chaotic_ann_gang_bits_sharded``.

Cores come from two places:

  * ``add_core(name, params, ...)`` — weights in hand (e.g. straight from
    the registry ``repro.prng.stream.trained_oscillator``);
  * ``from_generated(farm_dir)`` — a directory of ``generate_farm`` output:
    each package's weights.npz + solution.json are loaded and the frozen
    DSE solution (block shapes, compute unit, dtype) drives that core's
    service config, closing the train -> DSE -> codegen -> serve loop.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.dse import VMEM_USABLE, GangCostModel, stacked_gang_vmem_bytes
from repro.prng.stream import _round_rows
from repro.serve.clock import Clock, SystemClock
from repro.serve.health import CoreQuarantined
from repro.serve.prng_service import PRNGService


def _topology(svc: PRNGService) -> Optional[Tuple]:
    """Hashable device-axis signature of a service's mesh.

    ``None`` for an unsharded (single-device) pool; otherwise the named
    axis, its device count, and the flat device ids — the full identity a
    sharded launch depends on.  Part of every gang compat key, plan /
    decision / dispatch cache key, and farm snapshot, so nothing planned
    on one device count can silently serve another.
    """
    if svc.mesh is None:
        return None
    n_dev = int(svc.mesh.shape[svc.mesh_axis])
    devs = tuple(int(d.id) for d in np.asarray(svc.mesh.devices).reshape(-1))
    return (svc.mesh_axis, n_dev, devs)


def _as_topo(t) -> Optional[Tuple]:
    """Canonicalize a topology signature (JSON round-trips turn the tuples
    into lists; journal checkpoints compare through this)."""
    if t is None:
        return None
    return (str(t[0]), int(t[1]), tuple(int(x) for x in t[2]))


def _lattice_sig(svc: PRNGService) -> Optional[Tuple]:
    """Hashable lattice identity of one core's service, or ``None`` for a
    scalar (uncoupled) core.  The coupling operator is a pure function of
    this tuple (``lattice_coupling_matrix``), so equal signatures imply a
    shared coupling operand is exact for every member of a gang."""
    meta = svc.params.get("lattice_meta")
    if meta is None:
        return None
    from repro.core.ann import lattice_meta_tuple
    return lattice_meta_tuple(np.asarray(meta))


def _compat_key(svc: PRNGService) -> Optional[Tuple]:
    """Gang-compatibility signature of one core's service.

    Two cores may share a stacked-weight launch iff every static property
    of the kernel instantiation matches: network shape (i_dim, h_dim),
    compute dtype, activation, backend, the full DSE kernel config
    (s_block, t_block, unroll, compute_unit), the lattice signature
    (scalar cores never gang with lattice cores, and lattice cores gang
    only on identical (n_nodes, base_dim, topology, strength) — the
    launch carries ONE shared coupling operand), and the device topology.
    Mesh-sharded pools gang with pools on the SAME mesh (axis name, device
    count, device ids): the group launches as one shard_map'd gang across
    that mesh — the single-device-only limit recorded by PR 4 is gone.
    """
    c = svc.config
    return (svc.dim, int(svc.params["w1"].shape[1]), str(svc.dtype),
            svc.activation, svc.backend,
            c.s_block, c.t_block, c.unroll, c.compute_unit,
            _lattice_sig(svc), _topology(svc))


class GangScheduler:
    """Launches a group of compatible cores as stacked-weight kernels,
    choosing HOW per flush with a launch-cost model (the gang *planner*).

    Three caches keep steady-state traffic replay-only:

    * plan cache — per (group, membership, layout): stacked weight arrays,
      pool layout (lane spans + per-block core-id map), reusable offset /
      dead-lane padding buffers, and the last launch's device-resident
      stacked state (reused as the next x0 when no absorb rewrote any
      member pool — the common all-tenants-active case skips the
      per-flush ``jnp.stack``/``jnp.concatenate`` entirely);
    * decision cache — per (membership, ``_round_rows``-bucketed per-core
      demand vector): the cost-minimizing choice among ONE padded
      group-max launch (PR 3's policy), ONE ragged launch (each lane
      block computes only its own demand), or a SPLIT into
      demand-homogeneous sub-launches.  Steady traffic never replans;
    * dispatch keys — distinct (plan, bucketed rows) shapes ever launched;
      each is one XLA compile, and steady state stops growing it.

    ``planner=False`` pins every decision to the padded group-max launch,
    reproducing the PR 3 scheduler exactly.
    """

    def __init__(self, cost_model: Optional[GangCostModel] = None,
                 planner: bool = True, clock: Optional[Clock] = None,
                 faults=None):
        self.clock: Clock = clock or SystemClock()
        self.faults = faults          # FaultPlan (chaos harness) or None
        self._plans: Dict[Tuple, Dict] = {}
        self._decisions: Dict[Tuple, Dict] = {}
        self._dispatch_keys = set()   # (plan key, n_rows) ever launched
        self.launches = 0
        self.planner = bool(planner)
        self.cost_model = cost_model or GangCostModel()
        self.decisions = {"padded": 0, "ragged": 0, "split": 0}
        # flushes where an SLO class actually constrained the choice set
        self.slo_forced = {"latency": 0, "bulk": 0}
        self.profile: Optional[Dict[str, float]] = None

    @property
    def dispatch_misses(self) -> int:
        """Distinct (group, bucketed rows) keys launched so far — each one
        is a fresh XLA compile; steady state stops growing this."""
        return len(self._dispatch_keys)

    def _tick(self, stage: str, t0: float) -> float:
        t1 = self.clock.now()
        if self.profile is not None:
            self.profile[stage] = self.profile.get(stage, 0.0) + (t1 - t0)
        return t1

    def _plan(self, key: Tuple, members: List[Tuple[str, PRNGService]],
              mode: str) -> Dict:
        """Stacked weights + pool layout for one (membership, layout).

        Two launch layouts: equal-size vpu pools may take the
        *sublane-stacked* kernel (one grid cell per lane block advances the
        whole group — cheapest for the small coalesced flushes gangs exist
        for); ragged-pool or mxu groups — and ragged-DEMAND launches, where
        the early-out needs one grid cell per (block, core) — take the
        lane-concat kernel with a per-block core-id map.
        """
        sig = (key, tuple((name, int(svc.pool_x.shape[0]))
                          for name, svc in members), mode)
        plan = self._plans.get(sig)
        if plan is not None:
            return plan
        svc0 = members[0][1]
        s_block = svc0.config.s_block
        params = {k: jnp.stack([svc.params[k] for _, svc in members])
                  for k in ("w1", "b1", "w2", "b2")}
        # Lattice cores carry the coupling keys UN-stacked: the compat key
        # pins an identical lattice signature across the group, so one
        # shared (I, I) operand serves every member (ops._lattice_args).
        for k in ("coupling", "lattice_meta"):
            if k in svc0.params:
                params[k] = jnp.asarray(svc0.params[k])
        sizes = [int(svc.pool_x.shape[0]) for _, svc in members]
        plan = {"sig": sig, "params": params, "s_block": s_block,
                "mode": mode, "last_x": None, "handed": None}
        if mode == "stacked":
            plan["s_each"] = sizes[0]
            plan["offs_buf"] = np.zeros((len(members), sizes[0]), np.uint32)
        else:
            spans, core_map, pads, start = [], [], [], 0
            for ci, live in enumerate(sizes):
                padded = -(-live // s_block) * s_block
                spans.append((start, live, padded))
                core_map.extend([ci] * (padded // s_block))
                if padded > live:  # dead-lane padding, built once
                    pads.append(jnp.zeros((padded - live, svc0.dim),
                                          svc0.dtype))
                else:
                    pads.append(None)
                start += padded
            plan.update(spans=spans, pads=pads,
                        core_map=np.asarray(core_map, np.int32),
                        s_total=start,
                        offs_buf=np.zeros(start, np.uint32))
        self._plans[sig] = plan
        return plan

    # -- planning ------------------------------------------------------------

    def _decide(self, key: Tuple, members: Sequence[Tuple],
                demands: Tuple[int, ...],
                slo: Optional[str] = None) -> Dict:
        """Pick the cost-minimizing launch shape for one flush.

        ``demands`` are the ``_round_rows``-bucketed per-member word rows;
        the decision is cached on (membership, demands, slo) so
        steady-state traffic replans exactly never.  Candidate plans:

        * ``padded``  — one launch, every member at the group max
          (sublane-stacked when pools are equal + vpu, else lane-concat);
          this is the only option with ``planner=False`` (PR 3);
        * ``ragged``  — one demand-shaped launch (stacked-with-freeze or
          lane-concat-with-early-out, whichever models cheaper);
        * ``split``   — demand-homogeneous subgroups, each padded (solo
          per-core launches for singletons), paying one launch overhead
          per subgroup.

        ``slo`` constrains the choice set (the deadline-tier contract of
        the async front-end): ``"latency"`` forbids the padded group-max
        launch whenever demand is actually skewed — a latency-class
        tenant must not wait for co-tenants' overdraw rows, so the
        planner must pick a demand-shaped ragged or split plan even when
        the cost model scores padded cheaper; ``"bulk"`` pins the padded
        launch — bulk tenants always ride the maximally-amortized shape.
        ``None`` leaves the planner free (cost-minimizing).
        """
        from repro.kernels.chaotic_ann import gang_effective_rows
        if not self.planner:
            slo = None          # policy pinned: PR 3 padded group-max
        mem_sig = (key, tuple((name, int(svc.pool_x.shape[0]))
                              for name, svc, _, _ in members))
        dsig = (mem_sig, demands, slo)
        dec = self._decisions.get(dsig)
        if dec is not None:
            return dec
        svc0 = members[0][1]
        c = svc0.config
        sizes = [int(svc.pool_x.shape[0]) for _, svc, _, _ in members]
        blocks = [-(-s // c.s_block) for s in sizes]
        topo = _topology(svc0)
        n_dev = 1 if topo is None else topo[1]
        # the stacked kernel shards its LANE axis: each device needs an
        # equal lane slice, so stacked is only eligible when the (equal)
        # pool size divides the device count — and the whole stack must
        # fit VMEM (every core's carry/hidden/x0 is resident at once);
        # past that cliff the planner falls back to the lane-concat layout
        stacked_ok = (len(set(sizes)) == 1 and c.compute_unit == "vpu"
                      and sizes[0] % n_dev == 0
                      and stacked_gang_vmem_bytes(c, len(members))
                      <= VMEM_USABLE)
        model = self.cost_model
        all_idx = tuple(range(len(members)))
        dmax = max(demands)
        base_layout = "stacked" if stacked_ok else "concat"
        options = [("padded",
                    model.gang_cost(c, demands, blocks, sizes,
                                    layout=base_layout, n_dev=n_dev),
                    [{"members": all_idx, "kind": "gang",
                      "layout": base_layout, "ragged": False}])]
        if self.planner and len(set(demands)) > 1:
            # one ragged launch: early-out concat vs freeze-stacked
            eff = gang_effective_rows(
                np.repeat(np.asarray(demands), blocks), 2 * dmax,
                c.t_block, c.unroll)
            r_cost = model.gang_cost(c, demands, blocks, sizes,
                                     layout="concat",
                                     rows_by_block=[int(r) for r in eff],
                                     n_dev=n_dev)
            r_layout = "concat"
            if stacked_ok:
                s_cost = model.gang_cost(c, demands, blocks, sizes,
                                         layout="stacked",
                                         rows_by_block=list(demands),
                                         n_dev=n_dev)
                # the freeze layout saves buffering only (no FMA skipped);
                # require a clear modeled margin over the purpose-built
                # early-out concat path before trusting a noisy fit
                if s_cost < 0.9 * r_cost:
                    r_cost, r_layout = s_cost, "stacked"
            options.append(("ragged", r_cost,
                            [{"members": all_idx, "kind": "gang",
                              "layout": r_layout, "ragged": True}]))
            # split into demand-homogeneous subgroups
            by_demand: Dict[int, List[int]] = {}
            for i, d in enumerate(demands):
                by_demand.setdefault(d, []).append(i)
            cost, parts = 0.0, []
            for d in sorted(by_demand, reverse=True):
                idxs = by_demand[d]
                if len(idxs) == 1:
                    i = idxs[0]
                    cost += model.solo_cost(c, d, blocks[i], n_dev=n_dev)
                    parts.append({"members": (i,), "kind": "solo"})
                else:
                    sub_sizes = [sizes[i] for i in idxs]
                    sub_stacked = (len(set(sub_sizes)) == 1
                                   and c.compute_unit == "vpu"
                                   and sub_sizes[0] % n_dev == 0
                                   and stacked_gang_vmem_bytes(c, len(idxs))
                                   <= VMEM_USABLE)
                    lay = "stacked" if sub_stacked else "concat"
                    cost += model.gang_cost(
                        c, [d] * len(idxs), [blocks[i] for i in idxs],
                        sub_sizes, layout=lay, n_dev=n_dev)
                    parts.append({"members": tuple(idxs), "kind": "gang",
                                  "layout": lay, "ragged": False})
            options.append(("split", cost, parts))
        free_kind = min(options, key=lambda o: o[1])[0]
        eligible = options
        if slo == "bulk":
            eligible = [o for o in options if o[0] == "padded"]
        elif slo == "latency" and len(options) > 1:
            # skewed demand + a latency-class tenant: the padded group-max
            # launch would make that tenant wait for co-tenants' overdraw
            eligible = [o for o in options if o[0] != "padded"]
        kind, cost, parts = min(eligible, key=lambda o: o[1])
        if slo is not None and kind != free_kind:
            self.slo_forced[slo] += 1
        dec = {"kind": kind, "parts": parts, "slo": slo,
               "modeled_cycles": {k: v for k, v, _ in options}}
        self._decisions[dsig] = dec
        return dec

    # -- execution -----------------------------------------------------------

    def _gather_x0(self, plan: Dict, members: Sequence[Tuple]):
        """The launch's pooled x0; reuses the last launch's device-resident
        stacked state when every member pool is still the exact array this
        scheduler handed to its ``absorb`` (identity check — any rollback,
        restore, or registration rebuilds)."""
        handed = plan["handed"]
        if (handed is not None and len(handed) == len(members)
                and all(svc.pool_x is h
                        for (_, svc, _, _), h in zip(members, handed))):
            return plan["last_x"]
        if plan["mode"] == "stacked":
            return jnp.stack([svc.pool_x for _, svc, _, _ in members])
        parts = []
        for (start, live, padded), pad, (_, svc, _, _) in zip(
                plan["spans"], plan["pads"], members):
            parts.append(svc.pool_x)
            if pad is not None:
                parts.append(pad)
        return jnp.concatenate(parts, axis=0)

    def _launch_group(self, key: Tuple, members: Sequence[Tuple],
                      demands: Sequence[int], *, layout: str, ragged: bool,
                      deliver: bool) -> Dict[str, Dict[str, np.ndarray]]:
        """One gang launch (padded or ragged) for ``members``."""
        from repro.kernels import ops
        from repro.kernels.chaotic_ann import gang_effective_rows
        if self.faults is not None:
            # the injection seam sits BEFORE any kernel work or absorb
            # bookkeeping: a failed launch leaves every member's demand
            # parked at the same absolute rows, so a retry is bit-exact
            self.faults.on_launch([name for name, _, _, _ in members])
        t0 = self.clock.now()
        svc0 = members[0][1]
        cfg = svc0.config
        plan = self._plan(key, [(name, svc) for name, svc, _, _ in members],
                          layout)
        n_rows = max(demands)
        n_steps = 2 * n_rows
        t0 = self._tick("plan", t0)
        x0 = self._gather_x0(plan, members)
        if layout == "stacked":
            offs = plan["offs_buf"]
            for ci, (_, _, _, offsets) in enumerate(members):
                offs[ci, :] = offsets
            row_map = np.asarray(demands, np.int32) if ragged else None
            member_rows = list(demands) if ragged else [n_rows] * len(members)
            t0 = self._tick("stack", t0)
            words, state = ops.chaotic_bits_gang_stacked(
                plan["params"], x0, n_steps, jnp.asarray(offs),
                row_map=row_map, activation=svc0.activation,
                backend=svc0.backend, mesh=svc0.mesh,
                mesh_axis=svc0.mesh_axis, config=cfg)
            words = np.asarray(words)
            handed = [state[ci] for ci in range(len(members))]
            member_out = [(words[:member_rows[ci], ci, :], handed[ci])
                          for ci in range(len(members))]
        else:
            offs = plan["offs_buf"]
            for (start, live, _), (_, _, _, offsets) in zip(
                    plan["spans"], members):
                offs[start:start + live] = offsets
            if ragged:
                block_demand = np.repeat(np.asarray(demands, np.int64),
                                         [padded // plan["s_block"]
                                          for _, _, padded in plan["spans"]])
                eff = gang_effective_rows(block_demand, n_steps,
                                          cfg.t_block, cfg.unroll)
                row_map = eff
                # every block of a member shares its demand -> same eff rows
                member_rows, b0 = [], 0
                for _, _, padded in plan["spans"]:
                    member_rows.append(int(eff[b0]))
                    b0 += padded // plan["s_block"]
            else:
                row_map = None
                member_rows = [n_rows] * len(members)
            t0 = self._tick("stack", t0)
            words, state = ops.chaotic_bits_gang(
                plan["params"], x0, n_steps,
                jnp.asarray(offs), core_map=plan["core_map"],
                row_map=row_map, activation=svc0.activation,
                backend=svc0.backend, mesh=svc0.mesh,
                mesh_axis=svc0.mesh_axis, config=cfg)
            words = np.asarray(words)
            handed = [state[start:start + live]
                      for (start, live, _) in plan["spans"]]
            member_out = [(words[:member_rows[ci], start:start + live],
                           handed[ci])
                          for ci, (start, live, _) in enumerate(plan["spans"])]
        plan["last_x"], plan["handed"] = state, handed
        self.launches += 1
        # ragged and padded launches of the same shape are distinct jit
        # traces (row_map None vs array), hence distinct dispatch keys
        self._dispatch_keys.add((plan["sig"], n_rows, bool(ragged)))
        t0 = self._tick("launch", t0)
        out: Dict[str, Dict[str, np.ndarray]] = {}
        for (mwords, mstate), rows_c, (name, svc, _, _) in zip(
                member_out, member_rows, members):
            served = svc.absorb(mwords, mstate, rows_c, deliver=deliver)
            if served:
                out[name] = served
        self._tick("absorb", t0)
        return out

    def _launch_solo(self, member: Tuple, n_rows: int, *,
                     deliver: bool) -> Dict[str, Dict[str, np.ndarray]]:
        """A planner-split singleton: a plain per-core launch."""
        name, svc, _, offsets = member
        if self.faults is not None:
            self.faults.on_launch([name])
        t0 = self.clock.now()
        words, new_x = svc._launch(n_rows, jnp.asarray(offsets))
        t0 = self._tick("launch", t0)
        served = svc.absorb(words, new_x, n_rows, deliver=deliver)
        self._tick("absorb", t0)
        return {name: served} if served else {}

    def launch(self, key: Tuple,
               members: List[Tuple[str, PRNGService, int, np.ndarray]],
               *, deliver: bool = True,
               slo: Optional[str] = None) -> Dict[str, Dict[str, np.ndarray]]:
        """Serve one flush of ``members`` (each with its prepare_rows plan)
        with the planner-chosen launch shape (``slo`` constrains the
        choice set — see ``_decide``).

        However the plan shapes launches, every member advances by a row
        count >= its own demand with overdraw buffered, so delivered words
        are bit-identical to the per-core path (chunk-invariance of the
        absolute-row Weyl indexing).
        """
        t0 = self.clock.now()
        svc0 = members[0][1]
        demands = tuple(_round_rows(n, svc0.config.t_block)
                        for _, _, n, _ in members)
        dec = self._decide(key, members, demands, slo)
        self.decisions[dec["kind"]] += 1
        self._tick("plan", t0)
        out: Dict[str, Dict[str, np.ndarray]] = {}
        for part in dec["parts"]:
            sub = [members[i] for i in part["members"]]
            if part["kind"] == "solo":
                out.update(self._launch_solo(
                    sub[0], demands[part["members"][0]], deliver=deliver))
            else:
                out.update(self._launch_group(
                    key, sub, [demands[i] for i in part["members"]],
                    layout=part["layout"], ragged=part["ragged"],
                    deliver=deliver))
        return out


class OscillatorFarm:
    """Routes named clients to per-core ``PRNGService`` pools.

    ``gang=True`` (default) enables gang-scheduled flushes: compatible
    cores share one stacked-weight launch per flush.  ``gang=False``
    reproduces the legacy one-launch-per-core behavior — delivered words
    are bit-identical either way (tests/test_gang.py).
    ``planner=True`` (default) lets the gang scheduler shape each group's
    launch to per-core demand with the ``GangCostModel`` (padded / ragged /
    split, see ``GangScheduler``); ``planner=False`` pins the PR 3 padded
    group-max policy.  Pass ``gang_cost_model`` (e.g. a measured
    ``GangCostModel.fit``) to plan against this machine's real launch
    overhead.  ``auto_flush_rows`` is the coalescing threshold for
    ``request(..., auto_flush=True)``: the farm auto-flushes once total
    pending work reaches that many word rows (None = flush on every
    auto-flush request).  ``profile=True`` accumulates per-stage flush
    wall times (plan / stack / launch / absorb) in ``profile_stats``.
    Every time read (the profile timers are the only ones) goes through
    the injectable ``clock`` (``repro.serve.clock``): the sync farm's own
    deferral/coalescing logic is flush-cycle- and row-counted, never
    wall-clock-dependent, and a frozen ``FakeClock`` proves it
    (tests/test_async_frontend.py).
    """

    def __init__(self, *, gang: bool = True, planner: bool = True,
                 gang_cost_model: Optional[GangCostModel] = None,
                 auto_flush_rows: Optional[int] = None,
                 profile: bool = False, clock: Optional[Clock] = None,
                 faults=None):
        self.services: Dict[str, PRNGService] = {}
        self.gang = bool(gang)
        self.auto_flush_rows = auto_flush_rows
        self.clock: Clock = clock or SystemClock()
        self.faults = faults          # FaultPlan (chaos harness) or None
        self._sched = GangScheduler(cost_model=gang_cost_model,
                                    planner=planner, clock=self.clock,
                                    faults=faults)
        if profile:
            self._sched.profile = {"plan": 0.0, "stack": 0.0,
                                   "launch": 0.0, "absorb": 0.0,
                                   "flushes": 0.0}
        self._deferred: set = set()   # cores deferred by the last flush
        # Self-healing state (see quarantine()/rotate()): quarantined
        # cores are skipped by every flush; standbys are cold spare
        # services rotated into a quarantined core's routing slot.
        self._quarantined: set = set()
        self._standbys: Dict[str, PRNGService] = {}
        self._rotations: Dict[str, int] = {}
        self.monitor = None           # HealthMonitor via attach_monitor()

    # -- core management ----------------------------------------------------

    def add_core(self, core: str, params, *, config=None, dtype=None,
                 activation: str = "relu", lanes_per_client: int = 128,
                 burn_in: int = 16, backend: str = "auto",
                 mesh=None, mesh_axis: str = "data") -> PRNGService:
        """Attach a core (one oscillator network) as a serving pool."""
        if core in self.services:
            raise ValueError(f"core {core!r} already attached")
        svc = PRNGService(params, lanes_per_client=lanes_per_client,
                          burn_in=burn_in, activation=activation,
                          backend=backend, config=config, dtype=dtype,
                          mesh=mesh, mesh_axis=mesh_axis)
        self.services[core] = svc
        if self.monitor is not None:
            self._install_hook(core)
        return svc

    @classmethod
    def from_generated(cls, farm_dir: str | pathlib.Path,
                       cores: Optional[Iterable[str]] = None,
                       gang: bool = True, planner: bool = True,
                       gang_cost_model: Optional[GangCostModel] = None,
                       auto_flush_rows: Optional[int] = None,
                       **service_kw) -> "OscillatorFarm":
        """Build a farm from a ``generate_farm`` output directory.

        Every subdirectory with weights.npz + solution.json becomes a core;
        its frozen DSE solution is replayed as the service kernel config
        (including the solution's dtype), so serving uses exactly the
        microarchitecture the explorer picked for that system.  One
        adjustment: the solution's stream block is clamped to one client's
        lane block (the same sizing ``PRNGService`` autotunes for) — a
        wider s_block would only compute padding lanes, and since lanes
        evolve independently the clamp is bit-exact.
        """
        import dataclasses
        from repro.core.dse import LANES, Candidate, _pad
        reserved = {"config", "dtype", "activation"} & set(service_kw)
        if reserved:
            raise ValueError(
                f"{sorted(reserved)} are replayed from each core's "
                f"solution.json and cannot be overridden here; use "
                f"add_core() to attach a core with custom values")
        farm_dir = pathlib.Path(farm_dir)
        farm = cls(gang=gang, planner=planner,
                   gang_cost_model=gang_cost_model,
                   auto_flush_rows=auto_flush_rows)
        names = sorted(cores) if cores is not None else sorted(
            p.name for p in farm_dir.iterdir()
            if (p / "solution.json").exists() and (p / "weights.npz").exists())
        if not names:
            raise ValueError(f"no generated cores under {farm_dir}")
        lanes = service_kw.get("lanes_per_client", 128)
        p_cap = max(0, (_pad(lanes, LANES) // LANES).bit_length() - 1)
        for name in names:
            sol = json.loads((farm_dir / name / "solution.json").read_text())
            cand = Candidate(**sol["candidate"])
            cand = dataclasses.replace(cand, p=min(cand.p, p_cap))
            params = dict(np.load(farm_dir / name / "weights.npz"))
            farm.add_core(name, params, config=cand,
                          dtype=jnp.dtype(cand.dtype_name),
                          activation=sol.get("activation", "relu"),
                          **service_kw)
        return farm

    @property
    def cores(self) -> Tuple[str, ...]:
        return tuple(self.services)

    def _svc(self, core: str) -> PRNGService:
        try:
            return self.services[core]
        except KeyError:
            raise KeyError(f"unknown core {core!r}; have {sorted(self.services)}")

    # -- self-healing: quarantine, standbys, rotation ------------------------

    @property
    def quarantined(self) -> frozenset:
        """Cores currently quarantined (skipped by every flush)."""
        return frozenset(self._quarantined)

    @property
    def rotations(self) -> Dict[str, int]:
        """Standby rotations performed so far, per logical core."""
        return dict(self._rotations)

    def add_standby(self, core: str, params, *, config=None, dtype=None,
                    activation: str = "relu", lanes_per_client: int = 128,
                    burn_in: int = 16, backend: str = "auto",
                    mesh=None, mesh_axis: str = "data") -> PRNGService:
        """Attach a cold standby service for logical core ``core``.

        The standby (typically a retrained sibling from the weight
        registry) serves no traffic until :meth:`rotate` installs it in
        the core's routing slot.  Its streams are its own: a client
        re-registered on the standby restarts at row 0 of the standby's
        deterministic stream (same seed => same burn-in => bit-identical
        to serving that client on the standby solo from the start).
        """
        if core not in self.services:
            raise KeyError(f"unknown core {core!r}; attach it before a "
                           f"standby")
        if core in self._standbys:
            raise ValueError(f"core {core!r} already has a standby")
        svc = PRNGService(params, lanes_per_client=lanes_per_client,
                          burn_in=burn_in, activation=activation,
                          backend=backend, config=config, dtype=dtype,
                          mesh=mesh, mesh_axis=mesh_axis)
        self._standbys[core] = svc
        return svc

    def has_standby(self, core: str) -> bool:
        return core in self._standbys

    def quarantine(self, core: str, reason: str = "") -> bool:
        """Take ``core`` out of service: every flush skips it, cached
        gang plans and planner decisions drop (its groups re-plan
        without it), and its undeliverable pending demand is cleared
        (the caller already failed the owning futures with
        ``CoreQuarantined``).  Idempotent: returns False when the core
        was already quarantined.  Already-served words parked in its
        outbox stay (they are valid) — they surface if the core is ever
        un-quarantined by a rotation.
        """
        svc = self._svc(core)
        if core in self._quarantined:
            return False
        self._quarantined.add(core)
        for c in svc.clients.values():
            c.pending = 0
        self._deferred.discard(core)
        self._sched._plans.clear()
        self._sched._decisions.clear()
        if self.monitor is not None:
            self.monitor.reset(core)
        return True

    def rotate(self, core: str) -> PRNGService:
        """Install ``core``'s standby in its routing slot and lift the
        quarantine.  Every client of the old service is re-registered on
        the standby with its original seed — their streams restart at
        row 0 of the standby's own deterministic stream (bit-identical
        to a solo farm that served them on the standby all along).
        Returns the replaced (bad) service for post-mortem.
        """
        standby = self._standbys.pop(core, None)
        if standby is None:
            raise ValueError(
                f"core {core!r} has no standby attached; add_standby() "
                f"a registry sibling before rotating")
        old = self._svc(core)
        for c in sorted(old.clients.values(), key=lambda c: c.slot):
            standby.register(c.name, seed=c.seed)
        self.services[core] = standby
        self._quarantined.discard(core)
        self._rotations[core] = self._rotations.get(core, 0) + 1
        self._sched._plans.clear()
        self._sched._decisions.clear()
        if self.monitor is not None:
            self.monitor.reset(core)
            self._install_hook(core)
        return old

    def attach_monitor(self, monitor) -> None:
        """Wire a ``HealthMonitor``: every core's service gets a
        sampling hook that feeds each launch's word slab (bounded, and
        run through the fault plan's sample corruption when a chaos
        harness is attached) into ``monitor.ingest`` — off the delivery
        path.  Under an offloaded front-end the hook runs on the launch
        executor thread; ``ingest`` is thread-safe by contract."""
        self.monitor = monitor
        for core in self.services:
            self._install_hook(core)

    def _install_hook(self, core: str) -> None:
        svc = self.services[core]
        monitor, faults = self.monitor, self.faults
        cap = int(monitor.window_words)
        if faults is not None:
            faults.bind(core, svc)

        def hook(slab, _core=core, _svc=svc):
            w = slab.reshape(-1)[:cap]
            if faults is not None:
                w = faults.corrupt_sample(_core, _svc, w)
            monitor.ingest(_core, w)

        svc.sample_hook = hook

    def _check_serving(self, core: str) -> None:
        if core in self._quarantined:
            raise CoreQuarantined(
                f"core {core!r} is quarantined (no standby rotated in); "
                f"resubmit on another core or after rotation",
                core=core, reason="quarantined")

    # -- client API (per-core routing) --------------------------------------

    def register(self, core: str, client: str,
                 seed: Optional[int] = None) -> None:
        """Register a named client stream on one core's pool."""
        self._check_serving(core)
        self._svc(core).register(client, seed=seed)

    def request(self, core: str, client: str, n_words: int,
                auto_flush: bool = False) -> None:
        """Queue a draw; served by the next farm-wide flush().

        ``auto_flush=True`` lets small tenants coalesce instead of each
        calling flush(): after queueing, the farm flushes itself once total
        pending work across all cores reaches ``auto_flush_rows`` word rows
        (immediately when that threshold is None).  Words served by an
        auto-flush are parked in the per-service outboxes and returned by
        the tenant's next flush()/draw() — never dropped.
        """
        self._check_serving(core)
        self._svc(core).request(client, n_words)
        if auto_flush:
            if (self.auto_flush_rows is None
                    or self.pending_rows >= self.auto_flush_rows):
                self.flush(deliver=False)

    @property
    def pending_rows(self) -> int:
        """Unserved demand across all cores, in launch rows (words already
        coverable from client buffers contribute nothing).  This is the
        quantity the ``auto_flush_rows`` threshold compares against — the
        same accounting the async front-end uses for its coalescing
        trigger (``repro.serve.async_frontend``)."""
        return sum(svc.rows_needed() for svc in self.services.values())

    def flush(self, max_wait_rows: Optional[int] = None,
              deliver: bool = True,
              slo_by_core: Optional[Dict[str, str]] = None,
              ) -> Dict[str, Dict[str, np.ndarray]]:
        """Serve every pending request: one batched launch per core GROUP.

        Cores are grouped by gang-compatibility signature (``_compat_key``);
        each group with pending work costs one stacked-weight launch
        (``gang=False``: one launch per core, the legacy path).  Delivered
        words are bit-identical either way.

        ``max_wait_rows`` is the deadline knob: a group whose total needed
        rows is below it is *deferred* — no launch, its tenants keep
        waiting so the next flush sees a fuller gang — but a group is never
        deferred twice in a row (the deadline: at most one flush cycle).
        Deferred cores deliver nothing this flush.

        ``deliver=False`` parks all served words in the per-service
        outboxes instead of returning them (the auto-flush path).

        ``slo_by_core`` maps a core name to the SLO class of this flush's
        demand on it (``"latency"`` / ``"bulk"``, the async front-end's
        per-request tiers aggregated per core).  A group launches as
        ``"latency"`` if ANY member core carries latency-class demand
        (forbids the padded group-max shape on skewed demand), as
        ``"bulk"`` only if EVERY member is bulk (pins the padded shape);
        mixed/absent leaves the planner free.  SLO classes never change
        delivered words — only which launch shape serves them.

        Returns {core: {client: words}} for every client that received
        words (pending requests and previously parked outbox words alike).
        """
        if self.faults is not None:
            self.faults.on_flush()
        plans = {core: svc.prepare_rows()
                 for core, svc in self.services.items()
                 if core not in self._quarantined}
        # Group cores that need a launch by compatibility signature.
        groups: Dict[object, List[str]] = {}
        for core, (n_need, _) in plans.items():
            if n_need > 0:
                key = _compat_key(self.services[core]) if self.gang else None
                groups.setdefault(key if key is not None else ("solo", core),
                                  []).append(core)
        launching: List[Tuple[object, List[str]]] = []
        deferred_now: set = set()
        for key, cores in groups.items():
            total = sum(plans[c][0] for c in cores)
            overdue = any(c in self._deferred for c in cores)
            if max_wait_rows is None or total >= max_wait_rows or overdue:
                launching.append((key, cores))
            else:
                deferred_now.update(cores)
        out: Dict[str, Dict[str, np.ndarray]] = {}
        launching_cores = {c for _, cores in launching for c in cores}
        slo_by_core = slo_by_core or {}
        for key, cores in launching:
            classes = {slo_by_core.get(c) for c in cores}
            group_slo = ("latency" if "latency" in classes
                         else "bulk" if classes == {"bulk"} else None)
            if self.gang and len(cores) > 1:
                served = self._sched.launch(
                    key, [(c, self.services[c], plans[c][0], plans[c][1])
                          for c in cores], deliver=deliver, slo=group_slo)
                out.update(served)
            else:
                prof = self._sched.profile
                for c in cores:
                    svc = self.services[c]
                    if self.faults is not None:
                        self.faults.on_launch([c])
                    t0 = self._sched.clock.now()
                    n_rows = _round_rows(plans[c][0], svc.config.t_block)
                    words, new_x = svc._launch(n_rows,
                                               jnp.asarray(plans[c][1]))
                    t1 = self._sched.clock.now()
                    served = svc.absorb(words, new_x, n_rows,
                                        deliver=deliver)
                    if prof is not None:
                        prof["launch"] += t1 - t0
                        prof["absorb"] += self._sched.clock.now() - t1
                    if served:
                        out[c] = served
        # Launch-free delivery pass for cores with nothing to launch (their
        # buffers/outboxes may still owe words).  Deferred cores are fully
        # skipped: their buffers do not cover their pending requests yet.
        for core, (n_need, _) in plans.items():
            if core in launching_cores or core in deferred_now:
                continue
            if n_need == 0:
                served = self.services[core].absorb(None, None, 0,
                                                    deliver=deliver)
                if served:
                    out[core] = served
        self._deferred = deferred_now
        if self._sched.profile is not None:
            self._sched.profile["flushes"] += 1.0
        return out

    def draw(self, core: str, client: str, n_words: int) -> np.ndarray:
        """Convenience: request + flush one client on one core.

        Only that core's pool launches; other cores are untouched (their
        pending requests keep waiting for the next farm-wide flush()).
        """
        self._check_serving(core)
        return self._svc(core).draw(client, n_words)

    @property
    def launches(self) -> int:
        """Actual kernel launches issued: per-core launches + gang launches
        (a gang launch advances a whole group but costs ONE launch)."""
        return (sum(svc.launches for svc in self.services.values())
                + self._sched.launches)

    @property
    def gang_launches(self) -> int:
        return self._sched.launches

    @property
    def dispatch_misses(self) -> int:
        """Distinct (group, bucketed rows) gang keys compiled so far."""
        return self._sched.dispatch_misses

    @property
    def plan_decisions(self) -> Dict[str, int]:
        """Executed planner decisions so far, by kind
        (padded / ragged / split)."""
        return dict(self._sched.decisions)

    @property
    def slo_forced(self) -> Dict[str, int]:
        """Planner decisions where an SLO class overrode the free
        cost-minimizing choice (by class)."""
        return dict(self._sched.slo_forced)

    @property
    def profile_stats(self) -> Optional[Dict[str, float]]:
        """Accumulated per-stage flush seconds (``profile=True`` farms):
        plan / stack / launch / absorb, plus the flush count."""
        return (dict(self._sched.profile)
                if self._sched.profile is not None else None)

    # -- resumability -------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        """Farm-wide snapshot: every core pool, every client, in flight.

        Includes the deadline-deferral set, so a snapshot taken mid-gang
        (between request() and flush(), possibly after a deferring flush)
        replays identically — and each core's device topology, so a
        restore onto a different device count is caught (see restore()).
        """
        return {"cores": {core: svc.snapshot()
                          for core, svc in self.services.items()},
                "gang_launches": self._sched.launches,
                "deferred": sorted(self._deferred),
                "quarantined": sorted(self._quarantined),
                "rotations": dict(self._rotations),
                "topology": {core: _topology(svc)
                             for core, svc in self.services.items()}}

    def restore(self, snap: Dict[str, object], *,
                on_topology_mismatch: str = "refuse") -> None:
        """Restore a snapshot() onto a farm with the SAME cores attached.

        The core sets must match exactly: restoring onto a farm with extra
        cores would leave those pools in their post-snapshot state (clients,
        pending, outbox) — a silently mixed restore point.

        If the snapshot was taken on a different device topology (mesh
        axis / device count / device ids differ for any core), the restore
        must not silently proceed over plans shaped for the old topology:
        ``on_topology_mismatch="refuse"`` (default) raises;
        ``"replan"`` drops every cached gang plan and planner decision and
        restores anyway — stream words are device-count-invariant (lanes
        evolve independently, word rows are absolute), so a sharded
        snapshot restores bit-exactly onto an unsharded farm and vice
        versa once the planner re-plans on the new topology.
        """
        if on_topology_mismatch not in ("refuse", "replan"):
            raise ValueError(
                f"on_topology_mismatch must be 'refuse' or 'replan', "
                f"got {on_topology_mismatch!r}")
        cores = snap["cores"]
        missing = set(cores) - set(self.services)
        extra = set(self.services) - set(cores)
        if missing or extra:
            raise ValueError(
                f"snapshot/farm core mismatch: snapshot-only {sorted(missing)}, "
                f"farm-only {sorted(extra)}")
        snap_topo = snap.get("topology")
        if snap_topo is not None:
            changed = sorted(
                core for core, svc in self.services.items()
                if core in snap_topo
                and _as_topo(snap_topo[core]) != _topology(svc))
            if changed:
                if on_topology_mismatch == "refuse":
                    raise ValueError(
                        f"snapshot device topology differs from this farm's "
                        f"on cores {changed}; restore(snap, "
                        f"on_topology_mismatch='replan') to drop cached "
                        f"plans and re-plan on the current topology")
                self._sched._plans.clear()
                self._sched._decisions.clear()
        # Degraded-topology state replays BEFORE the per-core restores:
        # rotations re-point routing slots at standbys (the snapshot's
        # pool states belong to the post-rotation services), and the
        # per-core restore then overwrites the rotation's re-registered
        # clients wholesale with the snapshot's exact pool state.
        want = {c: int(n) for c, n in dict(snap.get("rotations", {})).items()}
        for core in sorted(set(want) | set(self._rotations)):
            n, have = want.get(core, 0), self._rotations.get(core, 0)
            if have > n:
                raise ValueError(
                    f"farm already rotated core {core!r} {have}x but the "
                    f"snapshot recorded {n}; cannot un-rotate")
            while self._rotations.get(core, 0) < n:
                self.rotate(core)
        self._quarantined = set(snap.get("quarantined", ()))
        for core, sub in cores.items():
            self.services[core].restore(sub)
        self._sched.launches = int(snap.get("gang_launches", 0))
        self._deferred = set(snap.get("deferred", ()))
