"""Heterogeneous oscillator farm: many generated cores, one serving API.

The paper emits ONE hardware core per run; the serving-scale analogue is a
*farm* of generated cores — different chaotic systems, system dimensions,
dtypes, and DSE-autotuned kernel configs — multiplexed behind a single
register/request/flush/snapshot surface.  Each core is backed by its own
``PRNGService`` pool (its clients share one fused-kernel launch per flush),
so a farm flush issues at most one launch per *core*, not per client, and
every determinism/resumability guarantee of ``PRNGService`` carries over
unchanged: a client's words are identical whether served standalone or
through the farm.

Cores come from two places:

  * ``add_core(name, params, ...)`` — weights in hand (e.g. straight from
    the registry ``repro.prng.stream.trained_oscillator``);
  * ``from_generated(farm_dir)`` — a directory of ``generate_farm`` output:
    each package's weights.npz + solution.json are loaded and the frozen
    DSE solution (block shapes, compute unit, dtype) drives that core's
    service config, closing the train -> DSE -> codegen -> serve loop.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Iterable, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serve.prng_service import PRNGService


class OscillatorFarm:
    """Routes named clients to per-core ``PRNGService`` pools."""

    def __init__(self):
        self.services: Dict[str, PRNGService] = {}

    # -- core management ----------------------------------------------------

    def add_core(self, core: str, params, *, config=None, dtype=None,
                 activation: str = "relu", lanes_per_client: int = 128,
                 burn_in: int = 16, backend: str = "auto",
                 mesh=None, mesh_axis: str = "data") -> PRNGService:
        """Attach a core (one oscillator network) as a serving pool."""
        if core in self.services:
            raise ValueError(f"core {core!r} already attached")
        svc = PRNGService(params, lanes_per_client=lanes_per_client,
                          burn_in=burn_in, activation=activation,
                          backend=backend, config=config, dtype=dtype,
                          mesh=mesh, mesh_axis=mesh_axis)
        self.services[core] = svc
        return svc

    @classmethod
    def from_generated(cls, farm_dir: str | pathlib.Path,
                       cores: Optional[Iterable[str]] = None,
                       **service_kw) -> "OscillatorFarm":
        """Build a farm from a ``generate_farm`` output directory.

        Every subdirectory with weights.npz + solution.json becomes a core;
        its frozen DSE solution is replayed as the service kernel config
        (including the solution's dtype), so serving uses exactly the
        microarchitecture the explorer picked for that system.  One
        adjustment: the solution's stream block is clamped to one client's
        lane block (the same sizing ``PRNGService`` autotunes for) — a
        wider s_block would only compute padding lanes, and since lanes
        evolve independently the clamp is bit-exact.
        """
        import dataclasses
        from repro.core.dse import LANES, Candidate, _pad
        reserved = {"config", "dtype", "activation"} & set(service_kw)
        if reserved:
            raise ValueError(
                f"{sorted(reserved)} are replayed from each core's "
                f"solution.json and cannot be overridden here; use "
                f"add_core() to attach a core with custom values")
        farm_dir = pathlib.Path(farm_dir)
        farm = cls()
        names = sorted(cores) if cores is not None else sorted(
            p.name for p in farm_dir.iterdir()
            if (p / "solution.json").exists() and (p / "weights.npz").exists())
        if not names:
            raise ValueError(f"no generated cores under {farm_dir}")
        lanes = service_kw.get("lanes_per_client", 128)
        p_cap = max(0, (_pad(lanes, LANES) // LANES).bit_length() - 1)
        for name in names:
            sol = json.loads((farm_dir / name / "solution.json").read_text())
            cand = Candidate(**sol["candidate"])
            cand = dataclasses.replace(cand, p=min(cand.p, p_cap))
            params = dict(np.load(farm_dir / name / "weights.npz"))
            farm.add_core(name, params, config=cand,
                          dtype=jnp.dtype(cand.dtype_name),
                          activation=sol.get("activation", "relu"),
                          **service_kw)
        return farm

    @property
    def cores(self) -> Tuple[str, ...]:
        return tuple(self.services)

    def _svc(self, core: str) -> PRNGService:
        try:
            return self.services[core]
        except KeyError:
            raise KeyError(f"unknown core {core!r}; have {sorted(self.services)}")

    # -- client API (per-core routing) --------------------------------------

    def register(self, core: str, client: str,
                 seed: Optional[int] = None) -> None:
        """Register a named client stream on one core's pool."""
        self._svc(core).register(client, seed=seed)

    def request(self, core: str, client: str, n_words: int) -> None:
        """Queue a draw; served by the next farm-wide flush()."""
        self._svc(core).request(client, n_words)

    def flush(self) -> Dict[str, Dict[str, np.ndarray]]:
        """Serve every pending request: one batched launch per active core.

        Returns {core: {client: words}} for every client that received
        words (pending requests and previously parked outbox words alike).
        """
        out: Dict[str, Dict[str, np.ndarray]] = {}
        for core, svc in self.services.items():
            served = svc.flush()
            if served:
                out[core] = served
        return out

    def draw(self, core: str, client: str, n_words: int) -> np.ndarray:
        """Convenience: request + flush one client on one core.

        Only that core's pool launches; other cores are untouched (their
        pending requests keep waiting for the next farm-wide flush()).
        """
        return self._svc(core).draw(client, n_words)

    @property
    def launches(self) -> int:
        return sum(svc.launches for svc in self.services.values())

    # -- resumability -------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        """Farm-wide snapshot: every core pool, every client, in flight."""
        return {"cores": {core: svc.snapshot()
                          for core, svc in self.services.items()}}

    def restore(self, snap: Dict[str, object]) -> None:
        """Restore a snapshot() onto a farm with the SAME cores attached.

        The core sets must match exactly: restoring onto a farm with extra
        cores would leave those pools in their post-snapshot state (clients,
        pending, outbox) — a silently mixed restore point.
        """
        cores = snap["cores"]
        missing = set(cores) - set(self.services)
        extra = set(self.services) - set(cores)
        if missing or extra:
            raise ValueError(
                f"snapshot/farm core mismatch: snapshot-only {sorted(missing)}, "
                f"farm-only {sorted(extra)}")
        for core, sub in cores.items():
            self.services[core].restore(sub)
