"""Heterogeneous oscillator farm: many generated cores, one serving API.

The paper emits ONE hardware core per run; the serving-scale analogue is a
*farm* of generated cores — different chaotic systems, system dimensions,
dtypes, and DSE-autotuned kernel configs — multiplexed behind a single
register/request/flush/snapshot surface.  Each core is backed by its own
``PRNGService`` pool (its clients share one fused-kernel launch per flush),
and every determinism/resumability guarantee of ``PRNGService`` carries
over unchanged: a client's words are identical whether served standalone
or through the farm.

**Gang scheduling** (the launch-overhead killer): compatible cores — same
(i_dim, h_dim, dtype, activation, kernel config) — do not each pay their
own kernel launch per flush.  ``GangScheduler`` stacks their weights along
a leading core axis, concatenates their lane pools, and issues ONE
``ops.chaotic_bits_gang`` launch for the whole group, then scatters words
and final states back to each ``PRNGService`` via its
``prepare_rows()/absorb()`` halves.  Lanes evolve independently and word
emission is defined in absolute word-row space, so per-client words are
bit-identical to the per-core path (gang overdraw is buffered exactly like
batching overdraw).  Incompatible cores (and mesh-sharded pools) fall back
to their own per-core launch.

Cores come from two places:

  * ``add_core(name, params, ...)`` — weights in hand (e.g. straight from
    the registry ``repro.prng.stream.trained_oscillator``);
  * ``from_generated(farm_dir)`` — a directory of ``generate_farm`` output:
    each package's weights.npz + solution.json are loaded and the frozen
    DSE solution (block shapes, compute unit, dtype) drives that core's
    service config, closing the train -> DSE -> codegen -> serve loop.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Iterable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.prng.stream import _round_rows
from repro.serve.prng_service import PRNGService


def _compat_key(svc: PRNGService) -> Optional[Tuple]:
    """Gang-compatibility signature of one core's service.

    Two cores may share a stacked-weight launch iff every static property
    of the kernel instantiation matches: network shape (i_dim, h_dim),
    compute dtype, activation, backend, and the full DSE kernel config
    (s_block, t_block, unroll, compute_unit).  Mesh-sharded pools return
    None (never ganged — their launch wraps a shard_map).
    """
    if svc.mesh is not None:
        return None
    c = svc.config
    return (svc.dim, int(svc.params["w1"].shape[1]), str(svc.dtype),
            svc.activation, svc.backend,
            c.s_block, c.t_block, c.unroll, c.compute_unit)


class GangScheduler:
    """Launches a group of compatible cores as ONE stacked-weight kernel.

    Holds the dispatch cache: per (group signature, membership) the stacked
    weight arrays and pool layout (lane spans + per-block core-id map) are
    built once and reused every flush, and launched row counts are bucketed
    by ``_round_rows``, so steady-state traffic replays a previously
    compiled kernel instead of re-stacking/recompiling.
    """

    def __init__(self):
        self._plans: Dict[Tuple, Dict] = {}
        self._dispatch_keys = set()   # (plan key, n_rows) ever launched
        self.launches = 0

    @property
    def dispatch_misses(self) -> int:
        """Distinct (group, bucketed rows) keys launched so far — each one
        is a fresh XLA compile; steady state stops growing this."""
        return len(self._dispatch_keys)

    def _plan(self, key: Tuple, members: List[Tuple[str, PRNGService]]) -> Dict:
        """Stacked weights + pool layout for one group membership.

        Two launch layouts: equal-size vpu pools take the *sublane-stacked*
        kernel (one grid cell per lane block advances the whole group —
        cheapest for the small coalesced flushes gangs exist for); ragged
        or mxu groups take the lane-concat kernel with a per-block core-id
        map.
        """
        sig = (key, tuple((name, int(svc.pool_x.shape[0]))
                          for name, svc in members))
        plan = self._plans.get(sig)
        if plan is not None:
            return plan
        svc0 = members[0][1]
        s_block = svc0.config.s_block
        params = {k: jnp.stack([svc.params[k] for _, svc in members])
                  for k in ("w1", "b1", "w2", "b2")}
        sizes = [int(svc.pool_x.shape[0]) for _, svc in members]
        plan = {"sig": sig, "params": params, "s_block": s_block}
        if len(set(sizes)) == 1 and svc0.config.compute_unit == "vpu":
            plan["mode"] = "stacked"
            plan["s_each"] = sizes[0]
        else:
            plan["mode"] = "concat"
            spans, core_map, start = [], [], 0
            for ci, live in enumerate(sizes):
                padded = -(-live // s_block) * s_block
                spans.append((start, live, padded))
                core_map.extend([ci] * (padded // s_block))
                start += padded
            plan.update(spans=spans,
                        core_map=np.asarray(core_map, np.int32),
                        s_total=start)
        self._plans[sig] = plan
        return plan

    def launch(self, key: Tuple,
               members: List[Tuple[str, PRNGService, int, np.ndarray]],
               *, deliver: bool = True) -> Dict[str, Dict[str, np.ndarray]]:
        """One gang launch for ``members`` (each with its prepare_rows plan).

        Every member advances by the same bucketed row count (the group
        max) — overdraw lands in per-client buffers, so delivered words are
        bit-identical to the per-core path (chunk-invariance of the
        absolute-row Weyl indexing).
        """
        from repro.kernels import ops
        svc0 = members[0][1]
        plan = self._plan(key, [(name, svc) for name, svc, _, _ in members])
        n_rows = _round_rows(max(n for _, _, n, _ in members),
                             svc0.config.t_block)
        if plan["mode"] == "stacked":
            x0 = jnp.stack([svc.pool_x for _, svc, _, _ in members])
            offs = np.stack([offsets for _, _, _, offsets in members])
            words, state = ops.chaotic_bits_gang_stacked(
                plan["params"], x0, 2 * n_rows, jnp.asarray(offs),
                activation=svc0.activation, backend=svc0.backend,
                config=svc0.config)
            words = np.asarray(words)
            member_out = [(words[:, ci, :], state[ci])
                          for ci in range(len(members))]
        else:
            parts, offs = [], np.zeros(plan["s_total"], np.uint32)
            for (start, live, padded), (_, svc, _, offsets) in zip(
                    plan["spans"], members):
                parts.append(svc.pool_x)
                if padded > live:  # pad to an s_block boundary (dead lanes)
                    parts.append(jnp.zeros((padded - live, svc0.dim),
                                           svc0.dtype))
                offs[start:start + live] = offsets
            words, state = ops.chaotic_bits_gang(
                plan["params"], jnp.concatenate(parts, axis=0), 2 * n_rows,
                jnp.asarray(offs), core_map=plan["core_map"],
                activation=svc0.activation, backend=svc0.backend,
                config=svc0.config)
            words = np.asarray(words)
            member_out = [(words[:, start:start + live],
                           state[start:start + live])
                          for (start, live, _) in plan["spans"]]
        self.launches += 1
        self._dispatch_keys.add((plan["sig"], n_rows))
        out: Dict[str, Dict[str, np.ndarray]] = {}
        for (mwords, mstate), (name, svc, _, _) in zip(member_out, members):
            served = svc.absorb(mwords, mstate, n_rows, deliver=deliver)
            if served:
                out[name] = served
        return out


class OscillatorFarm:
    """Routes named clients to per-core ``PRNGService`` pools.

    ``gang=True`` (default) enables gang-scheduled flushes: compatible
    cores share one stacked-weight launch per flush.  ``gang=False``
    reproduces the legacy one-launch-per-core behavior — delivered words
    are bit-identical either way (tests/test_gang.py).
    ``auto_flush_rows`` is the coalescing threshold for
    ``request(..., auto_flush=True)``: the farm auto-flushes once total
    pending work reaches that many word rows (None = flush on every
    auto-flush request).
    """

    def __init__(self, *, gang: bool = True,
                 auto_flush_rows: Optional[int] = None):
        self.services: Dict[str, PRNGService] = {}
        self.gang = bool(gang)
        self.auto_flush_rows = auto_flush_rows
        self._sched = GangScheduler()
        self._deferred: set = set()   # cores deferred by the last flush

    # -- core management ----------------------------------------------------

    def add_core(self, core: str, params, *, config=None, dtype=None,
                 activation: str = "relu", lanes_per_client: int = 128,
                 burn_in: int = 16, backend: str = "auto",
                 mesh=None, mesh_axis: str = "data") -> PRNGService:
        """Attach a core (one oscillator network) as a serving pool."""
        if core in self.services:
            raise ValueError(f"core {core!r} already attached")
        svc = PRNGService(params, lanes_per_client=lanes_per_client,
                          burn_in=burn_in, activation=activation,
                          backend=backend, config=config, dtype=dtype,
                          mesh=mesh, mesh_axis=mesh_axis)
        self.services[core] = svc
        return svc

    @classmethod
    def from_generated(cls, farm_dir: str | pathlib.Path,
                       cores: Optional[Iterable[str]] = None,
                       gang: bool = True,
                       auto_flush_rows: Optional[int] = None,
                       **service_kw) -> "OscillatorFarm":
        """Build a farm from a ``generate_farm`` output directory.

        Every subdirectory with weights.npz + solution.json becomes a core;
        its frozen DSE solution is replayed as the service kernel config
        (including the solution's dtype), so serving uses exactly the
        microarchitecture the explorer picked for that system.  One
        adjustment: the solution's stream block is clamped to one client's
        lane block (the same sizing ``PRNGService`` autotunes for) — a
        wider s_block would only compute padding lanes, and since lanes
        evolve independently the clamp is bit-exact.
        """
        import dataclasses
        from repro.core.dse import LANES, Candidate, _pad
        reserved = {"config", "dtype", "activation"} & set(service_kw)
        if reserved:
            raise ValueError(
                f"{sorted(reserved)} are replayed from each core's "
                f"solution.json and cannot be overridden here; use "
                f"add_core() to attach a core with custom values")
        farm_dir = pathlib.Path(farm_dir)
        farm = cls(gang=gang, auto_flush_rows=auto_flush_rows)
        names = sorted(cores) if cores is not None else sorted(
            p.name for p in farm_dir.iterdir()
            if (p / "solution.json").exists() and (p / "weights.npz").exists())
        if not names:
            raise ValueError(f"no generated cores under {farm_dir}")
        lanes = service_kw.get("lanes_per_client", 128)
        p_cap = max(0, (_pad(lanes, LANES) // LANES).bit_length() - 1)
        for name in names:
            sol = json.loads((farm_dir / name / "solution.json").read_text())
            cand = Candidate(**sol["candidate"])
            cand = dataclasses.replace(cand, p=min(cand.p, p_cap))
            params = dict(np.load(farm_dir / name / "weights.npz"))
            farm.add_core(name, params, config=cand,
                          dtype=jnp.dtype(cand.dtype_name),
                          activation=sol.get("activation", "relu"),
                          **service_kw)
        return farm

    @property
    def cores(self) -> Tuple[str, ...]:
        return tuple(self.services)

    def _svc(self, core: str) -> PRNGService:
        try:
            return self.services[core]
        except KeyError:
            raise KeyError(f"unknown core {core!r}; have {sorted(self.services)}")

    # -- client API (per-core routing) --------------------------------------

    def register(self, core: str, client: str,
                 seed: Optional[int] = None) -> None:
        """Register a named client stream on one core's pool."""
        self._svc(core).register(client, seed=seed)

    def request(self, core: str, client: str, n_words: int,
                auto_flush: bool = False) -> None:
        """Queue a draw; served by the next farm-wide flush().

        ``auto_flush=True`` lets small tenants coalesce instead of each
        calling flush(): after queueing, the farm flushes itself once total
        pending work across all cores reaches ``auto_flush_rows`` word rows
        (immediately when that threshold is None).  Words served by an
        auto-flush are parked in the per-service outboxes and returned by
        the tenant's next flush()/draw() — never dropped.
        """
        self._svc(core).request(client, n_words)
        if auto_flush:
            total = sum(svc.rows_needed() for svc in self.services.values())
            if self.auto_flush_rows is None or total >= self.auto_flush_rows:
                self.flush(deliver=False)

    def flush(self, max_wait_rows: Optional[int] = None,
              deliver: bool = True) -> Dict[str, Dict[str, np.ndarray]]:
        """Serve every pending request: one batched launch per core GROUP.

        Cores are grouped by gang-compatibility signature (``_compat_key``);
        each group with pending work costs one stacked-weight launch
        (``gang=False``: one launch per core, the legacy path).  Delivered
        words are bit-identical either way.

        ``max_wait_rows`` is the deadline knob: a group whose total needed
        rows is below it is *deferred* — no launch, its tenants keep
        waiting so the next flush sees a fuller gang — but a group is never
        deferred twice in a row (the deadline: at most one flush cycle).
        Deferred cores deliver nothing this flush.

        ``deliver=False`` parks all served words in the per-service
        outboxes instead of returning them (the auto-flush path).

        Returns {core: {client: words}} for every client that received
        words (pending requests and previously parked outbox words alike).
        """
        plans = {core: svc.prepare_rows()
                 for core, svc in self.services.items()}
        # Group cores that need a launch by compatibility signature.
        groups: Dict[object, List[str]] = {}
        for core, (n_need, _) in plans.items():
            if n_need > 0:
                key = _compat_key(self.services[core]) if self.gang else None
                groups.setdefault(key if key is not None else ("solo", core),
                                  []).append(core)
        launching: List[Tuple[object, List[str]]] = []
        deferred_now: set = set()
        for key, cores in groups.items():
            total = sum(plans[c][0] for c in cores)
            overdue = any(c in self._deferred for c in cores)
            if max_wait_rows is None or total >= max_wait_rows or overdue:
                launching.append((key, cores))
            else:
                deferred_now.update(cores)
        out: Dict[str, Dict[str, np.ndarray]] = {}
        launching_cores = {c for _, cores in launching for c in cores}
        for key, cores in launching:
            if self.gang and len(cores) > 1:
                served = self._sched.launch(
                    key, [(c, self.services[c], plans[c][0], plans[c][1])
                          for c in cores], deliver=deliver)
                out.update(served)
            else:
                for c in cores:
                    svc = self.services[c]
                    n_rows = _round_rows(plans[c][0], svc.config.t_block)
                    words, new_x = svc._launch(n_rows,
                                               jnp.asarray(plans[c][1]))
                    served = svc.absorb(words, new_x, n_rows,
                                        deliver=deliver)
                    if served:
                        out[c] = served
        # Launch-free delivery pass for cores with nothing to launch (their
        # buffers/outboxes may still owe words).  Deferred cores are fully
        # skipped: their buffers do not cover their pending requests yet.
        for core, (n_need, _) in plans.items():
            if core in launching_cores or core in deferred_now:
                continue
            if n_need == 0:
                served = self.services[core].absorb(None, None, 0,
                                                    deliver=deliver)
                if served:
                    out[core] = served
        self._deferred = deferred_now
        return out

    def draw(self, core: str, client: str, n_words: int) -> np.ndarray:
        """Convenience: request + flush one client on one core.

        Only that core's pool launches; other cores are untouched (their
        pending requests keep waiting for the next farm-wide flush()).
        """
        return self._svc(core).draw(client, n_words)

    @property
    def launches(self) -> int:
        """Actual kernel launches issued: per-core launches + gang launches
        (a gang launch advances a whole group but costs ONE launch)."""
        return (sum(svc.launches for svc in self.services.values())
                + self._sched.launches)

    @property
    def gang_launches(self) -> int:
        return self._sched.launches

    @property
    def dispatch_misses(self) -> int:
        """Distinct (group, bucketed rows) gang keys compiled so far."""
        return self._sched.dispatch_misses

    # -- resumability -------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        """Farm-wide snapshot: every core pool, every client, in flight.

        Includes the deadline-deferral set, so a snapshot taken mid-gang
        (between request() and flush(), possibly after a deferring flush)
        replays identically.
        """
        return {"cores": {core: svc.snapshot()
                          for core, svc in self.services.items()},
                "gang_launches": self._sched.launches,
                "deferred": sorted(self._deferred)}

    def restore(self, snap: Dict[str, object]) -> None:
        """Restore a snapshot() onto a farm with the SAME cores attached.

        The core sets must match exactly: restoring onto a farm with extra
        cores would leave those pools in their post-snapshot state (clients,
        pending, outbox) — a silently mixed restore point.
        """
        cores = snap["cores"]
        missing = set(cores) - set(self.services)
        extra = set(self.services) - set(cores)
        if missing or extra:
            raise ValueError(
                f"snapshot/farm core mismatch: snapshot-only {sorted(missing)}, "
                f"farm-only {sorted(extra)}")
        for core, sub in cores.items():
            self.services[core].restore(sub)
        self._sched.launches = int(snap.get("gang_launches", 0))
        self._deferred = set(snap.get("deferred", ()))
