"""Compatibility shim: the injectable clocks live in ``repro.clock``.

The ``Clock`` seam started life serving-only, but the profiling timers in
``train/loop.py`` and ``core/dse.py`` route through the same protocol, so
the implementation moved to the package root (``repro.clock``) where
non-serving layers can import it without a ``serve`` dependency.  This
module re-exports the same objects so every existing ``repro.serve.clock``
import keeps working (identity-preserving: ``isinstance`` checks and
``is`` comparisons across the two import paths hold).
"""
from repro.clock import Clock, FakeClock, SystemClock

__all__ = ["Clock", "FakeClock", "SystemClock"]
