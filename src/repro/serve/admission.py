"""Admission control for the serving tier: rate limits + backpressure.

Without policy, the async front-end's queue grows without bound the moment
aggregate tenant demand outruns the farm's flush rate — every queued
request makes the *next* flush bigger and slower, which makes the queue
grow faster (the classic congestion-collapse spiral).  This module is the
policy layer the front-end consults **before** a request ever enters its
queue:

* **per-tenant token buckets** — each (core, client) pair refills at
  ``rate_words_per_s`` with a burst allowance of ``burst_words``; a draw
  that would overdraw the bucket is rejected with the time at which the
  bucket will next cover it;
* **a farm-wide queued-rows ceiling** — a thread-safe gauge of launch
  rows currently queued in the front-end (each admitted request adds its
  own row estimate, released when the request leaves the queue: flushed,
  cancelled, or pruned).  When the gauge would exceed
  ``max_queued_rows``, further submits are rejected until flushes drain
  the backlog.

Rejections raise :class:`Overloaded` — a *typed* fail-fast error carrying
a ``retry_after_ms`` hint — instead of silently queueing work that cannot
meet any deadline.  In-flight (already admitted) requests are never
affected: the controller only gates entry.

Every time read comes from an injectable ``Clock`` (the same seam as the
rest of the serving stack), so the whole policy is testable under a
manual-advance ``FakeClock`` with zero real sleeps
(tests/test_admission.py).  The gauge and buckets take an internal lock:
``admit`` is safe from any thread, matching ``draw_sync``'s cross-thread
ingress.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
from typing import Dict, Optional, Tuple

from repro.serve.clock import Clock, SystemClock

# Clamp on every Overloaded.retry_after_ms hint.  The floor kills two
# stampede bugs: a sub-millisecond drain time rounds to a 0 ms hint
# (every rejected client retries immediately, in lockstep), and the
# never-admissible path used to leak float("inf") — a client honoring
# the hint literally would back off forever instead of resizing the
# request.  The cap keeps the hint a *retry* hint, not a farewell.
RETRY_FLOOR_MS = 1.0
RETRY_CAP_MS = 60_000.0


class AdaptiveCeiling:
    """Derives the farm-wide queued-rows ceiling from serving throughput
    instead of a hand-set constant.

    The ceiling answers "how many launch rows may queue before new work
    cannot meet any deadline?" — which is throughput times tolerable
    delay:

        ceiling = rows_per_second * target_delay_ms / 1e3

    clamped to [min_rows, max_rows].  ``rows_per_second`` comes from two
    sources, best first:

    * **observed** — a rolling window of the last ``window`` flushes'
      (stage seconds, rows) deltas, fed by ``update_from(farm, rows)``
      reading the farm's ``profile_stats`` stage timers (plan + stack +
      launch + absorb; the farm must be built with ``profile=True``);
    * **modeled** — a cold-start prior from a fitted ``GangCostModel``
      (``cost_model`` + ``candidate``): the modeled seconds of one
      nominal t_block/2-row launch.

    With neither signal the ceiling is ``max_rows`` (no information, so
    do not reject).  Attach via
    ``AdmissionController(adaptive=AdaptiveCeiling(...))`` — rejections
    keep the typed ``Overloaded(retry_after_ms)`` contract, with the
    retry hint upgraded to the modeled time for the backlog to drain.
    """

    _STAGES = ("plan", "stack", "launch", "absorb")

    def __init__(self, *, target_delay_ms: float = 50.0, window: int = 32,
                 min_rows: int = 64, max_rows: int = 1 << 20,
                 cost_model=None, candidate=None,
                 rows_per_launch: Optional[int] = None):
        if target_delay_ms <= 0:
            raise ValueError(
                f"target_delay_ms must be > 0, got {target_delay_ms}")
        self.target_delay_ms = float(target_delay_ms)
        self.window = int(window)
        self.min_rows = int(min_rows)
        self.max_rows = int(max_rows)
        self.cost_model = cost_model
        self.candidate = candidate
        self.rows_per_launch = (None if rows_per_launch is None
                                else max(1, int(rows_per_launch)))
        self._obs: collections.deque = collections.deque(maxlen=self.window)
        self._last_stage_s: Optional[float] = None
        self.updates = 0

    def prior_rows_per_s(self) -> Optional[float]:
        """Cold-start throughput prior from the fitted cost model (None
        without a model fitted to wall time, i.e. ``sec_per_cycle``).

        The modeled launch is shaped like the *plan's* launch: ``q`` is
        the gang plan's actual rows-per-launch when the caller supplied
        it (``rows_per_launch``), else one nominal t_block/2-row block.
        The candidate's dims carry the per-row cost, so a lattice core
        (i_dim = n_nodes x base dim, plus the coupling term) models
        n_nodes-fold slower rows instead of inheriting a scalar core's
        prior and over-admitting on cold start."""
        m, c = self.cost_model, self.candidate
        if m is None or c is None or getattr(m, "sec_per_cycle", None) is None:
            return None
        q = self.rows_per_launch or max(1, c.t_block // 2)
        sec = m.seconds(m.launch_cycles(c, [q]))
        return q / sec if sec and sec > 0 else None

    def observe(self, seconds: float, rows: int) -> None:
        """Record one flush: ``rows`` launch rows served in ``seconds``
        of flush stage time."""
        if seconds > 0 and rows > 0:
            self._obs.append((float(seconds), int(rows)))
            self.updates += 1

    def update_from(self, farm, rows_flushed: int) -> None:
        """Feed one completed flush from the farm's ``profile_stats``
        stage timers (no-op on farms built without ``profile=True``)."""
        stats = farm.profile_stats
        if stats is None:
            return
        total = sum(stats.get(k, 0.0) for k in self._STAGES)
        if self._last_stage_s is not None:
            self.observe(total - self._last_stage_s, rows_flushed)
        self._last_stage_s = total

    def rows_per_s(self) -> Optional[float]:
        """Observed rolling-window throughput, else the model prior."""
        if self._obs:
            sec = sum(s for s, _ in self._obs)
            rows = sum(r for _, r in self._obs)
            if sec > 0:
                return rows / sec
        return self.prior_rows_per_s()

    def ceiling(self) -> int:
        """The current queued-rows ceiling."""
        rps = self.rows_per_s()
        if rps is None:
            return self.max_rows
        return int(min(self.max_rows,
                       max(self.min_rows,
                           rps * self.target_delay_ms / 1e3)))


class Overloaded(RuntimeError):
    """A submit was rejected by admission control (fail fast, retry later).

    ``retry_after_ms`` is the caller's backoff hint: for a tenant-rate
    rejection it is the time until the token bucket covers the request;
    for a farm-ceiling rejection it is the controller's configured hint
    (the queue drains on flushes, whose timing the controller cannot
    know).  ``scope`` is ``"tenant"`` or ``"farm"``.  The hint is always
    a positive finite number in ``[RETRY_FLOOR_MS, RETRY_CAP_MS]``: a
    0 ms hint synchronizes every rejected client into a retry stampede,
    and an infinite one (the never-admissible oversized path) tells a
    literal-minded client to wait forever — both clamp.
    """

    def __init__(self, message: str, *, retry_after_ms: float, scope: str,
                 core: Optional[str] = None, client: Optional[str] = None):
        super().__init__(message)
        retry = float(retry_after_ms)
        if not math.isfinite(retry):
            retry = RETRY_CAP_MS
        self.retry_after_ms = min(RETRY_CAP_MS, max(RETRY_FLOOR_MS, retry))
        self.scope = scope
        self.core = core
        self.client = client


@dataclasses.dataclass
class _Bucket:
    """One tenant's token bucket (tokens are words)."""
    rate: float               # words per second
    burst: float              # bucket capacity, words
    tokens: float             # current fill
    stamp: float              # clock time of the last refill

    def refill(self, now: float) -> None:
        if now > self.stamp:
            self.tokens = min(self.burst, self.tokens
                              + (now - self.stamp) * self.rate)
        self.stamp = now

    def try_take(self, n: float, now: float) -> float:
        """Take ``n`` tokens; returns 0.0 on success, else the seconds
        until the bucket will cover ``n`` (state unchanged on failure)."""
        self.refill(now)
        if n <= self.tokens:
            self.tokens -= n
            return 0.0
        if self.rate <= 0.0 or n > self.burst:
            return float("inf")       # no amount of waiting covers this
        return (n - self.tokens) / self.rate


class AdmissionController:
    """Gates front-end submits: per-tenant rate limits + a farm ceiling.

    Parameters
    ----------
    rate_words_per_s / burst_words
        Default per-tenant token-bucket parameters; ``None`` disables
        tenant rate limiting.  A request larger than ``burst_words`` can
        never be admitted (rejected with an infinite retry hint) — size
        the burst to the largest legitimate draw.
    max_queued_rows
        Farm-wide ceiling on launch rows queued in the front-end;
        ``None`` disables the ceiling.  The gauge counts each admitted
        request's own row estimate (``ceil(n_words / lanes)``) — it is
        deliberately conservative: a request coverable from a client's
        buffer still counts, because admission runs before the farm is
        consulted.
    adaptive
        An :class:`AdaptiveCeiling`; when set it supersedes
        ``max_queued_rows`` — the ceiling tracks measured flush
        throughput (feed it from the front-end via ``update_from``) with
        a fitted-``GangCostModel`` prior before any measurement exists.
    ceiling_retry_ms
        The minimum ``retry_after_ms`` hint attached to farm-ceiling
        rejections (an adaptive ceiling raises it to the modeled
        backlog-drain time).
    per_tenant
        ``{(core, client): (rate_words_per_s, burst_words)}`` overrides
        for specific tenants (e.g. a paid tier).
    """

    def __init__(self, *, rate_words_per_s: Optional[float] = None,
                 burst_words: Optional[float] = None,
                 max_queued_rows: Optional[int] = None,
                 adaptive: Optional[AdaptiveCeiling] = None,
                 ceiling_retry_ms: float = 5.0,
                 per_tenant: Optional[Dict[Tuple[str, str],
                                           Tuple[float, float]]] = None,
                 clock: Optional[Clock] = None):
        if (rate_words_per_s is None) != (burst_words is None):
            raise ValueError("rate_words_per_s and burst_words must be "
                             "set together")
        self.rate_words_per_s = rate_words_per_s
        self.burst_words = burst_words
        self.max_queued_rows = max_queued_rows
        self.adaptive = adaptive
        self.ceiling_retry_ms = float(ceiling_retry_ms)
        self.clock: Clock = clock or SystemClock()
        self._overrides = dict(per_tenant or {})
        self._buckets: Dict[Tuple[str, str], _Bucket] = {}
        self._lock = threading.Lock()
        self._queued_rows = 0
        # Degraded-mode accounting: the supervision layer sets this to
        # (healthy cores / total cores) on quarantine/rotation, shrinking
        # the queued-rows ceiling with the lost capacity.
        self._capacity_factor = 1.0
        self.admitted = 0
        self.rejected_tenant = 0
        self.rejected_farm = 0

    # -- gauge ---------------------------------------------------------------

    @property
    def queued_rows(self) -> int:
        """Launch rows currently admitted into (and not yet released from)
        the front-end queue."""
        return self._queued_rows

    @property
    def capacity_factor(self) -> float:
        """Serving capacity still healthy, in [0, 1] (1.0 = full farm)."""
        return self._capacity_factor

    def set_capacity_factor(self, factor: float) -> None:
        """Scale the queued-rows ceiling by the healthy-capacity fraction
        (the supervision layer calls this on quarantine and rotation —
        a quarantined core's launch throughput is gone, so the backlog
        the farm can drain in bounded delay shrinks with it)."""
        with self._lock:
            self._capacity_factor = min(1.0, max(0.0, float(factor)))

    @property
    def current_ceiling(self) -> Optional[int]:
        """The queued-rows ceiling in force right now: the adaptive
        ceiling when attached, else the static ``max_queued_rows`` —
        either one scaled by the degraded-capacity factor.

        A degraded farm must never quantize to a zero ceiling: a small
        base times a reduced-but-nonzero capacity factor used to round
        to 0 and reject *all* traffic while healthy cores remained.
        Whenever ``capacity_factor > 0`` the scaled ceiling is floored
        at the adaptive ``min_rows`` (one row for a static ceiling),
        never exceeding the undegraded base.  A factor of exactly 0
        (every core quarantined) still means a zero ceiling.

        Lock-free on purpose: ``admit`` reads it while holding the
        controller lock, and ``set_capacity_factor`` publishes a single
        float (atomic under the GIL)."""
        base = (self.adaptive.ceiling() if self.adaptive is not None
                else self.max_queued_rows)
        if base is None:
            return None
        scaled = int(base * self._capacity_factor)
        if self._capacity_factor > 0.0:
            floor = (self.adaptive.min_rows if self.adaptive is not None
                     else 1)
            scaled = max(scaled, min(int(base), floor))
        return scaled

    def release(self, rows: int) -> None:
        """Return ``rows`` to the ceiling gauge (request left the queue:
        committed to a flush, cancelled, or pruned)."""
        with self._lock:
            self._queued_rows = max(0, self._queued_rows - int(rows))

    # -- the gate ------------------------------------------------------------

    def _bucket(self, core: str, client: str,
                now: float) -> Optional[_Bucket]:
        key = (core, client)
        b = self._buckets.get(key)
        if b is None:
            rb = self._overrides.get(key)
            if rb is not None:
                rate, burst = rb
            elif self.rate_words_per_s is not None:
                rate, burst = self.rate_words_per_s, self.burst_words
            else:
                return None
            b = _Bucket(rate=float(rate), burst=float(burst),
                        tokens=float(burst), stamp=now)
            self._buckets[key] = b
        return b

    def admit(self, core: str, client: str, n_words: int,
              rows_est: int) -> None:
        """Admit one request of ``n_words`` (``rows_est`` launch rows) or
        raise :class:`Overloaded`.  On success the ceiling gauge grows by
        ``rows_est``; the caller owes a matching :meth:`release` when the
        request leaves the queue."""
        now = self.clock.now()
        with self._lock:
            ceiling = self.current_ceiling
            if (ceiling is not None
                    and self._queued_rows + rows_est > ceiling):
                self.rejected_farm += 1
                retry_ms = self.ceiling_retry_ms
                if self.adaptive is not None:
                    # upgrade the hint to the modeled time for the excess
                    # backlog to drain at the observed flush rate
                    rps = self.adaptive.rows_per_s()
                    if rps is not None and rps > 0:
                        excess = self._queued_rows + rows_est - ceiling
                        retry_ms = max(retry_ms, excess / rps * 1e3)
                raise Overloaded(
                    f"farm over queued-rows ceiling: "
                    f"{self._queued_rows} + {rows_est} > "
                    f"{ceiling} rows queued",
                    retry_after_ms=retry_ms, scope="farm",
                    core=core, client=client)
            b = self._bucket(core, client, now)
            if b is not None:
                wait_s = b.try_take(float(n_words), now)
                if wait_s > 0.0:
                    self.rejected_tenant += 1
                    raise Overloaded(
                        f"tenant {core}/{client} over rate limit "
                        f"({n_words} words > {b.tokens:.0f} available)",
                        retry_after_ms=wait_s * 1e3, scope="tenant",
                        core=core, client=client)
            self._queued_rows += int(rows_est)
            self.admitted += 1

    def stats(self) -> Dict[str, float]:
        """Admission counters: admitted / rejected by scope + the live
        queued-rows gauge and the ceiling currently in force (-1 when
        uncapped)."""
        ceiling = self.current_ceiling
        return {"admitted": float(self.admitted),
                "rejected_tenant": float(self.rejected_tenant),
                "rejected_farm": float(self.rejected_farm),
                "queued_rows": float(self._queued_rows),
                "ceiling": -1.0 if ceiling is None else float(ceiling),
                "capacity_factor": float(self._capacity_factor)}
