"""Asyncio serving front-end: deadline-driven gang flushes, no manual flush().

The gang kernels and planner (``serve/farm.py``) amortize launch overhead
across cores — but only for tenants that coordinate their ``flush()``
calls by hand.  ``AsyncOscillatorFarm`` closes that gap: every tenant just
``await draw(core, client, n_words, deadline_ms=...)`` and a single
background *flusher* task coalesces pending demand across all tenants and
coroutines, firing one planner-shaped gang flush when either

  * the earliest wall-clock **deadline** among queued requests expires, or
  * **auto_flush_rows** worth of launch work has accumulated (counted in
    launch rows via ``PRNGService.rows_needed_with`` — a request coverable
    from a client's buffer adds no rows), whichever comes first.

Tenants on different threads participate through a thread-safe ingress
(a deque appended from any thread + ``loop.call_soon_threadsafe`` to wake
the flusher); sync callers block on ``draw_sync``.

**The production tier** (everything below is optional and off by default
except offload):

* **Executor offload** (``offload=True``): a flush is split into an
  on-loop *commit* phase (requests enter the services, demand freezes, an
  asyncio future can no longer be cancelled and a concurrent future is
  moved to RUNNING) and an off-loop *launch* phase — ``farm.flush
  (deliver=False)`` runs on a worker thread via ``run_in_executor``, so
  ingress, cancellation, and deadline accounting stay live while a slow
  gang launch is in flight.  Served words park in the service outboxes as
  each group absorbs; the launch-free delivery pass + FIFO split run back
  on the loop.  A single-flight ``asyncio.Lock`` guarantees two flushes
  never interleave ``absorb()`` against one farm — the committed batch is
  the *only* demand the in-flight launch serves, so requests arriving
  mid-launch wait for the next cycle and bit-identity to the solo path is
  preserved (property-tested with mid-launch submits/cancels).

* **Admission control** (``admission=AdmissionController(...)``,
  ``repro.serve.admission``): per-tenant token buckets and a farm-wide
  queued-rows ceiling gate every submit *before* it queues; over-limit
  submits fail fast with a typed ``Overloaded`` carrying a
  ``retry_after_ms`` hint.  Already-admitted futures always resolve.

* **SLO classes** (``slo=`` per request): ``"latency"`` demand forbids
  the padded group-max launch shape when demand is skewed (the planner
  must pick ragged/split, so a latency tenant never waits for co-tenants'
  overdraw rows); ``"bulk"`` demand always rides the padded,
  maximally-amortized launch.  SLO never changes delivered words — only
  the launch shape that serves them.

* **Crash recovery** (``journal=`` a ``FlushJournal`` or path,
  ``repro.serve.journal``): one appended record per completed flush
  (per-client row/pending/buffer/outbox positions) + one per
  registration.  A restarted process rebuilds the same farm and calls
  ``journal.replay_journal(farm, path)`` to resume every tenant stream
  bit-exactly at the last flush boundary.

Determinism contract (tests/test_async_frontend.py): delivered words are
bit-identical per tenant to the sync ``gang=False`` solo path, however
requests interleave, coalesce, or get cancelled — a direct consequence of
the farm's chunk-invariant absolute-row indexing plus two front-end rules:

  * a request enters the farm (``svc.request``) only at flush-commit
    time, so cancelling a queued future rolls its demand back by simply
    never submitting it;
  * a flush's returned words are split FIFO per (core, client): words owed
    to the sync surface (pre-existing service pending + outbox backlog)
    are re-parked via ``PRNGService.park`` — never dropped — and the tail
    resolves this front-end's futures in submission order.

Every time read goes through the injectable ``Clock``
(``repro.serve.clock``): under a manual-advance ``FakeClock`` the flusher
wakes exactly when the test advances fake time past a deadline, so every
deadline/coalescing behavior is testable with zero real sleeps.

``snapshot()`` quiesces in-flight futures: it waits out any launch in
flight (single-flight lock), drains the ingress, and folds still-queued
front-end demand into the per-client ``pending`` counts of the farm
snapshot.  Restoring that snapshot anywhere — a plain sync farm or
another front-end — replays the in-flight draws through the sync surface
(next ``flush()``), bit-identically to what the live futures receive.
The live front-end keeps serving its own futures after the snapshot.
"""
from __future__ import annotations

import asyncio
import collections
import concurrent.futures
import dataclasses
import functools
import os
import threading
from typing import Deque, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.serve.admission import AdmissionController
from repro.serve.clock import Clock, SystemClock
from repro.serve.farm import OscillatorFarm
from repro.serve.health import CoreQuarantined, HealthMonitor
from repro.serve.journal import FlushJournal

_Future = Union["asyncio.Future", "concurrent.futures.Future"]

_SLO_CLASSES = (None, "latency", "bulk")


@dataclasses.dataclass
class _Request:
    core: str
    client: str
    n_words: int
    deadline: float            # absolute, in this front-end's clock
    future: _Future
    slo: Optional[str] = None
    rows_est: int = 0          # admission gauge units owed back on dequeue
    released: bool = False


def percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, int(round(q * (len(s) - 1))))]


class AsyncOscillatorFarm:
    """Async front-end over an ``OscillatorFarm``: futures in, gang
    flushes out.

    Two ways to run the flusher:

      * ``async with AsyncOscillatorFarm(farm) as af`` (or ``await
        af.start()`` / ``await af.aclose()``) inside an existing event
        loop — the deterministic-test mode;
      * ``af.start_thread()`` / ``af.close()`` — a daemon thread owns the
        loop, and sync callers on any thread use ``draw_sync``.

    ``deadline_ms`` is *relative* wall-clock budget per request; ``None``
    falls back to ``default_deadline_ms`` (its own ``None`` meaning
    "flush at the next flusher pass", i.e. no intentional batching delay).
    A flush serves EVERY queued request, not just the due ones — riders
    amortize the launch the deadline paid for.

    ``offload=True`` (default) runs the launch phase of every flush on a
    worker thread so the event loop stays live; ``offload=False`` pins
    the PR 5 on-loop behavior (the benchmark baseline).  ``executor``
    optionally supplies the worker pool (otherwise a single-thread
    executor is owned and shut down with the front-end).

    ``stats_window`` / ``error_window`` bound ``deadline_stats()`` and
    ``flush_errors`` to the most recent N samples/errors (ring buffers) —
    a long-running front-end holds constant memory.

    ``health=HealthMonitor(...)`` arms the supervision layer
    (``repro.serve.health``): transient launch failures are retried with
    capped exponential backoff under the single-flight lock (the batch's
    demand stays parked at the same absolute stream rows, so retried
    words are bit-identical to a never-failed flush); consecutive
    failures trip a per-core circuit breaker; and an online NIST gate
    over words each core actually served quarantines a degraded core —
    rotating its standby into the routing slot when the farm has one,
    failing its tenants with a typed ``CoreQuarantined`` otherwise.
    Quarantines/rotations are journaled (when a journal is attached) and
    shrink the admission ceiling by the lost capacity fraction.
    """

    def __init__(self, farm: OscillatorFarm, *,
                 auto_flush_rows: Optional[int] = None,
                 default_deadline_ms: Optional[float] = None,
                 clock: Optional[Clock] = None,
                 offload: bool = True,
                 executor: Optional[concurrent.futures.Executor] = None,
                 admission: Optional[AdmissionController] = None,
                 journal: Union[FlushJournal, str, os.PathLike, None] = None,
                 health: Optional[HealthMonitor] = None,
                 stats_window: int = 4096,
                 error_window: int = 64):
        self.farm = farm
        self.health = health
        if health is not None:
            farm.attach_monitor(health)
        self.auto_flush_rows = auto_flush_rows
        self.default_deadline_ms = default_deadline_ms
        self.clock: Clock = clock or farm.clock or SystemClock()
        self.admission = admission
        self._own_journal = journal is not None and not isinstance(
            journal, FlushJournal)
        self.journal: Optional[FlushJournal] = (
            FlushJournal(journal, clock=self.clock) if self._own_journal
            else journal)
        self._offload = bool(offload)
        self._executor = executor
        self._own_executor = False
        self._queue: List[_Request] = []
        self._ingress: Deque[_Request] = collections.deque()
        self._wake: Optional[asyncio.Event] = None
        self._drain_waiters: List[asyncio.Future] = []
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._loop_thread: Optional[int] = None
        self._task: Optional[asyncio.Task] = None
        self._thread: Optional[threading.Thread] = None
        self._stop: Optional[asyncio.Event] = None
        self._flush_lock: Optional[asyncio.Lock] = None
        self._inflight = False
        self.flushes = 0
        self.served_words = 0
        # Ring buffers: a long-running front-end must not grow linearly in
        # served requests / failures.  deadline_stats() is windowed to the
        # stats_window most recent samples.
        self._miss_ms: Deque[float] = collections.deque(maxlen=stats_window)
        # flush failures survive here (each batch future also carries its
        # exception); the flusher itself never dies except by aclose()
        self.flush_errors: Deque[BaseException] = collections.deque(
            maxlen=error_window)

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> "AsyncOscillatorFarm":
        """Start the flusher task on the currently running loop."""
        if self._task is not None:
            raise RuntimeError("front-end already started")
        self._loop = asyncio.get_running_loop()
        self._loop_thread = threading.get_ident()
        self._wake = asyncio.Event()
        self._flush_lock = asyncio.Lock()
        if self._offload and self._executor is None:
            self._executor = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="farm-launch")
            self._own_executor = True
        self._task = self._loop.create_task(self._run())
        return self

    async def aclose(self) -> None:
        """Stop the flusher; still-queued futures are cancelled.

        An in-flight offloaded launch is allowed to FINISH (executor
        shutdown waits): its words are already parked in the service
        outboxes by the ``deliver=False`` pass, so nothing is lost — they
        surface on the sync surface, same as the partial-failure path.
        """
        if self._task is not None:
            self._task.cancel()
            await asyncio.gather(self._task, return_exceptions=True)
            self._task = None
        if self._own_executor and self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
            self._own_executor = False
        self._ingest()
        for r in self._queue:
            self._release(r)
            r.future.cancel()
        self._queue.clear()
        for w in self._drain_waiters:
            if not w.done():
                w.set_result(None)
        self._drain_waiters.clear()
        if self._own_journal and self.journal is not None:
            self.journal.close()

    async def __aenter__(self) -> "AsyncOscillatorFarm":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    def start_thread(self) -> "AsyncOscillatorFarm":
        """Run the event loop + flusher on a daemon thread (sync callers
        then use ``draw_sync`` from any thread)."""
        if self._thread is not None or self._task is not None:
            raise RuntimeError("front-end already started")
        started = threading.Event()
        self._thread = threading.Thread(
            target=lambda: asyncio.run(self._thread_body(started)),
            name="async-farm-flusher", daemon=True)
        self._thread.start()
        started.wait()
        return self

    async def _thread_body(self, started: threading.Event) -> None:
        self._stop = asyncio.Event()
        await self.start()
        started.set()
        await self._stop.wait()
        await self.aclose()

    def close(self) -> None:
        """Stop a ``start_thread`` front-end and join its thread."""
        if self._thread is None:
            return
        self._loop.call_soon_threadsafe(self._stop.set)
        self._thread.join()
        self._thread = None
        self._loop = None

    # -- client surface ------------------------------------------------------

    def register(self, core: str, client: str,
                 seed: Optional[int] = None) -> None:
        """Register a tenant stream (do this before serving traffic; it is
        not synchronized against a running flusher on another thread).
        With a journal attached, the registration — including the seed
        actually used — is journaled so crash recovery re-derives the
        identical stream."""
        self.farm.register(core, client, seed=seed)
        if self.journal is not None:
            self.journal.record_register(
                core, client, self.farm.services[core].clients[client].seed)

    def _deadline(self, deadline_ms: Optional[float]) -> float:
        if deadline_ms is None:
            deadline_ms = self.default_deadline_ms
        if deadline_ms is None:
            deadline_ms = 0.0
        return self.clock.now() + float(deadline_ms) / 1e3

    def _validate(self, core: str, client: str, n_words: int,
                  slo: Optional[str]) -> None:
        svc = self.farm.services.get(core)
        if svc is None:
            raise KeyError(f"unknown core {core!r}; "
                           f"have {sorted(self.farm.services)}")
        self.farm._check_serving(core)   # fail fast: CoreQuarantined
        if client not in svc.clients:
            raise KeyError(f"client {client!r} not registered on {core!r}")
        if n_words < 0:
            raise ValueError(f"n_words must be >= 0, got {n_words}")
        if slo not in _SLO_CLASSES:
            raise ValueError(f"slo must be one of {_SLO_CLASSES}, "
                             f"got {slo!r}")

    def _admit(self, core: str, client: str, n_words: int) -> int:
        """Admission gate (may raise ``Overloaded``); returns the request's
        launch-row estimate owed back to the ceiling gauge on dequeue."""
        rows_est = -(-int(n_words)
                     // self.farm.services[core].lanes_per_client)
        if self.admission is not None:
            self.admission.admit(core, client, n_words, rows_est)
        return rows_est

    def _release(self, r: _Request) -> None:
        """Return a dequeued request's rows to the admission gauge
        (exactly once per request)."""
        if not r.released:
            r.released = True
            if self.admission is not None:
                self.admission.release(r.rows_est)

    def submit(self, core: str, client: str, n_words: int,
               deadline_ms: Optional[float] = None,
               slo: Optional[str] = None) -> asyncio.Future:
        """Queue a draw from the loop thread; returns the tenant's future.

        The future resolves with exactly ``n_words`` uint32 words once a
        flush (deadline- or threshold-triggered) serves it.  Cancelling it
        while queued rolls the demand back cleanly — the farm never sees
        the request, and no other tenant's stream shifts.

        Loop-thread only (enforced): an asyncio future and the queue are
        not thread-safe, so a foreign-thread caller must use ``draw_sync``
        (the thread-safe ingress) instead.
        """
        if self._task is None:
            raise RuntimeError("front-end not started")
        if threading.get_ident() != self._loop_thread:
            raise RuntimeError(
                "submit() called from a foreign thread would race the "
                "queue unsynchronized; use draw_sync() (the thread-safe "
                "ingress) there")
        self._validate(core, client, n_words, slo)
        fut = self._loop.create_future()
        if n_words == 0:
            fut.set_result(np.empty(0, np.uint32))
            return fut
        rows_est = self._admit(core, client, n_words)
        self._queue.append(_Request(core, client, int(n_words),
                                    self._deadline(deadline_ms), fut,
                                    slo=slo, rows_est=rows_est))
        self._wake.set()
        return fut

    async def draw(self, core: str, client: str, n_words: int,
                   deadline_ms: Optional[float] = None,
                   slo: Optional[str] = None) -> np.ndarray:
        """``await`` one tenant draw (see ``submit``)."""
        return await self.submit(core, client, n_words, deadline_ms, slo)

    def draw_sync(self, core: str, client: str, n_words: int,
                  deadline_ms: Optional[float] = None,
                  timeout: Optional[float] = None,
                  slo: Optional[str] = None) -> np.ndarray:
        """Blocking draw from ANY thread: the thread-safe ingress.

        Appends the request to a cross-thread deque and wakes the flusher
        with ``call_soon_threadsafe``; blocks on a
        ``concurrent.futures.Future`` until the coalesced flush serves it.

        On ``timeout`` the request is PRUNED: a still-queued future is
        cancelled (its demand rolls back — the farm never sees it, and no
        stats are recorded for it); a request already committed to an
        in-flight flush cannot be un-launched, so its words are routed
        back to the service outbox when they arrive — the stream stays
        gap-free either way, and no launch rows are ever spent on a
        future nobody reads twice.
        """
        if self._task is None or self._loop is None:
            # _task (not just _loop) is the liveness flag: after aclose()
            # the loop object may survive with no flusher to serve us
            raise RuntimeError("front-end not started")
        try:
            running = asyncio.get_running_loop()
        except RuntimeError:
            running = None
        if running is self._loop:
            # blocking the loop thread would starve the flusher forever
            raise RuntimeError(
                "draw_sync called from the event-loop thread would "
                "deadlock; use `await draw(...)` / submit() there")
        self._validate(core, client, n_words, slo)
        cfut: concurrent.futures.Future = concurrent.futures.Future()
        if n_words == 0:
            cfut.set_result(np.empty(0, np.uint32))
            return cfut.result()
        rows_est = self._admit(core, client, n_words)
        self._ingress.append(_Request(core, client, int(n_words),
                                      self._deadline(deadline_ms), cfut,
                                      slo=slo, rows_est=rows_est))
        self._loop.call_soon_threadsafe(self._wake.set)
        try:
            return cfut.result(timeout)
        except concurrent.futures.TimeoutError:
            if not cfut.cancel():
                # Too late to prune: the flush already committed this
                # request (future RUNNING) or resolved it.  Re-park the
                # words on the sync surface so the stream stays gap-free
                # instead of stranding them in a future nobody reads.
                def _repark(f: concurrent.futures.Future) -> None:
                    if not f.cancelled() and f.exception() is None:
                        self.farm.services[core].park(client, f.result())
                cfut.add_done_callback(
                    lambda f: self._loop.call_soon_threadsafe(_repark, f))
            # wake the flusher so a cancelled request is pruned promptly
            # (it may hold the earliest deadline)
            self._loop.call_soon_threadsafe(self._wake.set)
            raise

    async def drain(self) -> None:
        """Wait until the flusher has no currently-actionable work left
        (every due flush performed — including any launch in flight;
        remaining requests are all waiting on future deadlines / more
        coalescing)."""
        if self._task is None:
            raise RuntimeError("front-end not started")
        fut = self._loop.create_future()
        self._drain_waiters.append(fut)
        self._wake.set()
        await fut

    async def flush_now(self) -> None:
        """Force one flush of everything queued, deadlines notwithstanding.

        A flush failure is recorded in ``flush_errors`` (same as the
        background path) and re-raised to this caller; the batch's
        futures carry it either way.  Serialized against the background
        flusher by the single-flight lock.
        """
        if self._task is None:
            raise RuntimeError("front-end not started")
        self._ingest()
        if self._queue:
            try:
                await self._flush_cycle()
            # repro: allow[broad-except] reason=record-and-reraise: any flush failure must land in flush_errors exactly like the background path before propagating to this caller
            except Exception as e:
                self.flush_errors.append(e)
                raise
        await self.drain()

    # -- introspection -------------------------------------------------------

    @property
    def pending_requests(self) -> int:
        """Queued front-end draws not yet served (ingress included)."""
        return (sum(1 for r in self._queue if not r.future.cancelled())
                + len(self._ingress))

    @property
    def in_flight(self) -> bool:
        """True while a committed flush's launch phase is running (the
        window during which ingress must stay live under offload)."""
        return self._inflight

    @property
    def loop(self) -> Optional[asyncio.AbstractEventLoop]:
        """The event loop serving this front-end (``None`` before start) —
        for foreign threads that need ``run_coroutine_threadsafe``."""
        return self._loop

    @property
    def launches(self) -> int:
        return self.farm.launches

    def pending_rows(self) -> int:
        """Launch rows the queued front-end demand would add on top of the
        farm's own pending — the quantity compared against
        ``auto_flush_rows``."""
        extra: Dict[str, Dict[str, int]] = {}
        for r in self._queue:
            if not r.future.cancelled():
                per = extra.setdefault(r.core, {})
                per[r.client] = per.get(r.client, 0) + r.n_words
        return sum(svc.rows_needed_with(extra.get(core))
                   for core, svc in self.farm.services.items())

    def miss_samples_ms(self) -> List[float]:
        """Recorded deadline-miss samples (ms past deadline, 0 = on time),
        oldest first — the raw series behind ``deadline_stats()``; public
        so benchmarks can window it (e.g. timed region only).  Bounded to
        the ``stats_window`` most recent samples."""
        return list(self._miss_ms)

    def deadline_stats(self) -> Dict[str, float]:
        """p50/p99/max deadline-miss latency (ms) over the most recent
        ``stats_window`` served requests (ring buffer — a long-running
        front-end reports a sliding window, not all-time); a request
        served before its deadline counts as 0 miss."""
        return {"served_requests": float(len(self._miss_ms)),
                "p50_miss_ms": percentile(list(self._miss_ms), 0.50),
                "p99_miss_ms": percentile(list(self._miss_ms), 0.99),
                "max_miss_ms": max(self._miss_ms, default=0.0)}

    # -- flusher -------------------------------------------------------------

    def _ingest(self) -> None:
        """Move thread-ingress requests into the queue; prune cancelled
        (returning their rows to the admission gauge)."""
        while self._ingress:
            self._queue.append(self._ingress.popleft())
        keep = []
        for r in self._queue:
            if r.future.cancelled():
                self._release(r)
            else:
                keep.append(r)
        self._queue = keep

    def _earliest_deadline(self) -> Optional[float]:
        return min((r.deadline for r in self._queue), default=None)

    def _due(self) -> bool:
        if not self._queue:
            return False
        if self._earliest_deadline() <= self.clock.now():
            return True
        return (self.auto_flush_rows is not None
                and self.pending_rows() >= self.auto_flush_rows)

    def _commit(self) -> Optional[Tuple[List[_Request],
                                        Dict[Tuple[str, str], int],
                                        Dict[Tuple[str, str],
                                             List[_Request]],
                                        Dict[str, str]]]:
        """On-loop commit phase: freeze the queued demand into the farm.

        Runs synchronously on the loop thread, so nothing interleaves with
        it: an asyncio future can no longer be cancelled once committed,
        and a concurrent future is moved to RUNNING first (late
        ``cancel()`` calls fail instead of racing the launch).  After
        commit, the batch is the ONLY demand the launch phase serves —
        requests arriving mid-launch stay queued for the next cycle.
        """
        batch: List[_Request] = []
        quarantined = self.farm.quarantined
        for r in self._queue:
            self._release(r)
            f = r.future
            if isinstance(f, concurrent.futures.Future):
                if not f.set_running_or_notify_cancel():
                    continue               # cancelled: demand rolled back
            elif f.cancelled():
                continue
            if r.core in quarantined:
                # quarantined with no standby after this request queued:
                # its demand never enters the farm
                f.set_exception(CoreQuarantined(
                    f"core {r.core!r} quarantined while request was "
                    f"queued", core=r.core, reason="quarantined"))
                continue
            batch.append(r)
        self._queue = []
        if not batch:
            return None
        # Words the sync surface is owed come FIRST in each client's flush
        # output (outbox backlog, then earlier-requested service pending);
        # record the counts so the split below can re-park them.
        owed: Dict[Tuple[str, str], int] = {}
        for core, svc in self.farm.services.items():
            for name in svc.clients:
                n = svc.pending_words(name) + svc.outbox_words(name)
                if n:
                    owed[(core, name)] = n
        fifo: Dict[Tuple[str, str], List[_Request]] = {}
        slos: Dict[str, set] = {}
        for r in batch:
            self.farm.services[r.core].request(r.client, r.n_words)
            fifo.setdefault((r.core, r.client), []).append(r)
            slos.setdefault(r.core, set()).add(r.slo)
        slo_by_core = {}
        for core, classes in slos.items():
            if "latency" in classes:
                slo_by_core[core] = "latency"
            elif classes == {"bulk"}:
                slo_by_core[core] = "bulk"
        return batch, owed, fifo, slo_by_core

    def _resolve(self, batch: List[_Request],
                 owed: Dict[Tuple[str, str], int],
                 fifo: Dict[Tuple[str, str], List[_Request]]) -> None:
        """On-loop resolution phase: launch-free delivery + FIFO split.

        Every group already absorbed its words into the service outboxes
        during the launch phase (``deliver=False``), so this second
        ``farm.flush()`` performs no kernel launch — it only drains
        outboxes (cheap, safe on the loop thread) and its content/order
        is identical to a ``deliver=True`` flush.
        """
        out = self.farm.flush()
        now = self.clock.now()
        self.flushes += 1
        for core, per_client in out.items():
            for client, words in per_client.items():
                head = owed.get((core, client), 0)
                if head:
                    self.farm.services[core].park(client, words[:head])
                pos = head
                for r in fifo.pop((core, client), ()):
                    r.future.set_result(words[pos:pos + r.n_words])
                    pos += r.n_words
                    self.served_words += r.n_words
                    self._miss_ms.append(
                        max(0.0, now - r.deadline) * 1e3)
                if pos != len(words):
                    raise AssertionError(
                        f"flush word accounting broken for "
                        f"{core}/{client}: {len(words)} words, "
                        f"consumed {pos}")
        if fifo:
            raise AssertionError(
                f"flush served no words for queued requests: "
                f"{sorted(fifo)}")

    async def _launch(self, slo_by_core: Dict[str, str]) -> None:
        """The launch phase of one flush (executor when ``offload``)."""
        launch = functools.partial(self.farm.flush, deliver=False,
                                   slo_by_core=slo_by_core)
        if self._offload:
            # The loop stays live here: submits, cancellations,
            # draw_sync ingress, and deadline tracking all proceed
            # while the launch runs on the worker thread.
            await self._loop.run_in_executor(self._executor, launch)
        else:
            launch()

    async def _launch_with_retries(self, batch: List[_Request],
                                   fifo: Dict[Tuple[str, str],
                                              List[_Request]],
                                   slo_by_core: Dict[str, str]) -> None:
        """Launch the committed batch, supervised (``health=``).

        A failed launch never reached ``absorb()`` for the failed group:
        its demand is still parked at the same absolute stream rows, so a
        retry (after capped exponential backoff through the injected
        clock — FakeClock-drivable, zero real sleeps) serves words
        bit-identical to a never-failed flush.  Groups that absorbed
        before the failure have zero remaining demand and are skipped by
        the retry's ``prepare_rows`` — never launched twice.  A core
        whose consecutive failures trip the breaker is quarantined
        mid-cycle: its batch requests fail with ``CoreQuarantined``, the
        gang re-plans without it, and the remaining batch retries with a
        fresh budget.  Without ``health=`` the first failure propagates
        (the pre-supervision behavior).
        """
        health = self.health
        attempt = 0
        while True:
            try:
                await self._launch(slo_by_core)
            # repro: allow[broad-except] reason=supervision seam: ANY launch failure is retried/attributed here; without health= it reraises unchanged
            except Exception as e:
                if health is None:
                    raise
                failed = sorted(set(getattr(e, "cores", ()))
                                or {r.core for r in batch})
                tripped = health.note_launch_failure(failed)
                if tripped:
                    for core in tripped:
                        self._quarantine(
                            core,
                            reason=(f"circuit breaker: "
                                    f"{health.breaker_threshold} consecutive "
                                    f"launch failures ({e})"),
                            batch=batch, fifo=fifo)
                    if not batch:
                        return
                    attempt = 0   # topology changed: fresh retry budget
                    continue      # relaunch now — the group re-plans
                attempt += 1
                if attempt > health.max_retries_per_flush:
                    raise
                health.stats["retries"] += 1
                # private event: only the timeout (fake or real time
                # advancing past the backoff) wakes this, never _wake
                await self.clock.wait(asyncio.Event(),
                                      health.backoff_ms(attempt) / 1e3)
            else:
                if health is not None and batch:
                    health.note_launch_success({r.core for r in batch})
                return

    def _quarantine(self, core: str, *, reason: str,
                    batch: Optional[List[_Request]] = None,
                    fifo: Optional[Dict[Tuple[str, str],
                                        List[_Request]]] = None) -> None:
        """Quarantine ``core`` (journaled), rotate its standby in when one
        exists, fail affected tenants with ``CoreQuarantined``, and shrink
        the admission ceiling by the lost capacity.

        Synchronous and loop-thread only (called under the single-flight
        lock): farm mutation never interleaves with a launch.
        """
        changed = self.farm.quarantine(core, reason=reason)
        if changed and self.journal is not None:
            self.journal.record_quarantine(core, reason=reason)
        rotated = False
        if self.farm.has_standby(core):
            self.farm.rotate(core)
            rotated = True
            if self.journal is not None:
                self.journal.record_rotation(core)
        err = CoreQuarantined(
            f"core {core!r} quarantined: {reason}"
            + (" — standby rotated into the slot; resubmit" if rotated
               else " — no standby; resubmit on another core"),
            core=core, reason=reason, rotated=rotated)
        if batch is not None:
            keep = []
            for r in batch:
                if r.core == core:
                    if not r.future.done():
                        r.future.set_exception(err)
                else:
                    keep.append(r)
            batch[:] = keep
        if fifo is not None:
            for k in [k for k in fifo if k[0] == core]:
                del fifo[k]
        if not rotated:
            # no standby: queued-but-uncommitted requests on this core can
            # never be served either — fail them now instead of hanging
            self._ingest()
            keep = []
            for r in self._queue:
                if r.core != core:
                    keep.append(r)
                    continue
                self._release(r)
                f = r.future
                if isinstance(f, concurrent.futures.Future):
                    if f.set_running_or_notify_cancel():
                        f.set_exception(err)
                elif not f.done():
                    f.set_exception(err)
            self._queue = keep
        if self.admission is not None:
            total = len(self.farm.services)
            healthy = total - len(self.farm.quarantined)
            self.admission.set_capacity_factor(
                healthy / total if total else 1.0)

    async def _evaluate_quality(self) -> None:
        """Run the online NIST gate over full sample windows (on the
        executor under ``offload`` — the p-value math never blocks the
        loop) and quarantine any core the monitor condemns."""
        if self.health is None:
            return
        if self._offload:
            verdicts = await self._loop.run_in_executor(
                self._executor, self.health.evaluate)
        else:
            verdicts = self.health.evaluate()
        for core, v in verdicts.items():
            if core not in self.farm.quarantined:
                self._quarantine(core, reason=str(v["reason"]))

    async def _flush_cycle(self) -> None:
        """ONE coalesced flush: commit (on-loop) -> launch (executor when
        ``offload``) -> deliver + resolve (on-loop), under the
        single-flight lock so two flushes never interleave ``absorb()``
        against one farm."""
        assert self._flush_lock is not None
        async with self._flush_lock:
            committed = self._commit()
            if committed is None:
                return
            batch, owed, fifo, slo_by_core = committed
            self._inflight = True
            try:
                await self._launch_with_retries(batch, fifo, slo_by_core)
                if batch:
                    self._resolve(batch, owed, fifo)
                    if self.journal is not None:
                        # repro: allow[async-blocking] reason=durability ordering: the fsync'd flush record must exist before the next commit can run; one bounded fsync per flush, serialized under the single-flight lock
                        self.journal.record_flush(self.farm)
                if (self.admission is not None
                        and self.admission.adaptive is not None):
                    # feed the adaptive ceiling one (stage seconds, rows)
                    # observation so the queued-rows cap tracks measured
                    # flush throughput (no-op without farm profile=True)
                    self.admission.adaptive.update_from(
                        self.farm, sum(r.rows_est for r in batch))
                await self._evaluate_quality()
            except asyncio.CancelledError:
                # aclose() mid-launch: the executor finishes the launch
                # (aclose waits), and its words are parked in the service
                # outboxes — lossless.  These futures just never resolve
                # here; fail them so nobody blocks forever.
                for r in batch:
                    f = r.future
                    if f.done():
                        continue
                    if isinstance(f, concurrent.futures.Future):
                        f.set_exception(
                            RuntimeError("front-end closed mid-flush; "
                                         "words parked on the sync surface"))
                    else:
                        f.cancel()
                raise
            # repro: allow[broad-except] reason=futures must carry ANY launch/accounting failure (reraised after) or admitted tenants block forever
            except Exception as e:
                # Fail loudly, never hang: every batched future still
                # pending carries the error — including when the
                # accounting backstops above fire after some futures
                # already resolved.
                for r in batch:
                    if not r.future.done():
                        r.future.set_exception(e)
                raise
            finally:
                self._inflight = False
                self._wake.set()     # re-check work queued mid-launch

    async def _run(self) -> None:
        while True:
            self._wake.clear()
            self._ingest()
            if self._due():
                try:
                    await self._flush_cycle()
                # repro: allow[broad-except] reason=the flusher task must survive any flush failure (error kept in flush_errors and on the batch futures); only aclose() may end it
                except Exception as e:     # noqa: BLE001 - kept, not lost
                    self.flush_errors.append(e)
                continue
            if not self._inflight:         # a flush_now() launch may be live
                for w in self._drain_waiters:
                    if not w.done():
                        w.set_result(None)
                self._drain_waiters.clear()
            nxt = self._earliest_deadline()
            timeout = None if nxt is None else max(0.0, nxt - self.clock.now())
            await self.clock.wait(self._wake, timeout)

    # -- resumability --------------------------------------------------------

    async def snapshot(self) -> Dict[str, object]:
        """Quiesce + snapshot: farm state with still-queued front-end
        demand folded into the per-client ``pending`` counts.

        Waits out any launch in flight (single-flight lock), so the farm
        state is never captured mid-mutation; the ingress is drained
        first so requests already submitted by sync threads are captured
        too.  Restoring the result on ANY farm/front-end replays the
        in-flight draws through the next sync ``flush()``, while this
        front-end still serves its own futures afterwards.
        """
        if self._flush_lock is None:          # not started: nothing in flight
            return self._snapshot_now()
        async with self._flush_lock:
            return self._snapshot_now()

    def _snapshot_now(self) -> Dict[str, object]:
        self._ingest()
        snap = self.farm.snapshot()
        for r in self._queue:
            if r.future.cancelled():
                continue
            cl = snap["cores"][r.core]["clients"][r.client]
            cl["pending"] = int(cl.get("pending", 0)) + r.n_words
        return snap

    def restore(self, snap: Dict[str, object]) -> None:
        """Restore a snapshot; requires a quiesced front-end (no queued
        futures or in-flight launch — they would double-count against the
        snapshot's merged pending demand)."""
        if self._inflight:
            raise RuntimeError(
                "a flush launch is in flight; await drain() before "
                "restore()")
        self._ingest()
        if self._queue:
            raise RuntimeError(
                f"{len(self._queue)} in-flight request(s); drain or cancel "
                f"them before restore()")
        self.farm.restore(snap)
