"""Asyncio serving front-end: deadline-driven gang flushes, no manual flush().

The gang kernels and planner (``serve/farm.py``) amortize launch overhead
across cores — but only for tenants that coordinate their ``flush()``
calls by hand.  ``AsyncOscillatorFarm`` closes that gap: every tenant just
``await draw(core, client, n_words, deadline_ms=...)`` and a single
background *flusher* task coalesces pending demand across all tenants and
coroutines, firing one planner-shaped gang flush when either

  * the earliest wall-clock **deadline** among queued requests expires, or
  * **auto_flush_rows** worth of launch work has accumulated (counted in
    launch rows via ``PRNGService.rows_needed_with`` — a request coverable
    from a client's buffer adds no rows), whichever comes first.

Tenants on different threads participate through a thread-safe ingress
(a deque appended from any thread + ``loop.call_soon_threadsafe`` to wake
the flusher); sync callers block on ``draw_sync``.

Determinism contract (tests/test_async_frontend.py): delivered words are
bit-identical per tenant to the sync ``gang=False`` solo path, however
requests interleave, coalesce, or get cancelled — a direct consequence of
the farm's chunk-invariant absolute-row indexing plus two front-end rules:

  * a request enters the farm (``svc.request``) only at flush time, so
    cancelling a queued future rolls its demand back by simply never
    submitting it;
  * a flush's returned words are split FIFO per (core, client): words owed
    to the sync surface (pre-existing service pending + outbox backlog)
    are re-parked via ``PRNGService.park`` — never dropped — and the tail
    resolves this front-end's futures in submission order.

Every time read goes through the injectable ``Clock``
(``repro.serve.clock``): under a manual-advance ``FakeClock`` the flusher
wakes exactly when the test advances fake time past a deadline, so every
deadline/coalescing behavior is testable with zero real sleeps.

``snapshot()`` quiesces in-flight futures: it drains the ingress and folds
still-queued front-end demand into the per-client ``pending`` counts of
the farm snapshot.  Restoring that snapshot anywhere — a plain sync farm
or another front-end — replays the in-flight draws through the sync
surface (next ``flush()``), bit-identically to what the live futures
receive.  The live front-end keeps serving its own futures after the
snapshot.
"""
from __future__ import annotations

import asyncio
import collections
import concurrent.futures
import dataclasses
import threading
from typing import Deque, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.serve.clock import Clock, SystemClock
from repro.serve.farm import OscillatorFarm

_Future = Union["asyncio.Future", "concurrent.futures.Future"]


@dataclasses.dataclass
class _Request:
    core: str
    client: str
    n_words: int
    deadline: float            # absolute, in this front-end's clock
    future: _Future


def percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, int(round(q * (len(s) - 1))))]


class AsyncOscillatorFarm:
    """Async front-end over an ``OscillatorFarm``: futures in, gang
    flushes out.

    Two ways to run the flusher:

      * ``async with AsyncOscillatorFarm(farm) as af`` (or ``await
        af.start()`` / ``await af.aclose()``) inside an existing event
        loop — the deterministic-test mode;
      * ``af.start_thread()`` / ``af.close()`` — a daemon thread owns the
        loop, and sync callers on any thread use ``draw_sync``.

    ``deadline_ms`` is *relative* wall-clock budget per request; ``None``
    falls back to ``default_deadline_ms`` (its own ``None`` meaning
    "flush at the next flusher pass", i.e. no intentional batching delay).
    A flush serves EVERY queued request, not just the due ones — riders
    amortize the launch the deadline paid for.
    """

    def __init__(self, farm: OscillatorFarm, *,
                 auto_flush_rows: Optional[int] = None,
                 default_deadline_ms: Optional[float] = None,
                 clock: Optional[Clock] = None):
        self.farm = farm
        self.auto_flush_rows = auto_flush_rows
        self.default_deadline_ms = default_deadline_ms
        self.clock: Clock = clock or farm.clock or SystemClock()
        self._queue: List[_Request] = []
        self._ingress: Deque[_Request] = collections.deque()
        self._wake: Optional[asyncio.Event] = None
        self._drain_waiters: List[asyncio.Future] = []
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._task: Optional[asyncio.Task] = None
        self._thread: Optional[threading.Thread] = None
        self._stop: Optional[asyncio.Event] = None
        self.flushes = 0
        self.served_words = 0
        self._miss_ms: List[float] = []
        # flush failures survive here (each batch future also carries its
        # exception); the flusher itself never dies except by aclose()
        self.flush_errors: List[BaseException] = []

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> "AsyncOscillatorFarm":
        """Start the flusher task on the currently running loop."""
        if self._task is not None:
            raise RuntimeError("front-end already started")
        self._loop = asyncio.get_running_loop()
        self._wake = asyncio.Event()
        self._task = self._loop.create_task(self._run())
        return self

    async def aclose(self) -> None:
        """Stop the flusher; still-queued futures are cancelled."""
        if self._task is not None:
            self._task.cancel()
            await asyncio.gather(self._task, return_exceptions=True)
            self._task = None
        self._ingest()
        for r in self._queue:
            r.future.cancel()
        self._queue.clear()
        for w in self._drain_waiters:
            if not w.done():
                w.set_result(None)
        self._drain_waiters.clear()

    async def __aenter__(self) -> "AsyncOscillatorFarm":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    def start_thread(self) -> "AsyncOscillatorFarm":
        """Run the event loop + flusher on a daemon thread (sync callers
        then use ``draw_sync`` from any thread)."""
        if self._thread is not None or self._task is not None:
            raise RuntimeError("front-end already started")
        started = threading.Event()
        self._thread = threading.Thread(
            target=lambda: asyncio.run(self._thread_body(started)),
            name="async-farm-flusher", daemon=True)
        self._thread.start()
        started.wait()
        return self

    async def _thread_body(self, started: threading.Event) -> None:
        self._stop = asyncio.Event()
        await self.start()
        started.set()
        await self._stop.wait()
        await self.aclose()

    def close(self) -> None:
        """Stop a ``start_thread`` front-end and join its thread."""
        if self._thread is None:
            return
        self._loop.call_soon_threadsafe(self._stop.set)
        self._thread.join()
        self._thread = None
        self._loop = None

    # -- client surface ------------------------------------------------------

    def register(self, core: str, client: str,
                 seed: Optional[int] = None) -> None:
        """Register a tenant stream (do this before serving traffic; it is
        not synchronized against a running flusher on another thread)."""
        self.farm.register(core, client, seed=seed)

    def _deadline(self, deadline_ms: Optional[float]) -> float:
        if deadline_ms is None:
            deadline_ms = self.default_deadline_ms
        if deadline_ms is None:
            deadline_ms = 0.0
        return self.clock.now() + float(deadline_ms) / 1e3

    def _validate(self, core: str, client: str, n_words: int) -> None:
        svc = self.farm.services.get(core)
        if svc is None:
            raise KeyError(f"unknown core {core!r}; "
                           f"have {sorted(self.farm.services)}")
        if client not in svc.clients:
            raise KeyError(f"client {client!r} not registered on {core!r}")
        if n_words < 0:
            raise ValueError(f"n_words must be >= 0, got {n_words}")

    def submit(self, core: str, client: str, n_words: int,
               deadline_ms: Optional[float] = None) -> asyncio.Future:
        """Queue a draw from the loop thread; returns the tenant's future.

        The future resolves with exactly ``n_words`` uint32 words once a
        flush (deadline- or threshold-triggered) serves it.  Cancelling it
        while queued rolls the demand back cleanly — the farm never sees
        the request, and no other tenant's stream shifts.
        """
        if self._task is None:
            raise RuntimeError("front-end not started")
        self._validate(core, client, n_words)
        fut = self._loop.create_future()
        if n_words == 0:
            fut.set_result(np.empty(0, np.uint32))
            return fut
        self._queue.append(_Request(core, client, int(n_words),
                                    self._deadline(deadline_ms), fut))
        self._wake.set()
        return fut

    async def draw(self, core: str, client: str, n_words: int,
                   deadline_ms: Optional[float] = None) -> np.ndarray:
        """``await`` one tenant draw (see ``submit``)."""
        return await self.submit(core, client, n_words, deadline_ms)

    def draw_sync(self, core: str, client: str, n_words: int,
                  deadline_ms: Optional[float] = None,
                  timeout: Optional[float] = None) -> np.ndarray:
        """Blocking draw from ANY thread: the thread-safe ingress.

        Appends the request to a cross-thread deque and wakes the flusher
        with ``call_soon_threadsafe``; blocks on a
        ``concurrent.futures.Future`` until the coalesced flush serves it.
        """
        if self._task is None or self._loop is None:
            # _task (not just _loop) is the liveness flag: after aclose()
            # the loop object may survive with no flusher to serve us
            raise RuntimeError("front-end not started")
        try:
            running = asyncio.get_running_loop()
        except RuntimeError:
            running = None
        if running is self._loop:
            # blocking the loop thread would starve the flusher forever
            raise RuntimeError(
                "draw_sync called from the event-loop thread would "
                "deadlock; use `await draw(...)` / submit() there")
        self._validate(core, client, n_words)
        cfut: concurrent.futures.Future = concurrent.futures.Future()
        if n_words == 0:
            cfut.set_result(np.empty(0, np.uint32))
            return cfut.result()
        self._ingress.append(_Request(core, client, int(n_words),
                                      self._deadline(deadline_ms), cfut))
        self._loop.call_soon_threadsafe(self._wake.set)
        return cfut.result(timeout)

    async def drain(self) -> None:
        """Wait until the flusher has no currently-actionable work left
        (every due flush performed; remaining requests are all waiting on
        future deadlines / more coalescing)."""
        if self._task is None:
            raise RuntimeError("front-end not started")
        fut = self._loop.create_future()
        self._drain_waiters.append(fut)
        self._wake.set()
        await fut

    async def flush_now(self) -> None:
        """Force one flush of everything queued, deadlines notwithstanding.

        A flush failure is recorded in ``flush_errors`` (same as the
        background path) and re-raised to this caller; the batch's
        futures carry it either way.
        """
        self._ingest()
        if self._queue:
            try:
                self._do_flush()
            except Exception as e:
                self.flush_errors.append(e)
                raise
        await self.drain()

    # -- introspection -------------------------------------------------------

    @property
    def pending_requests(self) -> int:
        """Queued front-end draws not yet served (ingress included)."""
        return (sum(1 for r in self._queue if not r.future.cancelled())
                + len(self._ingress))

    @property
    def launches(self) -> int:
        return self.farm.launches

    def pending_rows(self) -> int:
        """Launch rows the queued front-end demand would add on top of the
        farm's own pending — the quantity compared against
        ``auto_flush_rows``."""
        extra: Dict[str, Dict[str, int]] = {}
        for r in self._queue:
            if not r.future.cancelled():
                per = extra.setdefault(r.core, {})
                per[r.client] = per.get(r.client, 0) + r.n_words
        return sum(svc.rows_needed_with(extra.get(core))
                   for core, svc in self.farm.services.items())

    def miss_samples_ms(self) -> List[float]:
        """Recorded deadline-miss samples (ms past deadline, 0 = on time),
        oldest first — the raw series behind ``deadline_stats()``; public
        so benchmarks can window it (e.g. timed region only)."""
        return list(self._miss_ms)

    def deadline_stats(self) -> Dict[str, float]:
        """p50/p99/max deadline-miss latency (ms) over served requests;
        a request served before its deadline counts as 0 miss."""
        return {"served_requests": float(len(self._miss_ms)),
                "p50_miss_ms": percentile(self._miss_ms, 0.50),
                "p99_miss_ms": percentile(self._miss_ms, 0.99),
                "max_miss_ms": max(self._miss_ms, default=0.0)}

    # -- flusher -------------------------------------------------------------

    def _ingest(self) -> None:
        """Move thread-ingress requests into the queue; prune cancelled."""
        while self._ingress:
            self._queue.append(self._ingress.popleft())
        self._queue = [r for r in self._queue if not r.future.cancelled()]

    def _earliest_deadline(self) -> Optional[float]:
        return min((r.deadline for r in self._queue), default=None)

    def _due(self) -> bool:
        if not self._queue:
            return False
        if self._earliest_deadline() <= self.clock.now():
            return True
        return (self.auto_flush_rows is not None
                and self.pending_rows() >= self.auto_flush_rows)

    def _do_flush(self) -> None:
        """ONE coalesced farm flush serving every queued request.

        Runs synchronously on the loop thread, so nothing interleaves with
        it: an asyncio future cannot be cancelled mid-flush, and a
        concurrent future is moved to RUNNING first (late ``cancel()``
        calls fail instead of racing the launch).
        """
        batch: List[_Request] = []
        for r in self._queue:
            f = r.future
            if isinstance(f, concurrent.futures.Future):
                if not f.set_running_or_notify_cancel():
                    continue               # cancelled: demand rolled back
            elif f.cancelled():
                continue
            batch.append(r)
        self._queue = []
        if not batch:
            return
        # Words the sync surface is owed come FIRST in each client's flush
        # output (outbox backlog, then earlier-requested service pending);
        # record the counts so the split below can re-park them.
        owed: Dict[Tuple[str, str], int] = {}
        for core, svc in self.farm.services.items():
            for name in svc.clients:
                n = svc.pending_words(name) + svc.outbox_words(name)
                if n:
                    owed[(core, name)] = n
        fifo: Dict[Tuple[str, str], List[_Request]] = {}
        try:
            for r in batch:
                self.farm.services[r.core].request(r.client, r.n_words)
                fifo.setdefault((r.core, r.client), []).append(r)
            # Launch with deliver=False so every served word is parked in
            # its service outbox the moment its group absorbs: if a later
            # group's launch fails mid-flush, already-absorbed words are
            # safe on the sync surface instead of vanishing with the
            # in-flight return value.  The second pass is launch-free
            # delivery (identical content/order to a deliver=True flush).
            self.farm.flush(deliver=False)
            out = self.farm.flush()
            now = self.clock.now()
            self.flushes += 1
            for core, per_client in out.items():
                for client, words in per_client.items():
                    head = owed.get((core, client), 0)
                    if head:
                        self.farm.services[core].park(client, words[:head])
                    pos = head
                    for r in fifo.pop((core, client), ()):
                        r.future.set_result(words[pos:pos + r.n_words])
                        pos += r.n_words
                        self.served_words += r.n_words
                        self._miss_ms.append(
                            max(0.0, now - r.deadline) * 1e3)
                    if pos != len(words):
                        raise AssertionError(
                            f"flush word accounting broken for "
                            f"{core}/{client}: {len(words)} words, "
                            f"consumed {pos}")
            if fifo:
                raise AssertionError(
                    f"flush served no words for queued requests: "
                    f"{sorted(fifo)}")
        except Exception as e:
            # Fail loudly, never hang: every batched future still pending
            # carries the error — including when the accounting backstops
            # above fire after some futures already resolved.
            for r in batch:
                if not r.future.done():
                    r.future.set_exception(e)
            raise

    async def _run(self) -> None:
        while True:
            self._wake.clear()
            self._ingest()
            if self._due():
                try:
                    self._do_flush()
                except Exception as e:     # noqa: BLE001 - kept, not lost
                    self.flush_errors.append(e)
                continue
            for w in self._drain_waiters:
                if not w.done():
                    w.set_result(None)
            self._drain_waiters.clear()
            nxt = self._earliest_deadline()
            timeout = None if nxt is None else max(0.0, nxt - self.clock.now())
            await self.clock.wait(self._wake, timeout)

    # -- resumability --------------------------------------------------------

    async def snapshot(self) -> Dict[str, object]:
        """Quiesce + snapshot: farm state with still-queued front-end
        demand folded into the per-client ``pending`` counts.

        Runs on the loop thread between flushes (a flush is atomic there),
        so no launch is in flight; the ingress is drained first so
        requests already submitted by sync threads are captured too.
        Restoring the result on ANY farm/front-end replays the in-flight
        draws through the next sync ``flush()``, while this front-end
        still serves its own futures afterwards.
        """
        self._ingest()
        snap = self.farm.snapshot()
        for r in self._queue:
            if r.future.cancelled():
                continue
            cl = snap["cores"][r.core]["clients"][r.client]
            cl["pending"] = int(cl.get("pending", 0)) + r.n_words
        return snap

    def restore(self, snap: Dict[str, object]) -> None:
        """Restore a snapshot; requires a quiesced front-end (no queued
        futures — they would double-count against the snapshot's merged
        pending demand)."""
        self._ingest()
        if self._queue:
            raise RuntimeError(
                f"{len(self._queue)} in-flight request(s); drain or cancel "
                f"them before restore()")
        self.farm.restore(snap)
