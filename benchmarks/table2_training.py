"""Paper Table II: ANN training metrics per activation (ReLU/Tanh/Sigmoid)
on the Chen system.  Paper: ReLU MSE 3.1e-4, Tanh 6.98e-3, Sigmoid 4.4e-2."""
import time

from repro.core.ann import AnnConfig, train
from repro.core.chaotic import make_dataset

from benchmarks.common import emit

PAPER = {"relu": 0.00031, "tanh": 0.00698, "sigmoid": 0.04412}


def run(n_samples: int = 50_000, epochs: int = 200) -> None:
    ds = make_dataset("chen", n_samples=n_samples)
    for act in ("relu", "tanh", "sigmoid"):
        cfg = AnnConfig(hidden=8, activation=act)
        t0 = time.perf_counter()
        _, hist = train(cfg, ds, epochs=epochs, lr=3e-3)
        dt = (time.perf_counter() - t0) * 1e6
        m = hist["test_metrics"]
        emit(f"table2/{act}", dt,
             f"mse={m['mse']:.2e};mae={m['mae']:.4f};rmse={m['rmse']:.4f};"
             f"r2={m['r2']:.5f};paper_mse={PAPER[act]:.2e};"
             f"beats_paper={m['mse'] <= PAPER[act]}")


if __name__ == "__main__":
    run()
