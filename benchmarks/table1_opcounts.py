"""Paper Table I: operation counts — ANN (Eq. 7) vs RK-4 (Eq. 4)."""
from repro.core.chaotic import SYSTEMS, ann_op_counts, rk4_op_counts

from benchmarks.common import emit


def run() -> None:
    for sizes in ((3, 4, 3), (3, 8, 3), (3, 16, 3)):
        mul, add = ann_op_counts(sizes)
        emit(f"table1/ann_{'-'.join(map(str, sizes))}", 0.0,
             f"muls={mul};adds={add}")
    for name, sys_ in sorted(SYSTEMS.items()):
        mul, add = rk4_op_counts(sys_)
        emit(f"table1/rk4_{name}", 0.0, f"muls={mul};adds={add}")
    # the paper's headline comparison
    ann = ann_op_counts((3, 8, 3))
    rk4 = rk4_op_counts(SYSTEMS["chen"])
    emit("table1/ann_vs_rk4_chen", 0.0,
         f"ann={ann[0]}mul/{ann[1]}add;rk4={rk4[0]}mul/{rk4[1]}add;"
         f"match_paper={(ann == (48, 59)) and (rk4 == (60, 59))}")


if __name__ == "__main__":
    run()
