"""Benchmark driver — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit)."""
import argparse
import sys
import traceback


def main() -> None:
    import functools

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (e.g. table1,fig5)")
    ap.add_argument("--profile", action="store_true",
                    help="dump per-stage flush wall times "
                         "(plan/stack/launch/absorb) for suites that "
                         "drive the serving path (farm)")
    args = ap.parse_args()

    from benchmarks import (farm, fig3_design_space, fig4_cost_curves,
                            fig5_pareto, table1_opcounts, table2_training,
                            table3_dse, throughput)
    suites = {
        "table1": table1_opcounts.run,
        "table2": table2_training.run,
        "table3": table3_dse.run,
        "fig3": fig3_design_space.run,
        "fig4": fig4_cost_curves.run,
        "fig5": fig5_pareto.run,
        "throughput": throughput.run,
        "throughput_fused": throughput.run_fused,
        "farm": functools.partial(farm.run_farm, profile=args.profile),
    }
    selected = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        try:
            suites[name]()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
