"""Paper Fig. 4: cost as a function of (a) hidden neurons H and (b)
input/output neurons I — semi-linear relationships behind Eq. 9."""
import numpy as np

from repro.core.dse import Candidate, vmem_bytes

from benchmarks.common import emit


def run() -> None:
    # (a) vary H at fixed I=3, two parallelism levels (as in the paper)
    for p in (1, 3):
        hs = [8, 16, 32, 48, 64, 96, 128]
        costs = [vmem_bytes(Candidate(i_dim=3, h_dim=h, p=p, t_block=8))
                 for h in hs]
        slope = np.polyfit(hs, costs, 1)
        r = np.corrcoef(hs, costs)[0, 1]
        emit(f"fig4a/P{p}", 0.0,
             f"H={hs};vmem_KiB={[c // 1024 for c in costs]};"
             f"linear_r={r:.4f}")
    # (b) vary I at fixed H=8
    for p in (1, 3):
        is_ = [4, 8, 16, 24, 32]
        costs = [vmem_bytes(Candidate(i_dim=i, h_dim=8, p=p, t_block=8))
                 for i in is_]
        r = np.corrcoef(is_, costs)[0, 1]
        emit(f"fig4b/P{p}", 0.0,
             f"I={is_};vmem_KiB={[c // 1024 for c in costs]};"
             f"linear_r={r:.4f}")


if __name__ == "__main__":
    run()
