"""Paper Fig. 3a: estimated cost and latency for the 3-16-3 ANN design
space; Fig. 3b: normalized latency vs P with the cubic interpolation."""
import numpy as np

from repro.core.dse import (Candidate, CostModel, LatencyModel,
                            enumerate_candidates, measure_candidate)

from benchmarks.common import emit


def run() -> None:
    lm, cm = LatencyModel.fit(), CostModel.fit()
    cands = enumerate_candidates(3, 16)
    emit("fig3a/design_space_size", 0.0, f"candidates={len(cands)}")
    for p in range(6):
        est_lat = lm.predict(3, 16, p)
        est_cost = cm.predict(3, 16, p)
        emit(f"fig3a/3-16-3_P{p}", 0.0,
             f"est_latency_cyc={est_lat:.4f};est_vmem_KiB={est_cost/1024:.0f}")
    # Fig 3b: normalized actual latencies + interpolation residual
    sizes = ((3, 4), (3, 8), (3, 16), (4, 8), (4, 16))
    for p in range(6):
        norm = [measure_candidate(Candidate(i_dim=i, h_dim=h, p=p))
                ["per_stream_latency_cycles"] / (i * h) for i, h in sizes]
        fit = np.polyval(lm.coeffs[("vpu", 4)], float(p))
        emit(f"fig3b/P{p}", 0.0,
             f"mean_norm_latency={np.mean(norm):.6f};poly3_fit={fit:.6f};"
             f"residual={abs(np.mean(norm)-fit)/np.mean(norm):.2%}")


if __name__ == "__main__":
    run()
