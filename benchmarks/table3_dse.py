"""Paper Table III: DSE estimates vs 'post-synthesis' measurements for the
three ANN sizes (3-4-3, 3-8-3, 3-16-3) across parallelism levels, in both
compute-unit modes (MXU=DSP analogue, VPU=LUT-only analogue).

Estimate = Eq. 8/9 fitted models; Actual = microarchitectural measurement
(the deterministic oracle validated against compiled HLO in tests)."""
from repro.core.dse import (Candidate, CostModel, LatencyModel,
                            measure_candidate)

from benchmarks.common import emit


def run() -> None:
    lm, cm = LatencyModel.fit(), CostModel.fit()
    for h in (4, 8, 16):
        p_max = 5
        for p in range(p_max + 1):
            for unit in ("mxu", "vpu"):
                c = Candidate(i_dim=3, h_dim=h, p=p, compute_unit=unit)
                meas = measure_candidate(c)
                est_lat = lm.predict(3, h, p, unit, c.dtype_bytes)
                est_cost = cm.predict(3, h, p, unit, c.dtype_bytes)
                act_lat = meas["per_stream_latency_cycles"]
                act_cost = meas["vmem_bytes"]
                emit(f"table3/3-{h}-3_P{p}_{unit}", 0.0,
                     f"est_lat_cyc={est_lat:.4f};act_lat_cyc={act_lat:.4f};"
                     f"lat_err={abs(est_lat - act_lat) / act_lat:.1%};"
                     f"est_vmem={est_cost / 1024:.0f}KiB;"
                     f"act_vmem={act_cost / 1024:.0f}KiB;"
                     f"cost_err={abs(est_cost - act_cost) / act_cost:.1%};"
                     f"samples_per_s={meas['samples_per_sec']:.3e}")


if __name__ == "__main__":
    run()
