"""Paper Fig. 5: post-'synthesis' cost/latency design space per ANN size,
with (a) MXU (DSP analogue) and (b) VPU-only (no-DSP analogue) modes."""
from repro.core.dse import (CostModel, LatencyModel, enumerate_candidates,
                            pareto_front)

from benchmarks.common import emit


def run() -> None:
    lm, cm = LatencyModel.fit(), CostModel.fit()
    for h in (4, 8, 16):
        for unit in ("mxu", "vpu"):
            cands = [c for c in enumerate_candidates(3, h, units=(unit,))]
            front = pareto_front(cands, lm, cm)
            pts = ";".join(f"P{c.p}:{cost/1024:.0f}KiB@{lat:.3f}cyc"
                           for c, cost, lat in front[:6])
            # top-speed and cost-optimized extremes (paper's reading of Fig 5)
            fastest = min(front, key=lambda t: t[2])
            cheapest = min(front, key=lambda t: t[1])
            emit(f"fig5/3-{h}-3_{unit}", 0.0,
                 f"pareto={pts};fastest_P={fastest[0].p};"
                 f"cheapest_P={cheapest[0].p}")


if __name__ == "__main__":
    run()
