"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, List

import jax

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn: Callable, *args, n_iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (block_until_ready-aware)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(n_iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
