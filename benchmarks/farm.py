"""Cross-system oscillator-farm benchmark (BENCH_farm.json).

Two sections:

* ``systems`` — one row per registered chaotic system: the registry-trained
  oscillator drawn through the fused ``ops.chaotic_bits`` path with that
  system's DSE-selected solution (the same Pareto point ``generate_farm``
  freezes into the committed farm cores), reporting words/s.  Each row also
  carries the NIST-subset quarantine verdict for the core's serving dtype
  (``repro.prng.quality``): a quarantined system ships in the farm but a
  rollout can exclude it.

* ``gang`` — the launch-overhead killer measured end to end: the largest
  gang-compatible core group (same i_dim/h_dim/dtype/config — the four 3-D
  systems) served through ``OscillatorFarm`` with gang scheduling ON vs
  OFF, at two operating points: ``coalesced`` (small tenant flushes, the
  traffic gangs exist for) and ``bulk`` (full time-block flushes).  Words
  delivered are verified bit-identical between the two modes before any
  timing; launches per flush and gang dispatch-cache misses are reported
  alongside words/s.

CPU interpret mode: numbers are functional-relative, not TPU performance;
relative ordering (and the gang-vs-per-core ratio) is still meaningful.
"""
import json
import pathlib
import time

import jax.numpy as jnp
import numpy as np

from repro.core.chaotic import SYSTEMS
from repro.core.dse import (CostModel, LatencyModel, measure_candidate,
                            select)
from repro.kernels.ops import chaotic_bits
from repro.prng.stream import _splitmix_seeds, default_params
from repro.serve.farm import OscillatorFarm, _compat_key

from benchmarks.common import emit, time_fn

LANES_PER_CLIENT = 128


def _system_rows(n_streams, n_steps, p, lm, cm, nist_words):
    """Per-system fused-draw words/s + quarantine verdicts."""
    table = {}
    n_words = (n_steps // 2) * n_streams
    for name in sorted(SYSTEMS):
        params = {k: jnp.asarray(v)
                  for k, v in default_params(system=name).items()}
        i_dim, h_dim = params["w1"].shape
        cand = select(i_dim, h_dim, "pareto", p=p,
                      latency_model=lm, cost_model=cm)
        dtype = jnp.dtype(cand.dtype_name)
        x0 = _splitmix_seeds(jnp.uint32(1), n_streams, i_dim).astype(dtype)

        def draw():
            words, _ = chaotic_bits(params, x0, n_steps,
                                    backend="pallas_interpret", config=cand)
            return words

        us = time_fn(draw, n_iters=2, warmup=1)
        words_per_s = n_words / (us / 1e6)
        if nist_words:
            from repro.prng.quality import nist_gate
            gate = nist_gate(name, cand.dtype_name, n_words=nist_words,
                             backend="pallas_interpret")
            quarantined, failed = gate["quarantined"], gate["failed_tests"]
        else:
            quarantined, failed = None, None      # smoke mode: not gated
        table[name] = {
            "i_dim": i_dim, "h_dim": h_dim,
            "dtype": cand.dtype_name, "compute_unit": cand.compute_unit,
            "s_block": cand.s_block, "t_block": cand.t_block,
            "unroll": cand.unroll,
            "words_per_s": words_per_s,
            "modeled_samples_per_s": measure_candidate(cand)["samples_per_sec"],
            "quarantined": quarantined,
            "nist_failed_tests": failed,
        }
        emit(f"farm/{name}_words_per_s", us,
             f"I={i_dim};H={h_dim};dtype={cand.dtype_name};"
             f"words_per_s={words_per_s:.3e};quarantined={quarantined}")
    return table


def _compatible_group(p, lm, cm):
    """Largest set of systems sharing one gang-compatibility key."""
    groups = {}
    for name in sorted(SYSTEMS):
        params = default_params(system=name)
        i_dim, h_dim = params["w1"].shape
        cand = select(i_dim, h_dim, "pareto", p=p,
                      latency_model=lm, cost_model=cm)
        groups.setdefault((i_dim, h_dim, cand), []).append(name)
    (i_dim, h_dim, cand), members = max(groups.items(),
                                        key=lambda kv: len(kv[1]))
    return members, cand


def _build_farm(group, cand, n_clients, gang):
    farm = OscillatorFarm(gang=gang)
    for name in group:
        farm.add_core(name, default_params(system=name), config=cand,
                      dtype=jnp.dtype(cand.dtype_name),
                      lanes_per_client=LANES_PER_CLIENT,
                      backend="pallas_interpret")
        for j in range(n_clients):
            farm.register(name, f"c{j}", seed=100 + j)
    return farm


def _flush_once(farm, group, n_clients, n_words):
    for name in group:
        for j in range(n_clients):
            farm.request(name, f"c{j}", n_words)
    return farm.flush()


def _gang_section(n_streams, p, lm, cm, smoke):
    group, cand = _compatible_group(p, lm, cm)
    n_clients = max(1, n_streams // LANES_PER_CLIENT)

    # Bit-identity gate before any timing: same traffic, both launch modes.
    check_words = 16 * LANES_PER_CLIENT + 37
    farms = {g: _build_farm(group, cand, n_clients, g) for g in (True, False)}
    outs = {g: _flush_once(farms[g], group, n_clients, check_words)
            for g in (True, False)}
    for core in outs[True]:
        for client in outs[True][core]:
            np.testing.assert_array_equal(outs[True][core][client],
                                          outs[False][core][client])
    key = _compat_key(farms[True].services[group[0]])

    protocols = {"coalesced": 16}
    if not smoke:
        protocols["bulk"] = cand.t_block // 2
    n_iters = 3 if smoke else 9
    result = {
        "group": group,
        "compat_key": {"i_dim": cand.i_dim, "h_dim": cand.h_dim,
                       "dtype": cand.dtype_name,
                       "compute_unit": cand.compute_unit,
                       "s_block": cand.s_block, "t_block": cand.t_block,
                       "unroll": cand.unroll,
                       "full_key": [str(x) for x in key]},
        "n_streams_per_core": n_clients * LANES_PER_CLIENT,
        "bit_identical": True,
        "protocols": {},
    }
    for proto, rows in protocols.items():
        n_words = rows * LANES_PER_CLIENT
        words_per_flush = len(group) * n_clients * n_words
        stats = {}
        for gang in (True, False):
            farm = _build_farm(group, cand, n_clients, gang)
            _flush_once(farm, group, n_clients, n_words)   # compile
            _flush_once(farm, group, n_clients, n_words)
            l0 = farm.launches
            ts = []
            for _ in range(n_iters):
                t0 = time.perf_counter()
                _flush_once(farm, group, n_clients, n_words)
                ts.append(time.perf_counter() - t0)
            ts.sort()
            dt = ts[len(ts) // 2]
            stats[gang] = {
                "words_per_s": words_per_flush / dt,
                "ms_per_flush": dt * 1e3,
                "launches_per_flush": (farm.launches - l0) / (n_iters + 0.0),
            }
            if gang:
                stats[gang]["dispatch_misses"] = farm.dispatch_misses
        speedup = (stats[True]["words_per_s"] /
                   stats[False]["words_per_s"])
        result["protocols"][proto] = {
            "rows_per_client_flush": rows,
            "words_per_flush": words_per_flush,
            "gang": stats[True],
            "per_core": stats[False],
            "speedup": speedup,
        }
        emit(f"farm/gang_{proto}", stats[True]["ms_per_flush"] * 1e3,
             f"group={len(group)};speedup={speedup:.2f}x;"
             f"gang_words_per_s={stats[True]['words_per_s']:.3e};"
             f"per_core_words_per_s={stats[False]['words_per_s']:.3e}")
    result["speedup"] = max(pr["speedup"]
                            for pr in result["protocols"].values())
    return result


def run_farm(n_streams: int = 256, n_steps: int = 1024, p: int = 1,
             out_json: str | None = "BENCH_farm.json",
             smoke: bool = False, nist_words: int = 20_000) -> dict:
    lm, cm = LatencyModel.fit(), CostModel.fit()
    if smoke:
        n_steps = min(n_steps, 256)
        nist_words = 0
    table = _system_rows(n_streams, n_steps, p, lm, cm, nist_words)
    gang = _gang_section(n_streams, p, lm, cm, smoke)
    res = {"config": {"n_streams": n_streams, "n_steps": n_steps,
                      "pareto_p": p, "backend": "pallas_interpret",
                      "smoke": smoke},
           "systems": table,
           "gang": gang}
    if out_json:
        pathlib.Path(out_json).write_text(json.dumps(res, indent=2))
    return res


if __name__ == "__main__":
    import sys
    run_farm(smoke="--smoke" in sys.argv)
