"""Cross-system oscillator-farm benchmark (BENCH_farm.json).

Sections:

* ``systems`` — one row per registered chaotic system: the registry-trained
  oscillator drawn through the fused ``ops.chaotic_bits`` path with that
  system's DSE-selected solution (the same Pareto point ``generate_farm``
  freezes into the committed farm cores), reporting words/s.  Each row also
  carries the NIST-subset quarantine verdict for the core's serving dtype
  (``repro.prng.quality``): a quarantined system ships in the farm but a
  rollout can exclude it.

* ``gang`` — the launch-overhead killer measured end to end: the largest
  gang-compatible core group (same i_dim/h_dim/dtype/config — the four 3-D
  systems) served through ``OscillatorFarm`` with gang scheduling ON vs
  OFF, at two operating points: ``coalesced`` (small tenant flushes, the
  traffic gangs exist for) and ``bulk`` (full time-block flushes).

* ``async`` — the asyncio front-end (``serve/async_frontend.py``) at the
  coalesced operating point: every tenant independently ``await draw()``s
  a small request (no manual flush coordination anywhere) and the
  deadline/threshold flusher coalesces them into one gang launch per
  round.  Reported against two sync baselines: ``per_draw`` (one launch
  per draw — what uncoordinated tenants pay without the front-end) and
  ``manual_flush`` (hand-coordinated request+flush — the coordination
  optimum the front-end is supposed to recover).  Words/s plus p50/p99
  deadline-miss latency (ms past each request's deadline at delivery).

* ``async_offload`` — the production-tier proof: with every launch padded
  to a known duration, a foreign thread measures ingress round-trips
  through the event loop WHILE a launch is in flight.  Executor offload
  (PR 6) must keep p99 under 10% of the launch duration where the on-loop
  baseline pins near 100%, words must stay bit-identical across offload
  on/off/solo, and a low queued-rows ceiling must shed overload with
  typed ``Overloaded`` rejects while admitted futures all resolve.

* ``planner`` — the demand-shaped launch planner vs the PR 3 padded
  group-max gang policy.  ``skewed`` is the operating point the planner
  exists for (one hot tenant drawing 128 word rows per flush, three cold
  tenants at 8 — the group-max policy makes the cold cores compute 16x
  overdraw); ``uniform`` checks the no-regression side (the planner must
  keep picking the padded launch).  The ``GangCostModel`` is fitted from
  real launches first, so decisions reflect this machine's launch
  overhead.

* ``sharded`` — device-sharded gang launches: the gang group's coalesced
  operating point at every available forced host device count (the CI
  sharded leg forces 4 via ``XLA_FLAGS``), gated on bit-identity to the
  1-device gang path, launches/flush invariance as devices scale, and
  words/s scaling where the host has the CPUs to show it.

* ``lattice`` — block-coupled oscillator lattices (the MXU arm of the
  design space): a 32-node ring of Chen cores (I=96, H=256) drawn through
  the fused path with each compute unit's DSE-selected solution, reporting
  vpu-vs-mxu words/s next to the cycle-model prediction (``select_config``
  must pick mxu on this shape for the gate to pass), a >= 24-member
  stacked-gang bit-identity check against solo lattice draws, and the
  stacked-layout VMEM cliff: the core count where one
  ``chaotic_ann_gang_stacked_pallas`` launch exceeds the VMEM budget and
  the planner must fall back to the lane-concat layout.

* ``resilience`` — the self-healing layer under a seeded fault storm:
  words/s and p99 round latency before / during / after a 10%-transient
  launch-failure storm with one poisoned core (its monitor samples
  bit-masked so the online NIST gate condemns it).  Gated on the PR 9
  acceptance bars: quarantine + standby rotation within 3 flushes,
  degraded throughput >= 0.5x clean, and every delivered word
  bit-identical to fault-free solo runs (rotation split included).

All timed flushes separate warmup/compile from steady state: the first
flush (XLA compiles here) is reported as ``ms_first_flush``, steady-state
``words_per_s`` starts after one further warm flush.  Delivered words are
verified bit-identical to ``gang=False`` before any timing.

CPU interpret mode: numbers are functional-relative, not TPU performance;
relative ordering (and the gang/planner ratios) is still meaningful.
"""
import asyncio
import json
import pathlib
import time

import jax.numpy as jnp
import numpy as np

from repro.core.chaotic import SYSTEMS
from repro.core.dse import (CostModel, GangCostModel, LatencyModel,
                            measure_candidate, select)
from repro.kernels.ops import chaotic_bits
from repro.prng.stream import _splitmix_seeds, default_params
from repro.serve.farm import OscillatorFarm, _compat_key

try:
    from benchmarks.common import emit, time_fn
except ModuleNotFoundError:          # invoked as `python benchmarks/farm.py`
    from common import emit, time_fn

LANES_PER_CLIENT = 128
HOT_ROWS, COLD_ROWS = 128, 8      # the skewed-demand operating point
UNIFORM_ROWS = 16
ASYNC_ROWS = 8                    # small per-tenant async draws (coalesced)
ASYNC_DEADLINE_MS = 5.0


def _system_rows(n_streams, n_steps, p, lm, cm, nist_words):
    """Per-system fused-draw words/s + quarantine verdicts."""
    table = {}
    n_words = (n_steps // 2) * n_streams
    for name in sorted(SYSTEMS):
        params = {k: jnp.asarray(v)
                  for k, v in default_params(system=name).items()}
        i_dim, h_dim = params["w1"].shape
        cand = select(i_dim, h_dim, "pareto", p=p,
                      latency_model=lm, cost_model=cm)
        dtype = jnp.dtype(cand.dtype_name)
        x0 = _splitmix_seeds(jnp.uint32(1), n_streams, i_dim).astype(dtype)

        def draw():
            words, _ = chaotic_bits(params, x0, n_steps,
                                    backend="pallas_interpret", config=cand)
            return words

        us = time_fn(draw, n_iters=3, warmup=1)
        words_per_s = n_words / (us / 1e6)
        if nist_words:
            from repro.prng.quality import nist_gate
            gate = nist_gate(name, cand.dtype_name, n_words=nist_words,
                             backend="pallas_interpret")
            quarantined, failed = gate["quarantined"], gate["failed_tests"]
        else:
            quarantined, failed = None, None      # smoke mode: not gated
        table[name] = {
            "i_dim": i_dim, "h_dim": h_dim,
            "dtype": cand.dtype_name, "compute_unit": cand.compute_unit,
            "s_block": cand.s_block, "t_block": cand.t_block,
            "unroll": cand.unroll,
            "words_per_s": words_per_s,
            "modeled_samples_per_s": measure_candidate(cand)["samples_per_sec"],
            "quarantined": quarantined,
            "nist_failed_tests": failed,
        }
        emit(f"farm/{name}_words_per_s", us,
             f"I={i_dim};H={h_dim};dtype={cand.dtype_name};"
             f"words_per_s={words_per_s:.3e};quarantined={quarantined}")
    return table


def _compatible_group(p, lm, cm):
    """Largest set of systems sharing one gang-compatibility key."""
    groups = {}
    for name in sorted(SYSTEMS):
        params = default_params(system=name)
        i_dim, h_dim = params["w1"].shape
        cand = select(i_dim, h_dim, "pareto", p=p,
                      latency_model=lm, cost_model=cm)
        groups.setdefault((i_dim, h_dim, cand), []).append(name)
    (i_dim, h_dim, cand), members = max(groups.items(),
                                        key=lambda kv: len(kv[1]))
    return members, cand


def _build_farm(group, cand, n_clients, gang, mesh=None, **farm_kw):
    farm = OscillatorFarm(gang=gang, **farm_kw)
    for name in group:
        farm.add_core(name, default_params(system=name), config=cand,
                      dtype=jnp.dtype(cand.dtype_name),
                      lanes_per_client=LANES_PER_CLIENT,
                      backend="pallas_interpret", mesh=mesh)
        for j in range(n_clients):
            farm.register(name, f"c{j}", seed=100 + j)
    return farm


def _flush_once(farm, group, n_clients, words_by_core):
    for name in group:
        for j in range(n_clients):
            farm.request(name, f"c{j}", words_by_core[name])
    return farm.flush()


def _interleaved_flushes(farms, group, n_clients, words_by_core, n_iters,
                         cold):
    """Time flushes of several farms, interleaved so host drift cancels.

    ``cold=True`` is cold-start timing: every flush pays its demand's
    launches.  Repeating identical skewed traffic would let the padded
    group-max policy turn overdraw into prefetch (cold tenants are served
    from buffer for the next t_block//2 / rows flushes), measuring buffer
    amortization instead of launch shaping — the uniform point's regime.
    So each iteration restores the same post-registration snapshot first
    and every timed flush serves the full demand vector with cold
    buffers: the launch-shape cost the planner actually optimizes.
    Restore and request queueing happen OUTSIDE the timed region.

    Returns {label: {ms_first_flush, ms_per_flush, launches_per_flush}}:
    the first flush (XLA compiles, caches build) apart from the
    steady-state median.
    """
    snaps = ({label: farm.snapshot() for label, farm in farms.items()}
             if cold else None)
    launches = {}

    def once(label):
        farm = farms[label]
        if cold:
            farm.restore(snaps[label])
        for name in group:
            for j in range(n_clients):
                farm.request(name, f"c{j}", words_by_core[name])
        l0 = farm.launches
        t0 = time.perf_counter()
        farm.flush()
        dt = (time.perf_counter() - t0) * 1e3
        launches[label] = float(farm.launches - l0)
        return dt

    first = {label: once(label) for label in farms}   # compile + caches
    for label in farms:                               # warm
        once(label)
    ts = {label: [] for label in farms}
    for _ in range(n_iters):
        for label in farms:
            ts[label].append(once(label))
    out = {}
    for label in farms:
        s = sorted(ts[label])
        out[label] = {"ms_first_flush": first[label],
                      "ms_per_flush": s[len(s) // 2],
                      "launches_per_flush": launches[label]}
    return out


def _assert_bit_identical(a, b):
    for core in a:
        for client in a[core]:
            np.testing.assert_array_equal(a[core][client],
                                          b[core][client])


def _gang_section(n_streams, p, lm, cm, smoke):
    group, cand = _compatible_group(p, lm, cm)
    n_clients = max(1, n_streams // LANES_PER_CLIENT)
    uniform = {name: 16 * LANES_PER_CLIENT + 37 for name in group}

    # Bit-identity gate before any timing: same traffic, both launch modes.
    farms = {g: _build_farm(group, cand, n_clients, g) for g in (True, False)}
    outs = {g: _flush_once(farms[g], group, n_clients, uniform)
            for g in (True, False)}
    _assert_bit_identical(outs[True], outs[False])
    key = _compat_key(farms[True].services[group[0]])

    protocols = {"coalesced": 16}
    if not smoke:
        protocols["bulk"] = cand.t_block // 2
    n_iters = 3 if smoke else 9
    result = {
        "group": group,
        "compat_key": {"i_dim": cand.i_dim, "h_dim": cand.h_dim,
                       "dtype": cand.dtype_name,
                       "compute_unit": cand.compute_unit,
                       "s_block": cand.s_block, "t_block": cand.t_block,
                       "unroll": cand.unroll,
                       "full_key": [str(x) for x in key]},
        "n_streams_per_core": n_clients * LANES_PER_CLIENT,
        "bit_identical": True,
        "protocols": {},
    }
    for proto, rows in protocols.items():
        words = {name: rows * LANES_PER_CLIENT for name in group}
        words_per_flush = len(group) * n_clients * rows * LANES_PER_CLIENT
        gang_farms = {g: _build_farm(group, cand, n_clients, g)
                      for g in (True, False)}
        timings = _interleaved_flushes(gang_farms, group, n_clients, words,
                                       n_iters, cold=False)
        stats = {g: dict(timings[g],
                         words_per_s=words_per_flush
                         / (timings[g]["ms_per_flush"] / 1e3))
                 for g in (True, False)}
        stats[True]["dispatch_misses"] = gang_farms[True].dispatch_misses
        speedup = (stats[True]["words_per_s"] /
                   stats[False]["words_per_s"])
        result["protocols"][proto] = {
            "rows_per_client_flush": rows,
            "words_per_flush": words_per_flush,
            "gang": stats[True],
            "per_core": stats[False],
            "speedup": speedup,
        }
        emit(f"farm/gang_{proto}", stats[True]["ms_per_flush"] * 1e3,
             f"group={len(group)};speedup={speedup:.2f}x;"
             f"gang_words_per_s={stats[True]['words_per_s']:.3e};"
             f"per_core_words_per_s={stats[False]['words_per_s']:.3e}")
    result["speedup"] = max(pr["speedup"]
                            for pr in result["protocols"].values())
    return result


def _async_section(n_streams, p, lm, cm, smoke):
    """Uncoordinated async tenants vs per-draw and manual-flush baselines.

    Operating point: every tenant draws ``ASYNC_ROWS`` word rows per round
    with a ``ASYNC_DEADLINE_MS`` deadline and no flush calls anywhere; the
    front-end's row threshold is one full round of demand, so the launch
    fires the moment the round's last tenant submits (the deadline is the
    stragglers' backstop).  ``per_draw`` serves the same traffic one
    ``farm.draw`` (= one launch) at a time; ``manual_flush`` queues the
    whole round by hand and flushes once — the coordination optimum.
    Deadline-miss latency is measured per request at delivery time.
    """
    from repro.serve.async_frontend import (AsyncOscillatorFarm,
                                            percentile)

    group, cand = _compatible_group(p, lm, cm)
    n_clients = max(1, n_streams // LANES_PER_CLIENT)
    tenants = [(name, f"c{j}") for name in group for j in range(n_clients)]
    words_per_draw = ASYNC_ROWS * LANES_PER_CLIENT
    words_per_round = len(tenants) * words_per_draw
    round_rows = len(group) * ASYNC_ROWS     # launch rows of one full round
    n_rounds = 3 if smoke else 9

    # --- bit-identity gate: async-delivered words == gang=False solo ------
    gate_farm = _build_farm(group, cand, n_clients, True)
    delivered = {}

    async def _round(af):
        futs = [af.submit(core, cl, words_per_draw,
                          deadline_ms=ASYNC_DEADLINE_MS)
                for core, cl in tenants]
        return list(await asyncio.gather(*futs))

    async def _gate():
        async with AsyncOscillatorFarm(gate_farm,
                                       auto_flush_rows=round_rows) as af:
            for _ in range(2):               # round 2 hits warmed caches
                for (core, cl), w in zip(tenants, await _round(af)):
                    delivered.setdefault((core, cl), []).append(
                        np.asarray(w))

    asyncio.run(_gate())
    solo = _build_farm(group, cand, n_clients, False)
    for (core, cl), chunks in delivered.items():
        mine = np.concatenate(chunks)
        np.testing.assert_array_equal(mine, solo.draw(core, cl, mine.size))

    # --- async timing ------------------------------------------------------
    stats = {}
    farm = _build_farm(group, cand, n_clients, True)
    times, first, miss = [], [None], [0.0, 0.0, 0.0]

    async def _bench():
        async with AsyncOscillatorFarm(farm,
                                       auto_flush_rows=round_rows) as af:
            t0 = time.perf_counter()
            await _round(af)                               # compile
            first[0] = (time.perf_counter() - t0) * 1e3
            await _round(af)                               # warm
            n_before = len(af.miss_samples_ms())
            for _ in range(n_rounds):
                t0 = time.perf_counter()
                await _round(af)
                times.append((time.perf_counter() - t0) * 1e3)
            timed = af.miss_samples_ms()[n_before:]
            miss[0] = percentile(timed, 0.50)
            miss[1] = percentile(timed, 0.99)
            miss[2] = max(timed)

    l0 = farm.launches
    asyncio.run(_bench())
    ts = sorted(times)
    stats["async"] = {
        "ms_first_round": first[0],
        "ms_per_round": ts[len(ts) // 2],
        "words_per_s": words_per_round / (ts[len(ts) // 2] / 1e3),
        "launches_per_round": (farm.launches - l0) / (n_rounds + 2),
        "p50_miss_ms": miss[0], "p99_miss_ms": miss[1],
        "max_miss_ms": miss[2],
    }

    # --- sync baselines ----------------------------------------------------
    def _baseline(mode):
        bfarm = _build_farm(group, cand, n_clients, True)

        def round_():
            if mode == "per_draw":
                for core, cl in tenants:
                    bfarm.draw(core, cl, words_per_draw)
            else:                            # manual_flush: hand-coalesced
                for core, cl in tenants:
                    bfarm.request(core, cl, words_per_draw)
                bfarm.flush()

        t0 = time.perf_counter()
        round_()
        first_ms = (time.perf_counter() - t0) * 1e3
        round_()
        l0 = bfarm.launches
        bts = []
        for _ in range(n_rounds):
            t0 = time.perf_counter()
            round_()
            bts.append((time.perf_counter() - t0) * 1e3)
        bts.sort()
        return {"ms_first_round": first_ms,
                "ms_per_round": bts[len(bts) // 2],
                "words_per_s": words_per_round / (bts[len(bts) // 2] / 1e3),
                "launches_per_round": (bfarm.launches - l0) / n_rounds}

    stats["per_draw"] = _baseline("per_draw")
    stats["manual_flush"] = _baseline("manual_flush")

    speedup = (stats["async"]["words_per_s"]
               / stats["per_draw"]["words_per_s"])
    vs_manual = (stats["async"]["words_per_s"]
                 / stats["manual_flush"]["words_per_s"])
    result = {
        "group": group,
        "n_tenants": len(tenants),
        "rows_per_draw": ASYNC_ROWS,
        "deadline_ms": ASYNC_DEADLINE_MS,
        "auto_flush_rows": round_rows,
        "words_per_round": words_per_round,
        "bit_identical": True,
        **stats,
        "speedup_vs_per_draw": speedup,
        "ratio_vs_manual_flush": vs_manual,
    }
    emit("farm/async_coalesced", stats["async"]["ms_per_round"] * 1e3,
         f"tenants={len(tenants)};speedup_vs_per_draw={speedup:.2f}x;"
         f"vs_manual={vs_manual:.2f}x;"
         f"async_words_per_s={stats['async']['words_per_s']:.3e};"
         f"p99_miss_ms={stats['async']['p99_miss_ms']:.2f}")
    return result


SLOW_LAUNCH_S = 0.25              # injected launch duration (offload proof)


class _SlowFlush:
    """Wrap ``farm.flush`` so every launch pass (``deliver=False``) takes
    a known ``delay_s`` — the offload section needs a launch long enough
    that loop (un)responsiveness during it is unambiguous."""

    def __init__(self, farm, delay_s):
        self.farm = farm
        self.orig = farm.flush
        self.delay_s = delay_s

    def __call__(self, *a, **kw):
        if not kw.get("deliver", True):
            time.sleep(self.delay_s)
        return self.orig(*a, **kw)


def _offload_probe(offload, group, cand, n_clients, n_rounds, delay_s):
    """Ingress latency while a slow launch is in flight, one mode.

    A foreign thread (this one) submits a big draw, waits for its launch
    to be in flight, then measures round-trips of zero-word draws through
    the event loop — the loop-liveness probe behind every ingress path
    (submit scheduling, draw_sync wakeups, cancellation, deadlines).
    With ``offload=True`` the launch runs on the worker thread and probes
    return in microseconds; with ``offload=False`` (the PR 5 on-loop
    behavior) the first probe blocks for the whole launch.

    Returns (probe samples ms, delivered words per round).
    """
    from repro.serve.async_frontend import AsyncOscillatorFarm

    farm = _build_farm(group, cand, n_clients, True)
    slow = _SlowFlush(farm, delay_s)
    farm.flush = slow
    af = AsyncOscillatorFarm(farm, offload=offload).start_thread()
    probes, words = [], []
    core0 = group[0]
    try:
        for _ in range(n_rounds):
            dfut = asyncio.run_coroutine_threadsafe(
                af.draw(core0, "c0", ASYNC_ROWS * LANES_PER_CLIENT,
                        deadline_ms=0), af.loop)
            deadline = time.perf_counter() + 4 * delay_s + 5.0
            while not af.in_flight and not dfut.done():
                if time.perf_counter() > deadline:
                    raise RuntimeError("launch never became in-flight")
                time.sleep(1e-4)
            while af.in_flight and not dfut.done():
                t0 = time.perf_counter()
                asyncio.run_coroutine_threadsafe(
                    af.draw(core0, "c0", 0), af.loop).result(30.0)
                probes.append((time.perf_counter() - t0) * 1e3)
            words.append(np.asarray(dfut.result(30.0)))
    finally:
        farm.flush = slow.orig
        af.close()
    return probes, words


def _backpressure_point(group, cand, n_clients, delay_s):
    """Overload the front-end past a low queued-rows ceiling: over-limit
    submits must fail fast with ``Overloaded`` (typed, with a retry hint)
    while every admitted future still resolves with its exact words."""
    from repro.serve.admission import AdmissionController, Overloaded
    from repro.serve.async_frontend import AsyncOscillatorFarm

    farm = _build_farm(group, cand, n_clients, True)
    slow = _SlowFlush(farm, delay_s)
    farm.flush = slow
    ceiling = 2 * ASYNC_ROWS
    ac = AdmissionController(max_queued_rows=ceiling)
    af = AsyncOscillatorFarm(farm, admission=ac).start_thread()
    n_offered = 32
    words_per_draw = ASYNC_ROWS * LANES_PER_CLIENT
    served = rejected = failed = 0
    try:
        futs = [asyncio.run_coroutine_threadsafe(
                    af.draw(group[0], "c0", words_per_draw, deadline_ms=1.0),
                    af.loop)
                for _ in range(n_offered)]
        for f in futs:
            try:
                served += int(f.result(60.0).size == words_per_draw)
            except Overloaded as e:
                rejected += 1
                assert e.retry_after_ms >= 0.0 and e.scope == "farm"
            except Exception:            # noqa: BLE001 - tallied for the gate
                failed += 1
    finally:
        farm.flush = slow.orig
        af.close()
    stats = ac.stats()
    return {"offered": n_offered, "queued_rows_ceiling": ceiling,
            "served": served, "rejected": rejected,
            "failed_other": failed,
            "admitted": stats["admitted"],
            "rejected_farm": stats["rejected_farm"],
            "all_admitted_resolved": failed == 0
            and served + rejected == n_offered}


def _async_offload_section(n_streams, p, lm, cm, smoke):
    """The production-tier proof: executor offload keeps ingress live
    during slow launches, and admission control sheds overload.

    ``offload`` vs ``on_loop`` run identical traffic against a launch
    padded to ``SLOW_LAUNCH_S``; the p99 ingress probe (foreign-thread
    round-trip through the event loop while the launch is in flight) is
    the headline — the acceptance bar is p99 < 10% of the launch
    duration, where the on-loop baseline is pinned near 100%.  Delivered
    words are checked bit-identical across both modes and against the
    ``gang=False`` solo path before anything is reported.
    """
    from repro.serve.async_frontend import percentile

    group, cand = _compatible_group(p, lm, cm)
    n_clients = max(1, n_streams // LANES_PER_CLIENT)
    n_rounds = 2 if smoke else 4
    delay_s = SLOW_LAUNCH_S / (2 if smoke else 1)

    modes = {}
    delivered = {}
    for label, offload in (("offload", True), ("on_loop", False)):
        probes, words = _offload_probe(offload, group, cand, n_clients,
                                       n_rounds, delay_s)
        delivered[label] = words
        modes[label] = {
            "probe_samples": len(probes),
            "ingress_p50_ms": percentile(probes, 0.50),
            "ingress_p99_ms": percentile(probes, 0.99),
            "ingress_max_ms": max(probes, default=0.0),
        }

    # bit-identity: offload on == off == gang=False solo, round by round
    solo = _build_farm(group, cand, n_clients, False)
    bit_identical = True
    for a, b in zip(delivered["offload"], delivered["on_loop"]):
        ref = solo.draw(group[0], "c0", a.size)
        if not (np.array_equal(a, b) and np.array_equal(a, ref)):
            bit_identical = False
    back = _backpressure_point(group, cand, n_clients, delay_s / 4)

    launch_ms = delay_s * 1e3
    p99_frac = modes["offload"]["ingress_p99_ms"] / launch_ms
    result = {
        "group": group,
        "launch_ms_injected": launch_ms,
        "rounds": n_rounds,
        "bit_identical": bit_identical,
        "offload": modes["offload"],
        "on_loop": modes["on_loop"],
        "offload_p99_frac_of_launch": p99_frac,
        "backpressure": back,
    }
    emit("farm/async_offload", modes["offload"]["ingress_p99_ms"] * 1e3,
         f"p99_frac_of_launch={p99_frac:.4f};"
         f"on_loop_p99_ms={modes['on_loop']['ingress_p99_ms']:.1f};"
         f"bit_identical={bit_identical};"
         f"backpressure_rejects={back['rejected']}")
    return result


def _sharded_section(n_streams, p, lm, cm, smoke):
    """One logical gang launch across every forced host device.

    Runs the gang group's coalesced operating point at every available
    device count in {1, 2, 4, 8} (1 = the plain unsharded gang path; the
    CI sharded leg forces 4 via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).  Three
    invariants are recorded for the gate:

    * **bit-identity** — delivered words at every device count equal the
      1-device gang path, stream for stream (the sharded kernels' whole
      contract);
    * **launches/flush invariance** — sharding must not fragment the
      logical launch: the farm pays the same launches per flush at every
      device count;
    * **scaling** — words/s at 4 devices vs 1.  Forced host devices
      time-slice the physical cores, so the >= 2x bar arms only when the
      host actually has >= 4 CPUs (``speedup_gate_armed`` records the
      decision; the CI leg runs on such a host).

    The fitted cross-device launch overhead (``GangCostModel.fit`` with
    the largest mesh) is surfaced so planner decisions on a mesh are
    auditable.
    """
    import os

    import jax
    from jax.sharding import Mesh

    group, cand = _compatible_group(p, lm, cm)
    n_clients = max(1, n_streams // LANES_PER_CLIENT)
    avail = jax.device_count()
    counts = [n for n in (1, 2, 4, 8) if n <= avail]
    rows = 16                                  # the coalesced point
    words = {name: rows * LANES_PER_CLIENT for name in group}
    words_per_flush = len(group) * n_clients * rows * LANES_PER_CLIENT
    n_iters = 3 if smoke else 9
    host_cpus = os.cpu_count() or 1

    def build(n_dev):
        mesh = (None if n_dev == 1
                else Mesh(np.array(jax.devices()[:n_dev]), ("data",)))
        return _build_farm(group, cand, n_clients, True, mesh=mesh)

    # --- bit-identity gate: every device count vs the 1-device path -------
    outs = {}
    gate_farms = {n: build(n) for n in counts}
    for n, farm in gate_farms.items():
        outs[n] = _flush_once(farm, group, n_clients, words)
    bit_identical = True
    for n in counts[1:]:
        try:
            _assert_bit_identical(outs[n], outs[1])
        except AssertionError:
            bit_identical = False
    ganged = all(f.gang_launches > 0 for f in gate_farms.values())

    # --- timing: identical traffic, interleaved across device counts ------
    farms = {f"dev{n}": build(n) for n in counts}
    timings = _interleaved_flushes(farms, group, n_clients, words,
                                   n_iters, cold=False)
    per_count = {}
    for n in counts:
        t = timings[f"dev{n}"]
        per_count[str(n)] = dict(
            t, words_per_s=words_per_flush / (t["ms_per_flush"] / 1e3))
    launch_counts = {v["launches_per_flush"] for v in per_count.values()}

    speedup = (per_count["4"]["words_per_s"] / per_count["1"]["words_per_s"]
               if "4" in per_count else None)
    armed = 4 in counts and host_cpus >= 4
    result = {
        "group": group,
        "device_counts": counts,
        "host_cpus": host_cpus,
        "rows_per_client_flush": rows,
        "words_per_flush": words_per_flush,
        "bit_identical": bit_identical,
        "ganged_on_mesh": ganged,
        "per_device_count": per_count,
        "launches_per_flush_invariant": len(launch_counts) == 1,
        "speedup_4dev_vs_1dev": speedup,
        "speedup_gate_armed": armed,
    }
    if not armed and speedup is not None:
        result["speedup_gate_skip_reason"] = (
            f"host has {host_cpus} CPUs: forced devices time-slice, "
            f"words/s cannot scale")
    if counts[-1] > 1:
        mesh = Mesh(np.array(jax.devices()[:counts[-1]]), ("data",))
        model = GangCostModel.fit(cand, backend="pallas_interpret",
                                  mesh=mesh)
        result["fitted_cross_dev_overhead_cycles"] = (
            model.cross_dev_overhead_cycles)
    emit("farm/sharded",
         per_count[str(counts[-1])]["ms_per_flush"] * 1e3,
         f"devices={counts};bit_identical={bit_identical};"
         f"launches_invariant={result['launches_per_flush_invariant']};"
         f"speedup_4v1={'n/a' if speedup is None else f'{speedup:.2f}x'};"
         f"gate_armed={armed}")
    return result


def _planner_section(n_streams, p, lm, cm, smoke, profile=False):
    """Demand-shaped planner vs the PR 3 padded group-max gang policy.

    Measured on the f32 variant of the group's DSE solution: CPU interpret
    mode emulates bf16 by converting around every vector op, which makes
    per-op dispatch dominate and a C-tall stacked sweep cost the same as a
    single-core one — hiding exactly the overdraw compute the planner
    eliminates.  f32 keeps interpret costs proportional to array work, the
    regime a real TPU is in for either dtype (the gang section keeps the
    DSE-chosen bf16).
    """
    import dataclasses
    group, cand = _compatible_group(p, lm, cm)
    cand = dataclasses.replace(cand, dtype_bytes=4)
    n_clients = max(1, n_streams // LANES_PER_CLIENT)
    hot = group[0]
    skewed = {name: (HOT_ROWS if name == hot else COLD_ROWS)
              * LANES_PER_CLIENT for name in group}
    uniform = {name: UNIFORM_ROWS * LANES_PER_CLIENT for name in group}
    n_iters = 3 if smoke else 9

    # Launch-cost model fitted from real launches of this exact candidate,
    # so planner decisions reflect this machine (paper: estimate-then-
    # validate, applied to the launch model).
    model = GangCostModel.fit(cand, backend="pallas_interpret")
    result = {
        "group": group, "hot_core": hot,
        "dtype": cand.dtype_name,
        "rows": {"hot": HOT_ROWS, "cold": COLD_ROWS,
                 "uniform": UNIFORM_ROWS},
        "model": {"launch_overhead_cycles": model.launch_overhead_cycles,
                  "cell_overhead_cycles": model.cell_overhead_cycles,
                  "stacked_step_scale": model.stacked_step_scale,
                  "freeze_row_cycles": model.freeze_row_cycles,
                  "sec_per_cycle": model.sec_per_cycle},
    }

    # Bit-identity gate across two skewed flush rounds (the second round
    # exercises buffered state from the first) before any timing.
    check = {"planner": _build_farm(group, cand, n_clients, True,
                                    gang_cost_model=model),
             "solo": _build_farm(group, cand, n_clients, False)}
    for _ in range(2):
        outs = {k: _flush_once(f, group, n_clients, skewed)
                for k, f in check.items()}
        _assert_bit_identical(outs["planner"], outs["solo"])
    result["bit_identical"] = True

    for point, words in (("skewed", skewed), ("uniform", uniform)):
        words_per_flush = n_clients * sum(words.values())
        farms = {"planner": _build_farm(group, cand, n_clients, True,
                                        gang_cost_model=model),
                 "policy": _build_farm(group, cand, n_clients, True,
                                       planner=False)}
        timings = _interleaved_flushes(
            farms, group, n_clients, words,
            n_iters if point == "skewed" else max(n_iters, 7),
            cold=(point == "skewed"))
        stats = {}
        for label, farm in farms.items():
            stats[label] = dict(
                timings[label],
                words_per_s=words_per_flush
                / (timings[label]["ms_per_flush"] / 1e3),
                dispatch_misses=farm.dispatch_misses,
                decisions=farm.plan_decisions,
            )
        speedup = (stats["planner"]["words_per_s"]
                   / stats["policy"]["words_per_s"])
        result[point] = {
            "words_per_flush": words_per_flush,
            "timing": "cold_start" if point == "skewed" else "steady_state",
            "planner": stats["planner"], "policy": stats["policy"],
            "speedup": speedup,
        }
        emit(f"farm/planner_{point}", stats["planner"]["ms_per_flush"] * 1e3,
             f"speedup={speedup:.2f}x;"
             f"planner_words_per_s={stats['planner']['words_per_s']:.3e};"
             f"policy_words_per_s={stats['policy']['words_per_s']:.3e};"
             f"decisions={stats['planner']['decisions']}")

    if profile:
        farm = _build_farm(group, cand, n_clients, True,
                           gang_cost_model=model, profile=True)
        _interleaved_flushes({"profile": farm}, group, n_clients, skewed,
                             n_iters, cold=True)
        prof = farm.profile_stats
        n = max(prof.pop("flushes"), 1.0)
        result["profile_ms_per_flush"] = {k: v / n * 1e3
                                          for k, v in prof.items()}
        emit("farm/planner_profile", 0.0,
             ";".join(f"{k}={v:.2f}ms"
                      for k, v in result["profile_ms_per_flush"].items()))
    return result


LATTICE_SPEC = "chen@ring32"      # 32-node ring of Chen cores: I=96, H=256
LATTICE_LANES = 128               # streams per lattice draw


def _lattice_section(p, lm, cm, smoke):
    """Block-coupled lattice: vpu-vs-mxu on model AND measurement.

    The scalar systems never let the MXU win (I, H too small: 128-padding
    swamps the useful MACs), so this section is where the mxu arm of the
    DSE earns its keep.  At 32 ring-coupled Chen nodes the contraction is
    genuinely MXU-shaped and ``select_config`` must pick mxu on the cycle
    model; the measured run re-draws the same traffic with each unit's
    selected solution (same s_block/t_block/f32 for both, so the timing
    isolates the compute-unit choice).

    Measured-number caveat (recorded in the section): CPU interpret mode
    executes the vpu path's ~I+H broadcast-FMA passes as that many XLA
    ops per step where the mxu path issues a handful of matmuls, so the
    measured mxu win is partly op-dispatch economics; on a real TPU the
    same ordering comes from the 128x128 systolic array instead.  The
    cycle model is the hardware-facing claim; the measured run checks the
    ordering end to end.  The mxu-vs-vpu measured gate arms only on hosts
    with >= 4 CPUs (same discipline as the sharded scaling gate); raw
    numbers are always recorded.
    """
    import dataclasses
    import os

    from repro.core.ann import lattice_meta_tuple
    from repro.core.dse import (VMEM_USABLE, select_config,
                                stacked_gang_vmem_bytes)
    from repro.kernels import ops

    params = {k: jnp.asarray(v)
              for k, v in default_params(system=LATTICE_SPEC).items()}
    i_dim, h_dim = params["w1"].shape
    n_nodes, base_dim, topo, strength = lattice_meta_tuple(
        np.asarray(params["lattice_meta"]))
    lanes = LATTICE_LANES
    host_cpus = os.cpu_count() or 1

    cands = {unit: select_config(i_dim, h_dim, s_total=lanes, unit=unit,
                                 n_nodes=n_nodes)
             for unit in ("vpu", "mxu")}
    selected = select_config(i_dim, h_dim, s_total=lanes, n_nodes=n_nodes)

    # Measured draw: identical blocking and f32 for both units (interpret
    # mode's emulated bf16 would bill per-op conversions to whichever unit
    # issues more ops); t_block clamped hard because interpret-mode trace
    # cost grows ~quadratically in the unrolled body (t_block * (I + H)
    # ops — at I=96, H=256 a t_block of 16 already costs minutes to trace).
    t_blk = 4 if smoke else 8
    n_steps = 4 * t_blk
    n_words = (n_steps // 2) * lanes
    units = {}
    for unit, cand in cands.items():
        run_cand = dataclasses.replace(cand, p=0, t_block=t_blk, unroll=2,
                                       dtype_bytes=4)   # s_block = lanes
        x0 = _splitmix_seeds(jnp.uint32(1), lanes, i_dim).astype(
            jnp.dtype(run_cand.dtype_name))

        def draw(c=run_cand, x=x0):
            words, _ = chaotic_bits(params, x, n_steps,
                                    backend="pallas_interpret", config=c)
            return np.asarray(words)

        us = time_fn(draw, n_iters=3, warmup=1)
        meas = measure_candidate(cand)
        units[unit] = {
            "s_block": cand.s_block, "t_block": cand.t_block,
            "unroll": cand.unroll, "dtype": cand.dtype_name,
            "modeled_cycles_per_step": meas["cycles_per_step"],
            "modeled_samples_per_s": meas["samples_per_sec"],
            "words_per_s": n_words / (us / 1e6),
        }

    mxu_wins_model = (units["mxu"]["modeled_samples_per_s"]
                      > units["vpu"]["modeled_samples_per_s"])
    measured_speedup = (units["mxu"]["words_per_s"]
                        / units["vpu"]["words_per_s"])
    armed = host_cpus >= 4

    # --- >= 24-member stacked-gang bit-identity vs solo lattice draws -----
    C = 24
    gc = dataclasses.replace(cands["vpu"], p=0, t_block=t_blk, unroll=2,
                             dtype_bytes=4)              # s_block = lanes
    dtype = jnp.dtype(gc.dtype_name)
    x0_all = _splitmix_seeds(jnp.uint32(7), C * lanes, i_dim).astype(
        dtype).reshape(C, lanes, i_dim)
    gang_params = {k: jnp.stack([params[k]] * C)
                   for k in ("w1", "b1", "w2", "b2")}
    gang_params["coupling"] = params["coupling"]
    gang_params["lattice_meta"] = params["lattice_meta"]
    gwords, gstate = ops.chaotic_bits_gang_stacked(
        gang_params, x0_all, n_steps, jnp.zeros((C, lanes), jnp.uint32),
        backend="pallas_interpret", config=gc)
    gwords, gstate = np.asarray(gwords), np.asarray(gstate)
    gang_ok = True
    for ci in range(C):
        swords, sstate = chaotic_bits(params, x0_all[ci], n_steps,
                                      backend="pallas_interpret", config=gc)
        gang_ok &= bool(np.array_equal(gwords[:, ci, :], np.asarray(swords))
                        and np.array_equal(gstate[ci], np.asarray(sstate)))

    # --- stacked-layout VMEM cliff (the planner's fallback threshold) -----
    cliff = 1
    while stacked_gang_vmem_bytes(cands["vpu"], cliff) <= VMEM_USABLE:
        cliff += 1
        if cliff > 1_000_000:       # unreachable guard: tiny candidate
            cliff = None
            break

    result = {
        "system": LATTICE_SPEC,
        "n_nodes": n_nodes, "base_dim": base_dim, "topology": topo,
        "coupling_strength": strength,
        "i_dim": i_dim, "h_dim": h_dim, "lanes": lanes,
        "n_steps_measured": n_steps,
        "units": units,
        "selected_compute_unit": selected.compute_unit,
        "mxu_wins_model": bool(mxu_wins_model),
        "measured_speedup_mxu_vs_vpu": measured_speedup,
        "mxu_wins_measured": bool(measured_speedup > 1.0),
        "speedup_gate_armed": bool(armed),
        "gang_members": C,
        "gang_bit_identical": gang_ok,
        "stacked_vmem_cliff_cores": cliff,
        "stacked_gang_vmem_at_cliff": (
            None if cliff is None
            else stacked_gang_vmem_bytes(cands["vpu"], cliff)),
        "vmem_usable_bytes": VMEM_USABLE,
        "measured_note": (
            "CPU interpret mode: the measured mxu win is partly per-op "
            "dispatch economics (vpu issues ~I+H elementwise passes per "
            "step, mxu a handful of matmuls); on TPU hardware the same "
            "ordering comes from the systolic array. The cycle model is "
            "the hardware-facing claim."),
    }
    if not armed:
        result["speedup_gate_skip_reason"] = (
            f"host has {host_cpus} CPUs: measured vpu-vs-mxu ordering is "
            f"not trustworthy under contention")
    emit("farm/lattice", units["mxu"]["words_per_s"],
         f"spec={LATTICE_SPEC};selected={selected.compute_unit};"
         f"mxu_model_speedup="
         f"{units['mxu']['modeled_samples_per_s'] / units['vpu']['modeled_samples_per_s']:.2f}x;"
         f"mxu_measured_speedup={measured_speedup:.2f}x;"
         f"gang24_bit_identical={gang_ok};vmem_cliff_cores={cliff}")
    return result


TRANSIENT_RATE = 0.10             # the resilience storm's launch-fault coin
FAULT_SEED = 2                    # chosen so the coin lands in a short run


def _resilience_section(n_streams, p, lm, cm, smoke):
    """Self-healing under a seeded fault storm: words/s + p99 round
    latency before / during / after a 10%-transient-launch-failure storm
    with one poisoned core.

    The storm phase arms a ``FaultPlan``: every launch flips a seeded 10%
    coin (a transient failure the supervision layer must retry with
    FakeClock-disciplined backoff — real time here, but the same code
    path the FakeClock suite drives), and the first group core's monitor
    samples are bit-masked so the online NIST gate condemns it.  The
    farm must quarantine the poisoned core and rotate its standby in
    within 3 flushes, keep degraded throughput at >= 0.5x the clean
    phase, and deliver every word bit-identical to fault-free solo runs
    (the poisoned core: original-core words up to the rotation flush,
    standby-from-row-0 words after).
    """
    from repro.serve.async_frontend import (AsyncOscillatorFarm,
                                            percentile)
    from repro.serve.faults import FaultPlan
    from repro.serve.health import HealthMonitor

    group, cand = _compatible_group(p, lm, cm)
    n_clients = max(1, n_streams // LANES_PER_CLIENT)
    tenants = [(name, f"c{j}") for name in group for j in range(n_clients)]
    words_per_draw = ASYNC_ROWS * LANES_PER_CLIENT
    words_per_round = len(tenants) * words_per_draw
    round_rows = len(group) * ASYNC_ROWS
    poisoned = group[0]
    # one round delivers n_clients * words_per_draw words per core: size
    # the quality window to fill (and be judged) every round
    window = max(256, n_clients * words_per_draw)
    rounds = {"before": 3, "during": 5, "after": 3} if smoke else \
             {"before": 5, "during": 8, "after": 5}

    faults = FaultPlan(seed=FAULT_SEED, transient_rate=TRANSIENT_RATE,
                       poison={poisoned})
    faults.disarm()                        # armed only for the storm phase
    health = HealthMonitor(window_words=window, breaker_threshold=5,
                           backoff_base_ms=1.0, backoff_cap_ms=20.0)
    farm = _build_farm(group, cand, n_clients, True, faults=faults)
    farm.add_standby(poisoned, default_params(system=poisoned),
                     config=cand, dtype=jnp.dtype(cand.dtype_name),
                     lanes_per_client=LANES_PER_CLIENT,
                     backend="pallas_interpret")

    delivered = {}
    phase_times = {}
    rotated_after = [None]                 # storm flushes until rotation

    async def _round(af):
        futs = [af.submit(core, cl, words_per_draw,
                          deadline_ms=ASYNC_DEADLINE_MS)
                for core, cl in tenants]
        out = list(await asyncio.gather(*futs))
        for (core, cl), w in zip(tenants, out):
            delivered.setdefault((core, cl), []).append(np.asarray(w))

    async def _bench():
        async with AsyncOscillatorFarm(farm, offload=False, health=health,
                                       auto_flush_rows=round_rows) as af:
            await _round(af)               # compile + warm (untimed)
            for phase in ("before", "during", "after"):
                if phase == "during":
                    faults.arm()
                elif phase == "after":
                    faults.disarm()
                times = []
                for i in range(rounds[phase]):
                    t0 = time.perf_counter()
                    await _round(af)
                    times.append((time.perf_counter() - t0) * 1e3)
                    if (phase == "during" and rotated_after[0] is None
                            and farm.rotations.get(poisoned) == 1):
                        rotated_after[0] = i + 1
                phase_times[phase] = times

    asyncio.run(_bench())

    # --- bit-identity: every tenant vs fault-free solo runs ---------------
    n_rounds_total = 1 + sum(rounds.values())          # incl. warm round
    solo = _build_farm(group, cand, n_clients, False)
    standby_solo = OscillatorFarm(gang=False)
    standby_solo.add_core(poisoned, default_params(system=poisoned),
                          config=cand, dtype=jnp.dtype(cand.dtype_name),
                          lanes_per_client=LANES_PER_CLIENT,
                          backend="pallas_interpret")
    for j in range(n_clients):
        standby_solo.register(poisoned, f"c{j}", seed=100 + j)
    bit_identical = True
    for (core, cl), chunks in delivered.items():
        if core == poisoned:
            continue
        mine = np.concatenate(chunks)
        bit_identical &= bool(
            np.array_equal(mine, solo.draw(core, cl, mine.size)))
    total = n_rounds_total * words_per_draw
    ref_orig = {f"c{j}": solo.draw(poisoned, f"c{j}", total)
                for j in range(n_clients)}
    ref_stand = {f"c{j}": standby_solo.draw(poisoned, f"c{j}", total)
                 for j in range(n_clients)}
    split_found = None
    for k in range(n_rounds_total + 1):    # k = rounds before the rotation
        cut = k * words_per_draw
        if all(np.array_equal(
                np.concatenate(delivered[(poisoned, cl)]),
                np.concatenate([ref_orig[cl][:cut],
                                ref_stand[cl][:total - cut]]))
               for _, cl in tenants if _ == poisoned):
            split_found = k
            break
    bit_identical &= split_found is not None

    stats = {}
    for phase, times in phase_times.items():
        ts = sorted(times)
        stats[phase] = {
            "ms_per_round": ts[len(ts) // 2],
            "p99_round_ms": percentile(times, 0.99),
            "words_per_s": words_per_round / (ts[len(ts) // 2] / 1e3),
        }
    frac = stats["during"]["words_per_s"] / stats["before"]["words_per_s"]
    result = {
        "group": group, "poisoned_core": poisoned,
        "n_tenants": len(tenants),
        "transient_rate": TRANSIENT_RATE, "fault_seed": FAULT_SEED,
        "window_words": window,
        "rounds": rounds,
        "phases": stats,
        "injected": dict(faults.injected),
        "retries": health.stats["retries"],
        "breaker_trips": health.stats["breaker_trips"],
        "quality_quarantines": health.stats["quality_quarantines"],
        "quarantined_within_flushes": rotated_after[0],
        "rotation_split_round": split_found,
        "rotations": dict(farm.rotations),
        "degraded_words_per_s_frac": frac,
        "bit_identical": bool(bit_identical),
    }
    emit("farm/resilience", stats["during"]["ms_per_round"] * 1e3,
         f"degraded_frac={frac:.2f};"
         f"rotated_within={rotated_after[0]};"
         f"transients={faults.injected['transient']};"
         f"retries={health.stats['retries']};"
         f"during_words_per_s={stats['during']['words_per_s']:.3e}")
    return result


def run_farm(n_streams: int = 256, n_steps: int = 1024, p: int = 1,
             out_json: str | None = "BENCH_farm.json",
             smoke: bool = False, nist_words: int = 20_000,
             profile: bool = False, lattice_only: bool = False) -> dict:
    lm, cm = LatencyModel.fit(), CostModel.fit()
    if smoke:
        n_steps = min(n_steps, 256)
        nist_words = 0
    lattice = _lattice_section(p, lm, cm, smoke)
    if lattice_only:
        res = {"config": {"n_streams": n_streams, "pareto_p": p,
                          "backend": "pallas_interpret", "smoke": smoke,
                          "lattice_only": True},
               "lattice": lattice}
        if out_json:
            pathlib.Path(out_json).write_text(json.dumps(res, indent=2))
        return res
    table = _system_rows(n_streams, n_steps, p, lm, cm, nist_words)
    gang = _gang_section(n_streams, p, lm, cm, smoke)
    async_ = _async_section(n_streams, p, lm, cm, smoke)
    async_offload = _async_offload_section(n_streams, p, lm, cm, smoke)
    planner = _planner_section(n_streams, p, lm, cm, smoke, profile=profile)
    sharded = _sharded_section(n_streams, p, lm, cm, smoke)
    resilience = _resilience_section(n_streams, p, lm, cm, smoke)
    res = {"config": {"n_streams": n_streams, "n_steps": n_steps,
                      "pareto_p": p, "backend": "pallas_interpret",
                      "smoke": smoke},
           "systems": table,
           "gang": gang,
           "async": async_,
           "async_offload": async_offload,
           "planner": planner,
           "sharded": sharded,
           "resilience": resilience,
           "lattice": lattice}
    if out_json:
        pathlib.Path(out_json).write_text(json.dumps(res, indent=2))
    return res


def async_gate(res: dict) -> list[str]:
    """CI perf-smoke acceptance for the async front-end: async-delivered
    words must be bit-identical to the ``gang=False`` solo path, the
    coalesced rounds must actually coalesce (one launch per round), and
    uncoordinated async tenants must beat one-launch-per-draw."""
    errors = []
    a = res["async"]
    if not a.get("bit_identical"):
        errors.append("async-delivered words NOT bit-identical to "
                      "gang=False")
    if a["async"]["launches_per_round"] > 1.0:
        errors.append(
            f"async rounds did not coalesce into one launch: "
            f"{a['async']['launches_per_round']:.2f} launches/round")
    if a["speedup_vs_per_draw"] < 1.0:
        errors.append(
            f"async front-end underperforms one-launch-per-draw: "
            f"{a['speedup_vs_per_draw']:.3f}x "
            f"({a['async']['words_per_s']:.3e} vs "
            f"{a['per_draw']['words_per_s']:.3e} words/s)")
    return errors


def async_offload_gate(res: dict) -> list[str]:
    """CI perf-smoke acceptance for the production tier: with a launch
    padded to a known duration, foreign-thread ingress p99 during the
    launch must stay under 10% of that duration under offload (the
    on-loop baseline pins near 100%), words must be bit-identical across
    offload on/off and the solo path, and backpressure must shed
    over-ceiling load with typed rejects while admitted futures all
    resolve."""
    errors = []
    o = res["async_offload"]
    if not o.get("bit_identical"):
        errors.append("offloaded words NOT bit-identical to the on-loop / "
                      "solo paths")
    if o["offload_p99_frac_of_launch"] >= 0.10:
        errors.append(
            f"ingress p99 during an offloaded launch is "
            f"{o['offload']['ingress_p99_ms']:.2f} ms = "
            f"{o['offload_p99_frac_of_launch']:.1%} of the "
            f"{o['launch_ms_injected']:.0f} ms launch (bar: <10%)")
    b = o["backpressure"]
    if b["rejected"] == 0:
        errors.append("overload shed no requests: the queued-rows ceiling "
                      "never rejected")
    if not b["all_admitted_resolved"]:
        errors.append(
            f"admitted futures did not all resolve under overload: "
            f"served={b['served']} rejected={b['rejected']} "
            f"failed_other={b['failed_other']} of {b['offered']}")
    return errors


def planner_gate(res: dict) -> list[str]:
    """CI perf-smoke acceptance: bit-identity must hold and the planner
    must not lose to the padded group-max policy on the skewed workload."""
    errors = []
    if not res["planner"].get("bit_identical"):
        errors.append("planner delivered words NOT bit-identical to "
                      "gang=False")
    sk = res["planner"]["skewed"]
    if sk["speedup"] < 1.0:
        errors.append(
            f"planner underperforms the group-max policy on the skewed "
            f"workload: {sk['speedup']:.3f}x "
            f"({sk['planner']['words_per_s']:.3e} vs "
            f"{sk['policy']['words_per_s']:.3e} words/s)")
    return errors


def sharded_gate(res: dict) -> list[str]:
    """CI perf-smoke acceptance for device-sharded gang launches: words
    at every device count must be bit-identical to the 1-device gang
    path, the farm must actually gang on the mesh, launches/flush must
    not fragment as devices scale, and (on hosts with the CPUs to show
    it) 4 forced devices must deliver >= 2x the 1-device words/s."""
    errors = []
    s = res["sharded"]
    if not s.get("bit_identical"):
        errors.append("sharded words NOT bit-identical to the 1-device "
                      "gang path")
    if not s.get("ganged_on_mesh"):
        errors.append("mesh-sharded farm fell back to solo launches "
                      "(gang_launches == 0 at some device count)")
    if not s.get("launches_per_flush_invariant"):
        errors.append(
            f"launches/flush varies with device count: "
            f"{ {n: v['launches_per_flush'] for n, v in s['per_device_count'].items()} }")
    if s.get("speedup_gate_armed"):
        if s["speedup_4dev_vs_1dev"] < 2.0:
            errors.append(
                f"sharded scaling below bar: 4-device words/s is "
                f"{s['speedup_4dev_vs_1dev']:.2f}x the 1-device path "
                f"(bar: >= 2x on a >= 4-CPU host)")
    return errors


def lattice_gate(res: dict) -> list[str]:
    """CI acceptance for the lattice/MXU arm: DSE must select mxu on the
    32-node lattice shape, the cycle model must actually rank mxu ahead
    of vpu there, the >= 24-member stacked gang must be bit-identical to
    solo lattice draws, and the stacked-layout VMEM cliff must be
    computed and recorded.  The measured mxu-vs-vpu ordering is enforced
    only on hosts with the CPUs to trust it (armed flag recorded)."""
    errors = []
    L = res["lattice"]
    if L["selected_compute_unit"] != "mxu":
        errors.append(
            f"select_config picked {L['selected_compute_unit']} for the "
            f"{L['n_nodes']}-node lattice (I={L['i_dim']}, "
            f"H={L['h_dim']}); the MXU arm never arms")
    if not L["mxu_wins_model"]:
        errors.append(
            f"cycle model ranks vpu ahead of mxu on the lattice shape: "
            f"{L['units']['mxu']['modeled_samples_per_s']:.3e} vs "
            f"{L['units']['vpu']['modeled_samples_per_s']:.3e} samples/s")
    if not L["gang_bit_identical"]:
        errors.append(
            f"{L['gang_members']}-member stacked lattice gang NOT "
            f"bit-identical to solo lattice draws")
    if L["stacked_vmem_cliff_cores"] is None:
        errors.append("stacked-layout VMEM cliff not computed")
    if L["speedup_gate_armed"] and not L["mxu_wins_measured"]:
        errors.append(
            f"measured lattice draw: mxu does not beat vpu "
            f"({L['units']['mxu']['words_per_s']:.3e} vs "
            f"{L['units']['vpu']['words_per_s']:.3e} words/s = "
            f"{L['measured_speedup_mxu_vs_vpu']:.2f}x)")
    return errors


def resilience_gate(res: dict) -> list[str]:
    """CI perf-smoke acceptance for the self-healing layer: under the
    seeded 10%-transient + one-poisoned-core storm, the poisoned core
    must quarantine and rotate within 3 flushes, the storm must actually
    have injected faults, degraded throughput must hold >= 0.5x the
    clean phase, and every delivered word (rotation included) must be
    bit-identical to fault-free solo runs."""
    errors = []
    r = res["resilience"]
    if not r.get("bit_identical"):
        errors.append("storm-delivered words NOT bit-identical to "
                      "fault-free solo runs (no rotation split matches)")
    if r["quarantined_within_flushes"] is None or \
            r["quarantined_within_flushes"] > 3:
        errors.append(
            f"poisoned core not quarantined+rotated within 3 flushes "
            f"(took {r['quarantined_within_flushes']})")
    if r["injected"]["transient"] < 1:
        errors.append("the seeded storm injected no transient launch "
                      "failures — the retry path went unexercised")
    if r["retries"] < 1:
        errors.append("transient failures were injected but never "
                      "retried")
    if r["degraded_words_per_s_frac"] < 0.5:
        errors.append(
            f"degraded throughput below bar: storm words/s is "
            f"{r['degraded_words_per_s_frac']:.2f}x the clean phase "
            f"(bar: >= 0.5x)")
    return errors


if __name__ == "__main__":
    import sys
    lattice_only = "--lattice" in sys.argv
    res = run_farm(smoke="--smoke" in sys.argv,
                   profile="--profile" in sys.argv,
                   lattice_only=lattice_only)
    errors = [f"LATTICE GATE FAIL: {e}" for e in lattice_gate(res)]
    if not lattice_only:
        errors += [f"PLANNER GATE FAIL: {e}" for e in planner_gate(res)]
        errors += [f"ASYNC GATE FAIL: {e}" for e in async_gate(res)]
        errors += [f"OFFLOAD GATE FAIL: {e}"
                   for e in async_offload_gate(res)]
        errors += [f"SHARDED GATE FAIL: {e}" for e in sharded_gate(res)]
        errors += [f"RESILIENCE GATE FAIL: {e}"
                   for e in resilience_gate(res)]
    if errors:
        for e in errors:
            print(e, file=sys.stderr)
        raise SystemExit(1)
    L = res["lattice"]
    print(f"lattice gate OK: {L['system']} selected="
          f"{L['selected_compute_unit']}, model mxu/vpu "
          f"{L['units']['mxu']['modeled_samples_per_s'] / L['units']['vpu']['modeled_samples_per_s']:.2f}x, "
          f"measured {L['measured_speedup_mxu_vs_vpu']:.2f}x "
          f"({'armed' if L['speedup_gate_armed'] else 'disarmed'}), "
          f"gang{L['gang_members']} bit-identical, VMEM cliff at "
          f"{L['stacked_vmem_cliff_cores']} cores")
    if lattice_only:
        raise SystemExit(0)
    print(f"planner gate OK: skewed speedup "
          f"{res['planner']['skewed']['speedup']:.2f}x, uniform ratio "
          f"{res['planner']['uniform']['speedup']:.2f}x")
    print(f"async gate OK: {res['async']['speedup_vs_per_draw']:.2f}x over "
          f"per-draw ({res['async']['ratio_vs_manual_flush']:.2f}x of the "
          f"manual-flush optimum), p99 deadline miss "
          f"{res['async']['async']['p99_miss_ms']:.2f} ms")
    o = res["async_offload"]
    print(f"offload gate OK: ingress p99 "
          f"{o['offload']['ingress_p99_ms']:.2f} ms during a "
          f"{o['launch_ms_injected']:.0f} ms launch "
          f"({o['offload_p99_frac_of_launch']:.1%}; on-loop baseline "
          f"{o['on_loop']['ingress_p99_ms']:.1f} ms), "
          f"{o['backpressure']['rejected']} typed rejects under overload")
    sh = res["sharded"]
    sp = sh["speedup_4dev_vs_1dev"]
    gate_state = ("armed" if sh["speedup_gate_armed"] else
                  "disarmed: " + sh.get("speedup_gate_skip_reason",
                                        "1 device"))
    print(f"sharded gate OK: devices={sh['device_counts']}, "
          f"bit-identical, launches/flush invariant, 4v1 speedup "
          f"{'n/a' if sp is None else f'{sp:.2f}x'} (gate {gate_state})")
    r = res["resilience"]
    print(f"resilience gate OK: poisoned core rotated within "
          f"{r['quarantined_within_flushes']} flush(es), "
          f"{r['injected']['transient']} transients / {r['retries']} "
          f"retries, degraded throughput "
          f"{r['degraded_words_per_s_frac']:.2f}x clean, bit-identical "
          f"through the storm")
