"""Cross-system oscillator-farm benchmark (BENCH_farm.json).

One row per registered chaotic system: the registry-trained oscillator
drawn through the fused ``ops.chaotic_bits`` path with that system's
DSE-selected solution (the same Pareto point ``generate_farm`` freezes
into the committed farm cores), reporting words/s.  Includes the 4-D
hyperchaotic system, so the ``i_dim != 3`` padding path is measured, not
just tested.  CPU interpret mode: numbers are functional-relative, not
TPU performance; relative ordering across systems is still meaningful.
"""
import json
import pathlib

import jax.numpy as jnp

from repro.core.chaotic import SYSTEMS
from repro.core.dse import (CostModel, LatencyModel, measure_candidate,
                            select)
from repro.kernels.ops import chaotic_bits
from repro.prng.stream import _splitmix_seeds, default_params

from benchmarks.common import emit, time_fn


def run_farm(n_streams: int = 256, n_steps: int = 1024, p: int = 1,
             out_json: str | None = "BENCH_farm.json") -> dict:
    lm, cm = LatencyModel.fit(), CostModel.fit()
    table = {}
    n_words = (n_steps // 2) * n_streams
    for name in sorted(SYSTEMS):
        params = {k: jnp.asarray(v) for k, v in default_params(system=name).items()}
        i_dim, h_dim = params["w1"].shape
        cand = select(i_dim, h_dim, "pareto", p=p,
                      latency_model=lm, cost_model=cm)
        dtype = jnp.dtype(cand.dtype_name)
        x0 = _splitmix_seeds(jnp.uint32(1), n_streams, i_dim).astype(dtype)

        def draw():
            words, _ = chaotic_bits(params, x0, n_steps,
                                    backend="pallas_interpret", config=cand)
            return words

        us = time_fn(draw, n_iters=2, warmup=1)
        words_per_s = n_words / (us / 1e6)
        table[name] = {
            "i_dim": i_dim, "h_dim": h_dim,
            "dtype": cand.dtype_name, "compute_unit": cand.compute_unit,
            "s_block": cand.s_block, "t_block": cand.t_block,
            "unroll": cand.unroll,
            "words_per_s": words_per_s,
            "modeled_samples_per_s": measure_candidate(cand)["samples_per_sec"],
        }
        emit(f"farm/{name}_words_per_s", us,
             f"I={i_dim};H={h_dim};dtype={cand.dtype_name};"
             f"words_per_s={words_per_s:.3e}")
    res = {"config": {"n_streams": n_streams, "n_steps": n_steps,
                      "pareto_p": p, "backend": "pallas_interpret"},
           "systems": table}
    if out_json:
        pathlib.Path(out_json).write_text(json.dumps(res, indent=2))
    return res


if __name__ == "__main__":
    run_farm()
