"""Paper §IV throughput claim: CPU (i7-12700: 100 samples in ~3.5 s via
Python/SciPy) vs the hardware engine (31 us on FPGA).

Here: (a) measured scipy.odeint CPU time for 100 samples (the paper's CPU
baseline), (b) measured jitted-JAX RK-4, (c) measured interpret-mode kernel
(functional check only), and (d) the modeled TPU-engine time from the DSE
cycle model (the deliverable on CPU-only hardware; clearly labeled MODEL).

``run_fused`` benches the PRNG serving hot path: the fused in-kernel
bit-extraction vs the unfused trajectory -> ``bits_from_trajectory``
pipeline, with the kernel config picked by the DSE autotuner
(``select_config``).  Results also land in BENCH_prng_fused.json."""
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from scipy.integrate import odeint

from repro.core.ann import AnnConfig, extract_parameters, train
from repro.core.chaotic import get_system, integrate, make_dataset
from repro.core.dse import CLOCK_HZ, Candidate, measure_candidate, select_config
from repro.kernels.ops import bits_from_trajectory, chaotic_bits, chaotic_trajectory

from benchmarks.common import emit, time_fn


def run(n_samples: int = 100) -> None:
    sys_ = get_system("chen")

    # (a) paper-style CPU baseline: scipy odeint, one sample at a time
    f = lambda x, t: np.asarray(sys_.f(jnp.asarray(x, jnp.float32)), np.float64)
    t0 = time.perf_counter()
    odeint(f, np.asarray(sys_.x0), np.arange(n_samples + 1) * sys_.dt)
    cpu_scipy_us = (time.perf_counter() - t0) * 1e6
    emit("throughput/cpu_scipy_odeint_100", cpu_scipy_us,
         f"samples={n_samples};paper_cpu_us=3.5e6")

    # (b) jitted JAX RK-4 on CPU
    x0 = jnp.asarray(sys_.x0, jnp.float32)
    us = time_fn(lambda: integrate("chen", x0, n_samples))
    emit("throughput/cpu_jax_rk4_100", us, f"samples={n_samples}")

    # (c) trained ANN engine, interpret-mode kernel (functional timing only)
    ds = make_dataset("chen", n_samples=20_000)
    params, _ = train(AnnConfig(hidden=8), ds, epochs=120, lr=3e-3)
    p = {k: jnp.asarray(v) for k, v in extract_parameters(params).items()}
    x0s = jnp.zeros((128, 3), jnp.float32) + 0.1
    us = time_fn(lambda: chaotic_trajectory(p, x0s, n_samples,
                                            backend="pallas_interpret",
                                            s_block=128, t_block=max(4, n_samples // 4) // 4 * 4))
    emit("throughput/kernel_interpret_100x128", us,
         "note=interpret-mode-functional-not-perf")

    # (d) modeled TPU v5e engine time (DSE cycle model, clearly a MODEL)
    for pl in (0, 3, 5):
        c = Candidate(i_dim=3, h_dim=8, p=pl)
        m = measure_candidate(c)
        t_us = n_samples * m["cycles_per_step"] / CLOCK_HZ * 1e6
        thr = m["samples_per_sec"]
        emit(f"throughput/tpu_model_P{pl}_100steps", t_us,
             f"streams={c.s_block};samples_per_s={thr:.3e};"
             f"speedup_vs_scipy={cpu_scipy_us / t_us:.0f}x;source=cycle-model")


def run_fused(n_streams: int = 512, n_steps: int = 2048,
              out_json: str | None = "BENCH_prng_fused.json") -> dict:
    """Fused bit-extraction vs unfused trajectory->pack (CPU interpret).

    Both paths run the identical oscillator kernel with the DSE-selected
    config; the fused one packs words in VMEM (4x less HBM traffic, no
    second pass), the baseline round-trips the float trajectory.
    """
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    params = {"w1": jax.random.normal(ks[0], (3, 8)) * 0.5,
              "b1": jax.random.normal(ks[1], (8,)) * 0.1,
              "w2": jax.random.normal(ks[2], (8, 3)) * 0.5,
              "b2": jax.random.normal(ks[3], (3,)) * 0.1}
    x0 = jax.random.normal(ks[4], (n_streams, 3)) * 0.5
    cfg = select_config(3, 8, s_total=n_streams)

    def unfused():
        traj = chaotic_trajectory(params, x0, n_steps,
                                  backend="pallas_interpret", config=cfg)
        return bits_from_trajectory(traj)

    def fused():
        words, _ = chaotic_bits(params, x0, n_steps,
                                backend="pallas_interpret", config=cfg)
        return words

    n_words = (n_steps // 2) * n_streams
    us_unfused = time_fn(unfused, n_iters=3, warmup=1)
    us_fused = time_fn(fused, n_iters=3, warmup=1)
    res = {
        "config": {"i_dim": 3, "h_dim": 8, "n_streams": n_streams,
                   "n_steps": n_steps, "s_block": cfg.s_block,
                   "t_block": cfg.t_block, "unroll": cfg.unroll,
                   "compute_unit": cfg.compute_unit,
                   "backend": "pallas_interpret"},
        "unfused_words_per_s": n_words / (us_unfused / 1e6),
        "fused_words_per_s": n_words / (us_fused / 1e6),
        "fused_bits_per_s": 32 * n_words / (us_fused / 1e6),
        "speedup": us_unfused / us_fused,
    }
    emit("throughput/prng_unfused_words_per_s", us_unfused,
         f"words_per_s={res['unfused_words_per_s']:.3e}")
    emit("throughput/prng_fused_words_per_s", us_fused,
         f"words_per_s={res['fused_words_per_s']:.3e};"
         f"speedup={res['speedup']:.2f}x")
    if out_json:
        pathlib.Path(out_json).write_text(json.dumps(res, indent=2))
    return res


if __name__ == "__main__":
    run()
    run_fused()
