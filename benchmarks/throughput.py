"""Paper §IV throughput claim: CPU (i7-12700: 100 samples in ~3.5 s via
Python/SciPy) vs the hardware engine (31 us on FPGA).

Here: (a) measured scipy.odeint CPU time for 100 samples (the paper's CPU
baseline), (b) measured jitted-JAX RK-4, (c) measured interpret-mode kernel
(functional check only), and (d) the modeled TPU-engine time from the DSE
cycle model (the deliverable on CPU-only hardware; clearly labeled MODEL)."""
import time

import jax.numpy as jnp
import numpy as np
from scipy.integrate import odeint

from repro.core.ann import AnnConfig, extract_parameters, train
from repro.core.chaotic import get_system, integrate, make_dataset
from repro.core.dse import CLOCK_HZ, Candidate, measure_candidate
from repro.kernels.ops import chaotic_trajectory

from benchmarks.common import emit, time_fn


def run(n_samples: int = 100) -> None:
    sys_ = get_system("chen")

    # (a) paper-style CPU baseline: scipy odeint, one sample at a time
    f = lambda x, t: np.asarray(sys_.f(jnp.asarray(x, jnp.float32)), np.float64)
    t0 = time.perf_counter()
    odeint(f, np.asarray(sys_.x0), np.arange(n_samples + 1) * sys_.dt)
    cpu_scipy_us = (time.perf_counter() - t0) * 1e6
    emit("throughput/cpu_scipy_odeint_100", cpu_scipy_us,
         f"samples={n_samples};paper_cpu_us=3.5e6")

    # (b) jitted JAX RK-4 on CPU
    x0 = jnp.asarray(sys_.x0, jnp.float32)
    us = time_fn(lambda: integrate("chen", x0, n_samples))
    emit("throughput/cpu_jax_rk4_100", us, f"samples={n_samples}")

    # (c) trained ANN engine, interpret-mode kernel (functional timing only)
    ds = make_dataset("chen", n_samples=20_000)
    params, _ = train(AnnConfig(hidden=8), ds, epochs=120, lr=3e-3)
    p = {k: jnp.asarray(v) for k, v in extract_parameters(params).items()}
    x0s = jnp.zeros((128, 3), jnp.float32) + 0.1
    us = time_fn(lambda: chaotic_trajectory(p, x0s, n_samples,
                                            backend="pallas_interpret",
                                            s_block=128, t_block=max(4, n_samples // 4) // 4 * 4))
    emit("throughput/kernel_interpret_100x128", us,
         "note=interpret-mode-functional-not-perf")

    # (d) modeled TPU v5e engine time (DSE cycle model, clearly a MODEL)
    for pl in (0, 3, 5):
        c = Candidate(i_dim=3, h_dim=8, p=pl)
        m = measure_candidate(c)
        t_us = n_samples * m["cycles_per_step"] / CLOCK_HZ * 1e6
        thr = m["samples_per_sec"]
        emit(f"throughput/tpu_model_P{pl}_100steps", t_us,
             f"streams={c.s_block};samples_per_s={thr:.3e};"
             f"speedup_vs_scipy={cpu_scipy_us / t_us:.0f}x;source=cycle-model")


if __name__ == "__main__":
    run()
