"""HENNC quickstart — the paper's full flow in one script:

  1. software phase: generate Chen-system dataset (RK-4), train the 3-8-3
     ANN, report Table-II metrics;
  2. hardware phase: design-space exploration with the Eq.8/9 estimators,
     pick the three user options (min-latency / lowest-cost / Pareto-P);
  3. code generation: emit the selected core + testbench, run the testbench;
  4. use the core as a PRNG and run the NIST SP 800-22 subset.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import subprocess
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.core.ann import AnnConfig, extract_parameters, train
from repro.core.chaotic import make_dataset
from repro.core.codegen import generate_core
from repro.core.dse import CostModel, LatencyModel, pareto_front, \
    enumerate_candidates, select
from repro.prng import run_nist_subset
from repro.prng.stream import ChaoticStream


def main():
    print("=== 1. software phase: train the oscillator ANN (Chen) ===")
    ds = make_dataset("chen", n_samples=50_000)
    cfg = AnnConfig(dim=3, hidden=8, activation="relu")
    params, hist = train(cfg, ds, epochs=200, lr=3e-3, verbose=True)
    m = hist["test_metrics"]
    print(f"  metrics: MSE={m['mse']:.2e} MAE={m['mae']:.4f} "
          f"RMSE={m['rmse']:.4f} R2={m['r2']:.6f}")
    print(f"  (paper Table II, ReLU: MSE=3.1e-4, R2=0.99999)")

    print("\n=== 2. hardware phase: design space exploration ===")
    lm, cm = LatencyModel.fit(), CostModel.fit()
    cands = enumerate_candidates(3, 8)
    front = pareto_front(cands, lm, cm)
    print(f"  {len(cands)} candidates, Pareto front:")
    for c, cost, lat in front:
        print(f"    P={c.p} {c.compute_unit}/{c.dtype_name}: "
              f"{cost / 1024:.0f} KiB VMEM, {lat:.4f} cyc/stream-sample")
    fast = select(3, 8, "min_latency", latency_model=lm, cost_model=cm)
    cheap = select(3, 8, "lowest_cost", latency_model=lm, cost_model=cm)
    print(f"  min-latency solution: {fast}")
    print(f"  lowest-cost solution: {cheap}")

    print("\n=== 3. generate the hardware core + run its testbench ===")
    out = pathlib.Path("results/generated_cores")
    pkg = generate_core("chen_383_quickstart", out,
                        params=extract_parameters(params), candidate=fast,
                        scale=ds.scale, offset=ds.offset,
                        latency_model=lm, cost_model=cm)
    print(f"  emitted {pkg}")
    r = subprocess.run([sys.executable, str(pkg / "testbench.py")],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": f"src:{out}", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    print("  " + (r.stdout.strip() or r.stderr.strip()[-500:]))
    assert r.returncode == 0, "testbench failed"

    print("\n=== 4. PRNG: NIST SP 800-22 subset on emitted words ===")
    stream = ChaoticStream.from_trained(extract_parameters(params))
    words = np.asarray(stream.bits(40_000))
    for name, res in run_nist_subset(words).items():
        print(f"  {name:22s} p={res['p_value']:.4f} "
              f"{'PASS' if res['passed'] else 'FAIL'}")
    print("\nquickstart complete.")


if __name__ == "__main__":
    main()
