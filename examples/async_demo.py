"""Async serving demo — uncoordinated tenants, coalesced gang launches.

Eight tenant coroutines independently ``await draw(...)`` small requests
against a four-core oscillator farm.  Nobody calls ``flush()``; the
front-end's background flusher coalesces everything that is queued when
either the earliest deadline expires or a full round of demand
accumulates, and fires ONE planner-shaped gang launch for the whole
group.  The demo prints the launch count next to the draw count — the
whole point is the gap between the two — and verifies a tenant's words
against the sync solo path.

Run:  PYTHONPATH=src python examples/async_demo.py
"""
import asyncio
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.core.dse import Candidate  # noqa: E402
from repro.prng.stream import default_params  # noqa: E402
from repro.serve.async_frontend import AsyncOscillatorFarm  # noqa: E402
from repro.serve.farm import OscillatorFarm  # noqa: E402

SYSTEMS = ("lorenz", "chen", "rossler", "chua")     # gang-compatible 3-D
CAND = Candidate(i_dim=3, h_dim=8, p=1, compute_unit="vpu",
                 dtype_bytes=4, unroll=4, t_block=64)
N_TENANTS_PER_CORE = 2
ROUNDS = 3
WORDS = 1024                                        # 8 rows of 128 lanes


def build_farm(gang=True):
    farm = OscillatorFarm(gang=gang)
    for name in SYSTEMS:
        farm.add_core(name, default_params(system=name), config=CAND,
                      lanes_per_client=128, backend="pallas_interpret")
        for j in range(N_TENANTS_PER_CORE):
            farm.register(name, f"tenant{j}", seed=100 + j)
    return farm


async def tenant(af, core, client, log):
    """One tenant: draws in its own loop, never coordinates with anyone."""
    for r in range(ROUNDS):
        words = await af.draw(core, client, WORDS, deadline_ms=10)
        log[(core, client)].append(words)
        print(f"  round {r}: {core:8s}/{client} got {words.size} words "
              f"(head={words[:2]})")


async def main():
    farm = build_farm()
    log = {(c, f"tenant{j}"): []
           for c in SYSTEMS for j in range(N_TENANTS_PER_CORE)}
    n_draws = len(log) * ROUNDS

    # threshold = one full round of demand; 10 ms deadline as backstop
    async with AsyncOscillatorFarm(
            farm, auto_flush_rows=len(SYSTEMS) * WORDS // 128) as af:
        print(f"=== {len(log)} tenants x {ROUNDS} rounds, nobody calls "
              f"flush() ===")
        await asyncio.gather(*(tenant(af, core, client, log)
                               for core, client in log))
        stats = af.deadline_stats()

    print(f"\n{n_draws} draws served in {farm.launches} kernel launches "
          f"({farm.gang_launches} gang-scheduled) — "
          f"{n_draws / farm.launches:.1f} draws amortized per launch")
    print(f"deadline misses: p50={stats['p50_miss_ms']:.2f} ms, "
          f"p99={stats['p99_miss_ms']:.2f} ms over "
          f"{int(stats['served_requests'])} requests")

    # transparency: async-delivered words == the sync gang=False solo path
    solo = build_farm(gang=False)
    core, client = "lorenz", "tenant0"
    mine = np.concatenate(log[(core, client)])
    assert np.array_equal(mine, solo.draw(core, client, mine.size)), \
        "async words diverged from the solo path!"
    print(f"verified: {core}/{client} bit-identical to the sync solo path "
          f"({mine.size} words)")
    print("async demo complete.")


if __name__ == "__main__":
    asyncio.run(main())
