"""Async serving demo — uncoordinated tenants, coalesced gang launches.

Part 1: eight tenant coroutines independently ``await draw(...)`` small
requests against a four-core oscillator farm.  Nobody calls ``flush()``;
the front-end's background flusher coalesces everything that is queued
when either the earliest deadline expires or a full round of demand
accumulates, and fires ONE planner-shaped gang launch for the whole
group.  The demo prints the launch count next to the draw count — the
whole point is the gap between the two — and verifies a tenant's words
against the sync solo path.

Part 2 walks the production serving tier end to end:

* **admission control** — a token-bucket rate limit and a queued-rows
  ceiling reject over-limit submits with a typed ``Overloaded`` carrying
  a ``retry_after_ms`` hint (fail fast, honest backoff);
* **SLO classes** — a ``slo="latency"`` draw forbids the padded launch
  shape on a skewed group, ``slo="bulk"`` forces it; the farm counts the
  decisions its planner was forced into;
* **journaled crash recovery** — every flush appends one small position
  record; the demo "crashes" the serving process mid-stream, rebuilds a
  farm from weights + journal alone, and proves the recovered streams
  continue bit-identically.

Run:  PYTHONPATH=src python examples/async_demo.py
"""
import asyncio
import pathlib
import sys
import tempfile

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.core.dse import Candidate  # noqa: E402
from repro.prng.stream import default_params  # noqa: E402
from repro.serve.admission import (AdmissionController,  # noqa: E402
                                   Overloaded)
from repro.serve.async_frontend import AsyncOscillatorFarm  # noqa: E402
from repro.serve.farm import OscillatorFarm  # noqa: E402
from repro.serve.journal import replay_journal  # noqa: E402

SYSTEMS = ("lorenz", "chen", "rossler", "chua")     # gang-compatible 3-D
CAND = Candidate(i_dim=3, h_dim=8, p=1, compute_unit="vpu",
                 dtype_bytes=4, unroll=4, t_block=64)
N_TENANTS_PER_CORE = 2
ROUNDS = 3
WORDS = 1024                                        # 8 rows of 128 lanes


def build_farm(gang=True, register=True):
    farm = OscillatorFarm(gang=gang)
    for name in SYSTEMS:
        farm.add_core(name, default_params(system=name), config=CAND,
                      lanes_per_client=128, backend="pallas_interpret")
        if register:
            for j in range(N_TENANTS_PER_CORE):
                farm.register(name, f"tenant{j}", seed=100 + j)
    return farm


async def tenant(af, core, client, log):
    """One tenant: draws in its own loop, never coordinates with anyone."""
    for r in range(ROUNDS):
        words = await af.draw(core, client, WORDS, deadline_ms=10)
        log[(core, client)].append(words)
        print(f"  round {r}: {core:8s}/{client} got {words.size} words "
              f"(head={words[:2]})")


async def main():
    farm = build_farm()
    log = {(c, f"tenant{j}"): []
           for c in SYSTEMS for j in range(N_TENANTS_PER_CORE)}
    n_draws = len(log) * ROUNDS

    # threshold = one full round of demand; 10 ms deadline as backstop
    async with AsyncOscillatorFarm(
            farm, auto_flush_rows=len(SYSTEMS) * WORDS // 128) as af:
        print(f"=== {len(log)} tenants x {ROUNDS} rounds, nobody calls "
              f"flush() ===")
        await asyncio.gather(*(tenant(af, core, client, log)
                               for core, client in log))
        stats = af.deadline_stats()

    print(f"\n{n_draws} draws served in {farm.launches} kernel launches "
          f"({farm.gang_launches} gang-scheduled) — "
          f"{n_draws / farm.launches:.1f} draws amortized per launch")
    print(f"deadline misses: p50={stats['p50_miss_ms']:.2f} ms, "
          f"p99={stats['p99_miss_ms']:.2f} ms over "
          f"{int(stats['served_requests'])} requests")

    # transparency: async-delivered words == the sync gang=False solo path
    solo = build_farm(gang=False)
    core, client = "lorenz", "tenant0"
    mine = np.concatenate(log[(core, client)])
    assert np.array_equal(mine, solo.draw(core, client, mine.size)), \
        "async words diverged from the solo path!"
    print(f"verified: {core}/{client} bit-identical to the sync solo path "
          f"({mine.size} words)")

    await production_tier()
    print("async demo complete.")


async def production_tier():
    """Admission + SLO + journaled crash recovery, end to end."""
    print("\n=== production tier: admission, SLO classes, crash "
          "recovery ===")
    tmp = tempfile.mkdtemp(prefix="hennc_demo_")
    jpath = pathlib.Path(tmp) / "farm.journal"

    # -- the serving process (it is about to "crash") ----------------------
    farm = build_farm(register=False)
    admission = AdmissionController(rate_words_per_s=200_000,
                                    burst_words=8_192,
                                    max_queued_rows=256)
    delivered = []
    async with AsyncOscillatorFarm(farm, admission=admission,
                                   journal=jpath) as af:
        # registrations go through the front-end so the journal records
        # each tenant's seed — recovery re-derives the identical stream
        af.register("lorenz", "tenant0", seed=100)
        af.register("chen", "tenant0", seed=100)

        # SLO classes shape the launch, never the words: the latency draw
        # on a skewed group forbids the padded group-max shape
        lat, bulk = await asyncio.gather(
            af.draw("lorenz", "tenant0", 256, deadline_ms=5, slo="latency"),
            af.draw("chen", "tenant0", 4096, deadline_ms=5, slo="bulk"))
        delivered += [("lorenz", lat), ("chen", bulk)]
        print(f"slo demo: latency draw {lat.size} words + bulk draw "
              f"{bulk.size} words; planner decisions {farm.plan_decisions}, "
              f"slo-forced {farm.slo_forced}")

        # admission: a draw past the burst allowance fails FAST with a
        # typed error and an honest backoff hint — it never queues
        try:
            await af.draw("lorenz", "tenant0", 100_000, deadline_ms=5)
        except Overloaded as e:
            print(f"admission: rejected ({e.scope} scope), "
                  f"retry_after_ms={e.retry_after_ms:.1f}")

        delivered.append(("lorenz",
                          await af.draw("lorenz", "tenant0", 300,
                                        deadline_ms=5)))
        print(f"journal: {af.journal.seq} flushes recorded at {jpath}")
        # ... and here the process dies: queued-but-unflushed demand is
        # lost (the tenant retries), everything flushed is recoverable

    # -- the recovered process: weights + journal, no crashed memory ------
    farm2 = build_farm(register=False)
    info = replay_journal(farm2, jpath)
    print(f"recovery: replayed {info['clients']} tenants to flush "
          f"#{info['flushes']} ({info['rows_replayed']} word rows "
          f"recomputed, torn_tail={info['torn_tail']})")

    # the recovered streams CONTINUE bit-identically: a solo farm that
    # served the same pre-crash draws agrees on what comes next
    solo = build_farm(gang=False, register=False)
    solo.register("lorenz", "tenant0", seed=100)
    solo.register("chen", "tenant0", seed=100)
    for core, words in delivered:
        ref = solo.draw(core, "tenant0", words.size)
        assert np.array_equal(words, ref), "pre-crash stream diverged!"
    for core in ("lorenz", "chen"):
        cont = farm2.draw(core, "tenant0", 500)
        ref = solo.draw(core, "tenant0", 500)
        assert np.array_equal(cont, ref), \
            f"{core} stream diverged after recovery!"
        print(f"verified: {core}/tenant0 continues bit-identically "
              f"after crash recovery (500 words)")


if __name__ == "__main__":
    asyncio.run(main())
