"""Design-space exploration flow across ANN sizes (paper Figs. 3 & 5):
sweep all candidate microarchitectures for 3-4-3 / 3-8-3 / 3-16-3, print the
Pareto fronts in both compute-unit modes, and emit generated cores for the
three paper-style user options.

Run:  PYTHONPATH=src python examples/dse_flow.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.core.ann import AnnConfig, extract_parameters, train
from repro.core.chaotic import make_dataset
from repro.core.codegen import generate_core
from repro.core.dse import (CostModel, LatencyModel, enumerate_candidates,
                            pareto_front, select)


def main():
    lm, cm = LatencyModel.fit(), CostModel.fit()
    print("fitted Eq.8 coefficients (b3..b0) per (unit, dtype):")
    for k, v in lm.coeffs.items():
        print(f"  {k}: {[f'{c:.3e}' for c in v]}")

    for h in (4, 8, 16):
        print(f"\n=== 3-{h}-3 design space ===")
        for unit in ("mxu", "vpu"):
            cands = enumerate_candidates(3, h, units=(unit,))
            front = pareto_front(cands, lm, cm)
            label = {"mxu": "MXU (DSP analogue)", "vpu": "VPU (LUT analogue)"}[unit]
            print(f"  {label}: {len(cands)} candidates, "
                  f"front = {[(f'P{c.p}', f'{cost/1024:.0f}KiB', f'{lat:.3f}cyc') for c, cost, lat in front[:5]]}")

    print("\n=== generate the three user options for 3-8-3 ===")
    ds = make_dataset("chen", n_samples=30_000)
    params, _ = train(AnnConfig(hidden=8), ds, epochs=150, lr=3e-3)
    ex = extract_parameters(params)
    out = pathlib.Path("results/generated_cores")
    for mode, p in (("min_latency", None), ("lowest_cost", None), ("pareto", 2)):
        c = select(3, 8, mode, p=p, latency_model=lm, cost_model=cm)
        name = f"chen_383_{mode}" + (f"_p{p}" if p is not None else "")
        pkg = generate_core(name, out, params=ex, candidate=c,
                            scale=ds.scale, offset=ds.offset,
                            latency_model=lm, cost_model=cm)
        print(f"  {mode:12s} -> P={c.p} {c.compute_unit}/{c.dtype_name} "
              f"=> {pkg}")
    print("\ndse_flow complete.")


if __name__ == "__main__":
    main()
