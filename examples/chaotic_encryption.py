"""Image encryption with the chaotic-oscillator PRNG — the paper's
motivating application (§I: countering attacks on image encryption needs a
high-throughput PRNG).

Encrypt a synthetic image by XOR with the chaotic keystream; verify
(a) exact decryption, (b) ciphertext histogram flatness (chi-square),
(c) adjacent-pixel correlation collapse — the standard chaotic-crypto checks.

Run:  PYTHONPATH=src python examples/chaotic_encryption.py
"""
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.prng import default_stream


def make_test_image(n=128):
    """Smooth synthetic image (high adjacent-pixel correlation)."""
    y, x = np.mgrid[0:n, 0:n]
    img = (128 + 60 * np.sin(x / 9.0) * np.cos(y / 13.0)
           + 40 * np.exp(-((x - 64) ** 2 + (y - 64) ** 2) / 800.0))
    return img.astype(np.uint8)


def adjacent_correlation(img):
    a = img[:, :-1].astype(np.float64).ravel()
    b = img[:, 1:].astype(np.float64).ravel()
    return float(np.corrcoef(a, b)[0, 1])


def main():
    img = make_test_image()
    n_bytes = img.size
    print(f"plaintext: {img.shape}, adjacent-pixel corr = "
          f"{adjacent_correlation(img):.4f}")

    stream = default_stream(n_streams=256, seed=7)
    words = np.asarray(stream.bits((n_bytes + 3) // 4))
    keystream = words.view(np.uint8)[:n_bytes].reshape(img.shape)

    cipher = img ^ keystream
    print(f"ciphertext: adjacent-pixel corr = "
          f"{adjacent_correlation(cipher):.4f}")

    # histogram flatness: chi-square over 256 bins
    hist, _ = np.histogram(cipher, bins=256, range=(0, 256))
    expected = n_bytes / 256
    chi2 = float(((hist - expected) ** 2 / expected).sum())
    # 99% critical value for 255 dof ~ 310.5
    print(f"ciphertext histogram chi2 = {chi2:.1f} "
          f"({'flat (<310.5)' if chi2 < 310.5 else 'NOT flat'})")

    # decryption (stream is counter-based: regenerate the same keystream)
    stream2 = default_stream(n_streams=256, seed=7)
    words2 = np.asarray(stream2.bits((n_bytes + 3) // 4))
    keystream2 = words2.view(np.uint8)[:n_bytes].reshape(img.shape)
    recovered = cipher ^ keystream2
    ok = np.array_equal(recovered, img)
    print(f"decryption exact: {ok}")
    assert ok and abs(adjacent_correlation(cipher)) < 0.05 and chi2 < 310.5
    print("encryption demo complete.")


if __name__ == "__main__":
    main()
