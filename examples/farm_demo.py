"""Heterogeneous oscillator farm demo — the full multi-system flow:

  1. train one ANN oscillator per chaotic system (registry-cached, so the
     committed weights under results/weights/ make this instant);
  2. DSE-select a solution per system and emit a core per system
     (``generate_farm``) — including the 4-D hyperchaotic Lorenz;
  3. serve all cores behind one ``OscillatorFarm``: per-core routing,
     with compatible cores GANG-SCHEDULED into one stacked-weight launch
     per flush (the four 3-D cores share a launch; the 4-D hyperchaotic
     core launches alone);
  4. verify farm transparency (standalone service == farmed service) and
     farm-wide snapshot/restore with requests in flight.

Run:  PYTHONPATH=src python examples/farm_demo.py
"""
import json
import pathlib
import sys
import tempfile

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax.numpy as jnp  # noqa: E402

from repro.core.codegen import generate_farm  # noqa: E402
from repro.core.dse import Candidate  # noqa: E402
from repro.serve.farm import OscillatorFarm  # noqa: E402
from repro.serve.prng_service import PRNGService  # noqa: E402


def main():
    out = pathlib.Path(tempfile.mkdtemp(prefix="hennc_farm_"))
    print("=== 1+2. train + DSE + codegen, one core per system ===")
    cores = generate_farm(out, mode="pareto", p=1)
    for name, pkg in cores.items():
        sol = json.loads((pkg / "solution.json").read_text())
        c = sol["candidate"]
        print(f"  {name:12s} I={c['i_dim']} H={c['h_dim']} "
              f"P={c['p']} {c['compute_unit']}/"
              f"{'bf16' if c['dtype_bytes'] == 2 else 'f32'} "
              f"t_block={c['t_block']} unroll={c['unroll']}")

    print("\n=== 3. one farm, gang-scheduled launches ===")
    farm = OscillatorFarm.from_generated(out)
    for core in farm.cores:
        farm.register(core, "alice", seed=11)
        farm.register(core, "bob", seed=22)
    for core in farm.cores:
        farm.request(core, "alice", 1000)
        farm.request(core, "bob", 500)
    served = farm.flush()
    # one stacked launch for the compatible 3-D group + one solo launch
    # for the incompatible 4-D core — not one launch per core
    assert farm.launches == 2, farm.launches
    assert farm.gang_launches == 1
    print(f"  {len(farm.cores)} cores served in {farm.launches} launches "
          f"({farm.gang_launches} gang)")
    for core in sorted(served):
        w = served[core]["alice"]
        ones = np.unpackbits(w.view(np.uint8)).mean()
        print(f"  {core:12s} alice={w.size} bob={served[core]['bob'].size} "
              f"words, monobit={ones:.4f}, head={w[:3]}")

    print("\n=== 4a. farm transparency: standalone == farmed ===")
    sol = json.loads((cores["hyperlorenz"] / "solution.json").read_text())
    cand = Candidate(**sol["candidate"])
    params = dict(np.load(cores["hyperlorenz"] / "weights.npz"))
    solo = PRNGService(params, lanes_per_client=128, config=cand,
                       dtype=jnp.dtype(cand.dtype_name))
    solo.register("alice", seed=11)
    assert np.array_equal(solo.draw("alice", 1000),
                          served["hyperlorenz"]["alice"]), "transparency broken!"
    print("  hyperlorenz/alice: bit-identical standalone vs farmed")

    print("\n=== 4b. snapshot with requests in flight ===")
    farm.request("chen", "bob", 750)            # queued, not yet flushed
    snap = farm.snapshot()
    a = farm.flush()["chen"]["bob"]
    farm2 = OscillatorFarm.from_generated(out)
    farm2.restore(snap)
    b = farm2.flush()["chen"]["bob"]
    assert np.array_equal(a, b), "pending draw lost across snapshot!"
    print(f"  chen/bob: {a.size} queued words survived snapshot/restore")

    print("\n=== 5. demand-shaped gang planning ===")
    # one hot tenant, everyone else cold: the planner shapes the launch to
    # demand (ragged row maps / a split) instead of padding the whole gang
    # to the hot tenant's row count.
    hot, *cold = farm.cores
    farm.request(hot, "alice", 64 * 128)
    for core in cold:
        farm.request(core, "alice", 512)
    farm.flush()
    print(f"  skewed flush decisions so far: {farm.plan_decisions}")

    print(f"\n{len(farm.cores)} cores ({sum(1 for _ in farm.cores)} systems, "
          f"incl. one 4-D hyperchaotic), {farm.launches} launches total "
          f"({farm.gang_launches} gang-scheduled).")
    print("farm demo complete.")


if __name__ == "__main__":
    main()
