"""Streaming chaotic-PRNG service demo — the HENNC engine as a serving
system.

Eight named clients share ONE fused-kernel launch per flush: each owns a
block of lanes on the stream axis, carries its own Weyl word counter, and
the DSE autotuner (paper Eqs. 8-9) picks the kernel microarchitecture.
Shows (1) batched serving, (2) bit-exact determinism across service
instances, (3) snapshot/restore resumability.

Run:  PYTHONPATH=src python examples/prng_service_demo.py
"""
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.prng.stream import default_params
from repro.serve.prng_service import PRNGService

N_CLIENTS = 8


def build_service(params):
    svc = PRNGService(params, lanes_per_client=128)
    for i in range(N_CLIENTS):
        svc.register(f"client{i}", seed=1000 + i)
    return svc


def main():
    print("=== train the Chen oscillator (cached per process) ===")
    params = default_params()

    svc = build_service(params)
    print(f"DSE-selected kernel config: {svc.config}")

    print(f"\n=== {N_CLIENTS} clients, one batched launch ===")
    for i in range(N_CLIENTS):
        svc.request(f"client{i}", 1000 + 100 * i)
    out = svc.flush()
    assert svc.launches == 1
    for name in sorted(out):
        w = out[name]
        ones = np.unpackbits(w.view(np.uint8)).mean()
        print(f"  {name}: {w.size:5d} words in launch #1, "
              f"monobit={ones:.4f}, head={w[:3]}")

    print("\n=== determinism: a fresh service replays identical streams ===")
    svc2 = build_service(params)
    replay = svc2.draw("client3", 1300)
    assert np.array_equal(replay, out["client3"]), "determinism broken!"
    print("  client3 replay: bit-identical")

    print("\n=== resumability: snapshot -> draw -> restore -> draw ===")
    snap = svc.snapshot()
    a = svc.draw("client5", 2000)
    svc3 = PRNGService(params, lanes_per_client=128)
    svc3.restore(snap)
    b = svc3.draw("client5", 2000)
    assert np.array_equal(a, b), "resume broken!"
    print(f"  client5 resumed mid-stream: bit-identical "
          f"({a.size} words, head={a[:3]})")

    print(f"\n{svc.launches + svc2.launches + svc3.launches} total kernel "
          f"launches served {N_CLIENTS + 2} draws for {N_CLIENTS} clients.")
    print("demo complete.")


if __name__ == "__main__":
    main()
