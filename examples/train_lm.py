"""End-to-end LM training driver (example-scale): data pipeline with
chaotic-PRNG shuffling, microbatched train step, checkpoint/resume, straggler
watchdog — the full production loop at CPU-runnable size.

Run:    PYTHONPATH=src python examples/train_lm.py --steps 60
Resume: rerun the same command — it restarts from the latest checkpoint.

``--preset small`` is a ~100M-class config; the default ``tiny`` keeps the
example fast on CPU.  On TPU pods use repro.launch.train instead (same loop,
production mesh + sharding).
"""
import argparse
import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLMDataset
from repro.train.loop import LoopConfig, run
from repro.train.optimizer import Adam, warmup_cosine
from repro.train.train_step import TrainStepConfig, init_train_state, make_train_step

PRESETS = {
    # ~8M params: fast on CPU
    "tiny": ModelConfig(name="tiny_lm", n_layers=4, d_model=256, n_heads=4,
                        n_kv_heads=2, d_ff=1024, vocab_size=4096,
                        remat=False, dtype="float32"),
    # ~100M params (llama3-family shape, the e2e-driver scale)
    "small": ModelConfig(name="small_lm", n_layers=12, d_model=768, n_heads=12,
                         n_kv_heads=4, d_ff=2048, vocab_size=32000,
                         remat=True, dtype="bfloat16"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="results/train_lm_ckpt")
    ap.add_argument("--chaotic-shuffle", action="store_true", default=True)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"[train_lm] {cfg.name}: {cfg.n_params() / 1e6:.1f}M params")

    opt = Adam(lr=warmup_cosine(3e-4, 20, args.steps), clip_norm=1.0,
               weight_decay=0.01)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        cfg, opt, TrainStepConfig(num_microbatches=args.microbatches)))

    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq,
                            global_batch=args.batch, seed=0,
                            use_chaotic_shuffle=args.chaotic_shuffle)
    batch_at = lambda i: {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}

    res = run(state, step, batch_at,
              LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=20, log_every=5))
    first = res.metrics_history[0]["loss"] if res.metrics_history else float("nan")
    last = res.metrics_history[-1]["loss"] if res.metrics_history else float("nan")
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} "
          f"(resumed_from={res.resumed_from}, stragglers={len(res.straggler_steps)})")
    assert last < first, "loss did not decrease"
    print("[train_lm] complete.")


if __name__ == "__main__":
    main()
