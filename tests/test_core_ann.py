"""ANN oscillator training (paper §III-A, Table II)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ann import (ACTIVATIONS, AnnConfig, apply, extract_parameters,
                            init_params, iterate, one_step_reference,
                            regression_metrics, train)
from repro.core.chaotic import make_dataset


@pytest.fixture(scope="module")
def chen_ds():
    return make_dataset("chen", n_samples=20_000, seed=0)


def test_apply_shapes():
    cfg = AnnConfig(hidden=8)
    p = init_params(cfg, jax.random.PRNGKey(0))
    y = apply(cfg, p, jnp.zeros((5, 3)))
    assert y.shape == (5, 3)


def test_training_reaches_paper_quality(chen_ds):
    """Table II (ReLU): MSE 3.1e-4, R² 0.99999.  We require at least that
    MSE band and R² >= 0.999 on held-out data."""
    cfg = AnnConfig(hidden=8, activation="relu")
    params, hist = train(cfg, chen_ds, epochs=200, lr=3e-3, seed=0)
    m = hist["test_metrics"]
    assert m["mse"] <= 3.1e-4, m
    assert m["r2"] >= 0.999, m


def test_activation_ordering(chen_ds):
    """Paper Table II ordering: ReLU < Tanh < Sigmoid in MSE."""
    res = {}
    for act in ("relu", "tanh", "sigmoid"):
        cfg = AnnConfig(hidden=8, activation=act)
        _, hist = train(cfg, chen_ds, epochs=60, lr=3e-3, seed=0)
        res[act] = hist["test_metrics"]["mse"]
    assert res["relu"] < res["sigmoid"], res
    assert res["tanh"] < res["sigmoid"], res


def test_target_mse_early_stop(chen_ds):
    cfg = AnnConfig(hidden=16)
    params, hist = train(cfg, chen_ds, epochs=500, lr=3e-3, target_mse=1e-3)
    assert len(hist["train_loss"]) < 500  # stopped early


def test_metrics_definitions():
    pred = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    tgt = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    m = regression_metrics(pred, tgt)
    assert m["mse"] == 0.0 and m["r2"] == 1.0
    m2 = regression_metrics(pred + 1.0, tgt)
    assert abs(m2["mse"] - 1.0) < 1e-6 and abs(m2["mae"] - 1.0) < 1e-6
    assert abs(m2["rmse"] - 1.0) < 1e-6


def test_iterate_is_autonomous_feedback(chen_ds):
    cfg = AnnConfig(hidden=8)
    p = init_params(cfg, jax.random.PRNGKey(1))
    x0 = jnp.zeros((4, 3))
    traj = iterate(cfg, p, x0, 5)
    # step i+1 equals apply(step i)
    np.testing.assert_allclose(np.asarray(traj[1]),
                               np.asarray(apply(cfg, p, traj[0])), rtol=1e-6)


def test_one_step_reference_matches_training_targets(chen_ds):
    x = jnp.asarray(chen_ds.x_test[:64])
    y = one_step_reference("chen", chen_ds, x)
    np.testing.assert_allclose(np.asarray(y), chen_ds.y_test[:64], atol=2e-5)


def test_extract_parameters_roundtrip():
    cfg = AnnConfig(hidden=4)
    p = init_params(cfg, jax.random.PRNGKey(0))
    ex = extract_parameters(p)
    assert set(ex) == {"w1", "b1", "w2", "b2"}
    assert all(isinstance(v, np.ndarray) and v.dtype == np.float32
               for v in ex.values())


def test_trained_oscillator_stays_on_attractor(chen_ds):
    """Closed-loop stability: 2k autonomous steps remain bounded (the PRNG
    use case requires a non-diverging, non-collapsing oscillator)."""
    cfg = AnnConfig(hidden=8)
    params, _ = train(cfg, chen_ds, epochs=150, lr=3e-3)
    x0 = jnp.asarray(chen_ds.x_test[:16])
    traj = iterate(cfg, params, x0, 2000)
    assert bool(jnp.all(jnp.isfinite(traj)))
    assert float(jnp.max(jnp.abs(traj))) < 5.0
    # non-collapse: variance over time stays meaningful
    assert float(jnp.std(traj[-500:])) > 0.05
