"""Golden regression: regenerating the checked-in quickstart core must
reproduce its source byte-for-byte.

Pins three things at once: the codegen templates, the Candidate field
surface they consume, and the DSE min-latency selection for the paper's
3-8-3 Chen network.  SCALE/OFFSET are dataset statistics (inputs to
codegen, float-sensitive across jax versions), so they are read back out
of the golden file rather than recomputed.
"""
import pathlib
import re

import numpy as np
import pytest

from repro.core.codegen import generate_core
from repro.core.dse import Candidate, select

GOLDEN = pathlib.Path(__file__).parent.parent / "results" / "generated_cores" \
    / "chen_383_quickstart"


def _golden_scale_offset():
    text = (GOLDEN / "__init__.py").read_text()
    vals = {}
    for name in ("SCALE", "OFFSET"):
        m = re.search(rf"^{name} = np\.asarray\(\[(.*?)\]", text, re.M)
        assert m, f"{name} not found in golden core"
        vals[name] = [float(x) for x in re.findall(r"\(([-0-9.e+]+)\)", m.group(1))]
    return vals["SCALE"], vals["OFFSET"]


@pytest.fixture(scope="module")
def regenerated(tmp_path_factory):
    scale, offset = _golden_scale_offset()
    cand = select(3, 8, "min_latency")
    dummy = {"w1": np.zeros((3, 8), np.float32), "b1": np.zeros(8, np.float32),
             "w2": np.zeros((8, 3), np.float32), "b2": np.zeros(3, np.float32)}
    return generate_core("chen_383_quickstart",
                         tmp_path_factory.mktemp("golden"),
                         params=dummy, candidate=cand,
                         scale=scale, offset=offset)


def test_min_latency_selection_is_stable():
    """The quickstart solution the DSE hands out (P=5, vpu, bf16; the
    (t_block, unroll) tie broken by the shared overhead score, so it
    matches what ``select_config`` autotunes for the same point)."""
    cand = select(3, 8, "min_latency")
    assert cand == Candidate(i_dim=3, h_dim=8, p=5, compute_unit="vpu",
                             dtype_bytes=2, unroll=8, t_block=256)


@pytest.mark.parametrize("fname", ["__init__.py", "testbench.py"])
def test_generated_source_matches_golden(regenerated, fname):
    golden = (GOLDEN / fname).read_text()
    assert (regenerated / fname).read_text() == golden


def test_generated_artifacts_complete(regenerated):
    assert (regenerated / "weights.npz").exists()
    assert (regenerated / "solution.json").exists()
