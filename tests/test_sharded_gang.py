"""Device-sharded gang launches: one logical farm across every chip.

The sharded gang contract is the single-device gang contract, lifted:
partitioning a gang launch's lane blocks across a mesh axis must change
NOTHING about the words — per lane, the sharded launch is bit-identical
to the single-device gang kernel AND to a solo per-core launch, at every
device count, in both layouts (ragged lane-block gang and sublane
stack), at both widths (f32 and bf16), under ragged demand.  Streams are
therefore device-count-invariant: a snapshot taken sharded restores onto
an unsharded farm (and vice versa) and continues bit-exactly — but only
through the explicit ``on_topology_mismatch="replan"`` path, because
cached plans are NOT topology-invariant.

Multi-device tests force host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI sharded
leg); on a plain 1-device run they skip and the always-on tests below
still cover the mesh-of-one and topology-mismatch seams.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.kernels import ops
from repro.serve.farm import OscillatorFarm, _compat_key, _topology

from test_gang import CAND, _params, _stacked
from test_kernels import _mk

N_DEV = jax.device_count()
DEVICE_COUNTS = (2, 4, 8)


def _mesh(n_dev):
    if N_DEV < n_dev:
        pytest.skip(
            f"needs {n_dev} host devices, have {N_DEV} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_dev}")
    return Mesh(np.array(jax.devices()[:n_dev]), ("data",))


# ---------------------------------------------------------------------------
# Kernel level: ops routing with a mesh == ops routing without one
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sharded_gang_bits_matches_unsharded(n_dev, dtype):
    """Ragged lane-block gang across a mesh: words and final state are
    bit-identical to the 1-device gang kernel, including when the block
    count does not divide the device count (dead-block padding) and
    under a demand-shaped row_map."""
    mesh = _mesh(n_dev)
    s_block, n_steps = 128, 64
    plist = [_params(key=k) for k in range(3)]
    # 6 blocks: divides 2, not 4, not 8 — exercises gang_partition_maps
    core_map = np.asarray([0, 2, 1, 2, 0, 1], np.int32)
    s_total = len(core_map) * s_block
    _, _, _, _, x0 = _mk(3, 8, s_total, key=9)
    x0 = x0.astype(dtype)
    rng = np.random.default_rng(3)
    offs = jnp.asarray(rng.integers(0, 10_000, size=s_total), np.uint32)
    row_map = np.asarray([32, 7, 0, 32, 13, 21], np.int32)

    kw = dict(backend="pallas_interpret", s_block=s_block, t_block=32,
              unroll=2)
    for rmap in (None, row_map):
        ref_w, ref_s = ops.chaotic_bits_gang(
            _stacked(plist), x0, n_steps, offs, core_map=core_map,
            row_map=rmap, **kw)
        got_w, got_s = ops.chaotic_bits_gang(
            _stacked(plist), x0, n_steps, offs, core_map=core_map,
            row_map=rmap, mesh=mesh, **kw)
        eff = (ops.gang_effective_rows(rmap, n_steps, 32, 2)
               if rmap is not None
               else np.full(len(core_map), n_steps // 2, np.int32))
        for g in range(len(core_map)):
            sl = slice(g * s_block, (g + 1) * s_block)
            r = int(eff[g])     # rows past a block's demand are garbage
            np.testing.assert_array_equal(np.asarray(got_w)[:r, sl],
                                          np.asarray(ref_w)[:r, sl])
            np.testing.assert_array_equal(
                np.asarray(jnp.asarray(got_s[sl], jnp.float32)),
                np.asarray(jnp.asarray(ref_s[sl], jnp.float32)))


@pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sharded_stacked_matches_unsharded_and_per_core(n_dev, dtype):
    """Sublane-stacked gang sharded on the STREAM axis: bit-identical to
    the 1-device stacked kernel and to solo per-core launches, under a
    ragged per-core row_map."""
    mesh = _mesh(n_dev)
    C, S, n_steps = 3, 1024, 64       # S divides every forced n_dev
    plist = [_params(key=k) for k in range(C)]
    _, _, _, _, x0 = _mk(3, 8, C * S, key=6)
    x0 = x0.reshape(C, S, 3).astype(dtype)
    rng = np.random.default_rng(8)
    offs = jnp.asarray(rng.integers(0, 10_000, size=(C, S)), np.uint32)
    row_map = np.asarray([32, 11, 0], np.int32)

    kw = dict(backend="pallas_interpret", s_block=128, t_block=32, unroll=2)
    ref_w, ref_s = ops.chaotic_bits_gang_stacked(
        _stacked(plist), x0, n_steps, offs, row_map=row_map, **kw)
    got_w, got_s = ops.chaotic_bits_gang_stacked(
        _stacked(plist), x0, n_steps, offs, row_map=row_map, mesh=mesh,
        **kw)
    for c in range(C):
        r = int(row_map[c])
        np.testing.assert_array_equal(np.asarray(got_w)[:r, c],
                                      np.asarray(ref_w)[:r, c])
        np.testing.assert_array_equal(
            np.asarray(jnp.asarray(got_s[c], jnp.float32)),
            np.asarray(jnp.asarray(ref_s[c], jnp.float32)))
        if r:   # per-core solo identity on the demanded prefix
            w, _ = ops.chaotic_bits(plist[c], x0[c], 2 * r, offs[c], **kw)
            np.testing.assert_array_equal(np.asarray(got_w)[:r, c],
                                          np.asarray(w))


# ---------------------------------------------------------------------------
# Farm level: sharded flushes == unsharded flushes == solo streams
# ---------------------------------------------------------------------------

def _mk_farm(mesh, *, gang=True, n_cores=3, dtype=None, seed_base=11):
    farm = OscillatorFarm(gang=gang, planner=gang)
    for i in range(n_cores):
        farm.add_core(f"c{i}", _params(key=30 + i), config=CAND,
                      dtype=dtype, lanes_per_client=128,
                      backend="pallas_interpret", mesh=mesh)
        farm.register(f"c{i}", "t", seed=seed_base + i)
    return farm


@pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
@pytest.mark.parametrize("dtype", [None, jnp.bfloat16])
def test_farm_flush_bit_identical_across_topologies(n_dev, dtype):
    """The whole serving path on a mesh: skewed demand (ragged/split
    planner choices) then equal demand (stacked-eligible) both deliver
    words bit-identical to an unsharded gang farm AND to a gang-less
    solo farm, and the meshed cores share one compat group."""
    mesh = _mesh(n_dev)
    farms = [_mk_farm(None, gang=False, dtype=dtype),
             _mk_farm(None, dtype=dtype),
             _mk_farm(mesh, dtype=dtype)]
    meshed = farms[2]
    assert len({_compat_key(s) for s in meshed.services.values()}) == 1

    for demand in ({"c0": 4096, "c1": 512, "c2": 512},       # skewed
                   {"c0": 1024, "c1": 1024, "c2": 1024}):    # equal
        outs = []
        for f in farms:
            for core, n in demand.items():
                f.request(core, "t", n)
            outs.append(f.flush())
        for core in demand:
            np.testing.assert_array_equal(outs[2][core]["t"],
                                          outs[1][core]["t"])
            np.testing.assert_array_equal(outs[2][core]["t"],
                                          outs[0][core]["t"])
    # it actually ganged on the mesh (no silent solo fallback)
    assert meshed.gang_launches > 0


@pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
def test_snapshot_round_trips_across_topologies(n_dev):
    """Snapshot sharded -> restore unsharded and vice versa: default
    refuses (stale plans are topology-bound); ``replan`` continues every
    stream bit-exactly because words are device-count-invariant."""
    mesh = _mesh(n_dev)
    sharded, flat = _mk_farm(mesh), _mk_farm(None)
    for f in (sharded, flat):
        f.request("c0", "t", 700)
        f.request("c1", "t", 300)
        f.flush()

    for snap_src, dst_mesh in ((sharded, None), (flat, mesh)):
        snap = snap_src.snapshot()
        dst = _mk_farm(dst_mesh)
        with pytest.raises(ValueError, match="topology"):
            dst.restore(snap)
        dst.restore(snap, on_topology_mismatch="replan")
        for f in (snap_src, dst):
            f.request("c0", "t", 777)
            f.request("c2", "t", 130)
        a, b = snap_src.flush(), dst.flush()
        np.testing.assert_array_equal(a["c0"]["t"], b["c0"]["t"])
        np.testing.assert_array_equal(a["c2"]["t"], b["c2"]["t"])


# ---------------------------------------------------------------------------
# Always-on seams (no forced devices needed)
# ---------------------------------------------------------------------------

def test_mesh_of_one_routes_to_unsharded_kernels():
    """A 1-device mesh is a real topology for the compat key but must
    route to the plain gang kernels (no shard_map overhead) — words
    bit-identical to mesh=None."""
    mesh1 = Mesh(np.array(jax.devices()[:1]), ("data",))
    flat, meshed = _mk_farm(None), _mk_farm(mesh1)
    assert _topology(meshed.services["c0"]) == ("data", 1, (0,))
    assert _topology(flat.services["c0"]) is None
    for f in (flat, meshed):
        f.request("c0", "t", 500)
        f.request("c1", "t", 200)
    a, b = flat.flush(), meshed.flush()
    np.testing.assert_array_equal(a["c0"]["t"], b["c0"]["t"])
    np.testing.assert_array_equal(a["c1"]["t"], b["c1"]["t"])


def test_mesh_of_one_topology_mismatch_still_refused():
    """Even a 1-device mesh differs from no mesh in the compat key and
    snapshot topology: restore across that boundary refuses by default
    and names the changed cores."""
    mesh1 = Mesh(np.array(jax.devices()[:1]), ("data",))
    meshed, flat = _mk_farm(mesh1), _mk_farm(None)
    meshed.request("c0", "t", 300)
    meshed.flush()
    snap = meshed.snapshot()
    with pytest.raises(ValueError) as ei:
        flat.restore(snap)
    assert "topology" in str(ei.value) and "c0" in str(ei.value)
    flat.restore(snap, on_topology_mismatch="replan")
    for f in (meshed, flat):
        f.request("c0", "t", 256)
    np.testing.assert_array_equal(meshed.flush()["c0"]["t"],
                                  flat.flush()["c0"]["t"])
