"""Sharding planner + mesh + multi-device correctness (subprocess for the
multi-device parts, so the main test process keeps 1 CPU device)."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.registry import ARCH_IDS, get_config
from repro.distributed.sharding import (MeshSpec, plan_batch,
                                        plan_decode_state, plan_params)
from repro.models import transformer as tf


def _fake_mesh(shape=(16, 16), axes=("data", "model")):
    """An abstract mesh over fake devices — enough for planning logic."""
    devs = np.empty(shape, dtype=object)
    it = np.nditer(devs, flags=["multi_index", "refs_ok"])
    class FakeDev:  # minimal device stand-in
        def __init__(self, i): self.id = i
    i = 0
    for _ in it:
        devs[it.multi_index] = FakeDev(i)
        i += 1
    return Mesh(devs, axes)


@pytest.fixture(scope="module")
def mesh_spec():
    return MeshSpec.from_mesh(_fake_mesh())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_planner_divisibility_all_archs(arch, mesh_spec):
    """Every planned axis size must divide the dim it shards — the property
    that makes the 40-cell dry-run compile."""
    cfg = get_config(arch)
    params_shape = jax.eval_shape(lambda: tf.init(cfg, jax.random.PRNGKey(0)))
    specs = plan_params(params_shape, mesh_spec, n_layers_hint=cfg.n_layers)

    mesh_shape = dict(zip(("data", "model"), (16, 16)))
    checked = 0
    for leaf, spec in zip(jax.tree.leaves(params_shape),
                          jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))):
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * (len(leaf.shape) - len(spec))):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([mesh_shape[a] for a in axes]))
            assert dim % size == 0, (arch, leaf.shape, spec)
            checked += 1
    assert checked > 0


def test_planner_megatron_conventions(mesh_spec):
    cfg = get_config("qwen2_72b")
    params_shape = jax.eval_shape(lambda: tf.init(cfg, jax.random.PRNGKey(0)))
    specs = plan_params(params_shape, mesh_spec, n_layers_hint=cfg.n_layers)
    attn = specs["blocks"]["attn"]
    # column-parallel qkv: model on last dim; row-parallel wo: model on dim 1
    assert attn["wq"][-1] == "model" and attn["wo"][1] == "model"
    mlp = specs["blocks"]["ffn"]
    assert mlp["wi"][-1] == "model" and mlp["wo"][1] == "model"
    # FSDP: data axis appears on the other big dim
    assert "data" in str(attn["wq"]) and "data" in str(mlp["wi"])


def test_planner_llama_heads_not_sharded(mesh_spec):
    """llama3.2 has 24 q heads (16 does not divide 24) — the planner must
    shard the flattened 3072 qkv dim instead, never a heads dim."""
    cfg = get_config("llama3_2_3b")
    params_shape = jax.eval_shape(lambda: tf.init(cfg, jax.random.PRNGKey(0)))
    specs = plan_params(params_shape, mesh_spec, n_layers_hint=cfg.n_layers)
    wq = specs["blocks"]["attn"]["wq"]
    assert wq[-1] == "model"   # 24*128 = 3072 divisible by 16


def test_planner_moe_expert_parallel(mesh_spec):
    # qwen3: 128 experts / 16 = 8 per shard -> expert dim sharded over data
    cfg = get_config("qwen3_moe_30b_a3b")
    ps = jax.eval_shape(lambda: tf.init(cfg, jax.random.PRNGKey(0)))
    specs = plan_params(ps, mesh_spec, n_layers_hint=cfg.n_layers)
    wi = specs["blocks"]["moe"]["wi"]        # (L, E, D, F)
    assert wi[1] == "data" and wi[-1] == "model"
    # mixtral: 8 experts not divisible by 16 -> replicated expert dim
    cfg = get_config("mixtral_8x7b")
    ps = jax.eval_shape(lambda: tf.init(cfg, jax.random.PRNGKey(0)))
    specs = plan_params(ps, mesh_spec, n_layers_hint=cfg.n_layers)
    wi = specs["blocks"]["moe"]["wi"]
    assert wi[1] is None and wi[-1] == "model"


def test_plan_batch_and_state(mesh_spec):
    import jax.numpy as jnp
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    bs = plan_batch(batch, mesh_spec)
    assert bs["tokens"][0] == "data"
    # batch=1 (long_500k): replicated
    bs1 = plan_batch({"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}, mesh_spec)
    assert bs1["tokens"] == P()

    cfg = get_config("qwen2_72b")
    st = jax.eval_shape(lambda: tf.init_decode_state(cfg, 128, 1024))
    ss = plan_decode_state(st, mesh_spec, n_layers_hint=cfg.n_layers)
    kv = ss["layers"]["k"]                   # (L, B, S, KV=8, HD=128)
    assert kv[1] == "data"
    assert kv[-1] == "model"                 # kv=8 can't shard; hd=128 can


def test_multipod_mesh_axes(mesh_spec):
    spec3 = MeshSpec.from_mesh(_fake_mesh((2, 16, 16), ("pod", "data", "model")))
    assert spec3.dp_axes == ("pod", "data")
    assert spec3.dp_size == 32
    assert spec3.tp_size == 16
    # dim 256 shards over pod+data jointly; dim 16 over data only
    assert spec3.dp_spec_for(256) == ("pod", "data")
    assert spec3.dp_spec_for(16) == ("data",)
    assert spec3.dp_spec_for(7) is None


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.registry import get_smoke_config
    from repro.distributed.sharding import (MeshSpec, make_shard_fn, named,
                                            plan_batch, plan_params)
    from repro.launch.mesh import make_debug_mesh
    from repro.models import transformer as tf
    from repro.train.optimizer import Adam
    from repro.train.train_step import (TrainStepConfig, init_train_state,
                                        make_train_step)

    cfg = get_smoke_config("llama3_2_3b")
    mesh = make_debug_mesh(2, 2)
    spec = MeshSpec.from_mesh(mesh)
    opt = Adam(lr=1e-3)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # single-device reference
    step0 = jax.jit(make_train_step(cfg, opt, TrainStepConfig()))
    ref_state, ref_m = step0(state, batch)

    # sharded run on 2x2 mesh
    shard_fn = make_shard_fn(spec)
    step = make_train_step(cfg, opt, TrainStepConfig(), shard_fn=shard_fn)
    with mesh:
        pspec = plan_params(jax.eval_shape(lambda: state.params), spec,
                            n_layers_hint=cfg.n_layers)
        bspec = plan_batch(batch, spec)
        sh_state = state._replace(
            params=jax.device_put(state.params, named(spec, pspec)),
            opt=state.opt._replace(
                mu=jax.device_put(state.opt.mu, named(spec, pspec)),
                nu=jax.device_put(state.opt.nu, named(spec, pspec))))
        sh_batch = jax.device_put(batch, named(spec, bspec))
        new_state, m = jax.jit(step)(sh_state, sh_batch)

    a = float(ref_m["loss"]); b = float(m["loss"])
    assert abs(a - b) / abs(a) < 1e-3, (a, b)
    d = jax.tree.map(lambda x, y: float(jnp.max(jnp.abs(
        x.astype(jnp.float32) - y.astype(jnp.float32)))),
        ref_state.params, new_state.params)
    md = max(jax.tree.leaves(d))
    assert md < 5e-2, md
    print("MULTIDEV OK", a, b, md)
""")


def test_sharded_train_step_matches_single_device():
    """2x2-mesh sharded train step == single-device step (numerics)."""
    r = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu", "HOME": "/root"})
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "MULTIDEV OK" in r.stdout
