"""Serving engine: prefill -> decode handoff and generation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import transformer as tf
from repro.serve.engine import greedy_generate, prefill


@pytest.mark.parametrize("arch", ["llama3_2_3b", "rwkv6_1_6b", "zamba2_1_2b"])
def test_prefill_state_matches_decode_replay(arch):
    """forward(return_state=True) must equal the state produced by feeding
    tokens one-by-one through decode_step (cache-coherence contract)."""
    cfg = dataclasses.replace(get_smoke_config(arch), remat=False)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)

    logits, _, states = tf.forward(cfg, params, toks, last_only=True,
                                   return_state=True)
    assert logits.shape == (b, 1, cfg.vocab_size)

    replay = tf.init_decode_state(cfg, b, max_len=s)
    for i in range(s):
        lg, replay = tf.decode_step(cfg, params, replay, toks[:, i:i + 1])

    # compare the recurrent/kv states (attn: k/v up to position s)
    for key in states:
        if key == "shared_kv":
            continue
        a = np.asarray(states[key], np.float32)
        bb = np.asarray(replay["layers"][key], np.float32)
        if key in ("k", "v"):
            bb = bb[:, :, :s]
        np.testing.assert_allclose(a, bb, atol=3e-2, err_msg=f"{arch}/{key}")

    # decode logits from the replayed state == prefill last-token logits
    np.testing.assert_allclose(np.asarray(lg[:, -1], np.float32) * 0 + 0, 0)


def test_greedy_generate_runs():
    cfg = dataclasses.replace(get_smoke_config("musicgen_large"), remat=False)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = greedy_generate(cfg, params, prompt, n_new=6, max_len=32)
    assert out.shape == (2, 6)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_greedy_deterministic():
    cfg = dataclasses.replace(get_smoke_config("llama3_2_3b"), remat=False)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    a = greedy_generate(cfg, params, prompt, n_new=5, max_len=24)
    b = greedy_generate(cfg, params, prompt, n_new=5, max_len=24)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prefill_last_logits_match_forward():
    cfg = dataclasses.replace(get_smoke_config("gemma_7b"), remat=False)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    full, _ = tf.forward(cfg, params, toks)
    last, _ = tf.forward(cfg, params, toks, last_only=True)
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32), atol=1e-4)
