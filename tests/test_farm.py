"""Heterogeneous oscillator farm: train -> DSE -> codegen -> serve.

Covers the farm acceptance surface: ``generate_farm`` emits a runnable
core per system (testbenches pass, including the 4-D hyperchaotic one),
generated cores draw through the fused ``ops.chaotic_bits`` path
bit-identically to the serving stack, and ``OscillatorFarm`` routing is
transparent (a client's words are identical standalone vs farmed).
"""
import json
import os
import pathlib
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.chaotic import SYSTEMS, get_system
from repro.core.dse import Candidate
from repro.prng.stream import _lineage_counter, _round_rows, _splitmix_seeds
from repro.serve.farm import OscillatorFarm
from repro.serve.prng_service import PRNGService

FARM_SYSTEMS = ("chen", "lorenz", "rossler", "chua", "hyperlorenz")


@pytest.fixture(scope="module")
def farm_dir(tmp_path_factory):
    """One farm generation shared by every test in this module (P=1)."""
    from repro.core.codegen import generate_farm
    out = tmp_path_factory.mktemp("farm")
    cores = generate_farm(out, systems=FARM_SYSTEMS, mode="pareto", p=1)
    assert set(cores) == set(FARM_SYSTEMS)
    return out


def _load_solution(farm_dir, name):
    sol = json.loads((farm_dir / name / "solution.json").read_text())
    return Candidate(**sol["candidate"]), dict(np.load(farm_dir / name / "weights.npz"))


def test_farm_emits_one_core_per_system(farm_dir):
    assert len(FARM_SYSTEMS) >= 4
    for name in FARM_SYSTEMS:
        pkg = farm_dir / name
        for f in ("__init__.py", "testbench.py", "weights.npz", "solution.json"):
            assert (pkg / f).exists(), (name, f)
        cand, params = _load_solution(farm_dir, name)
        dim = get_system(name).dim
        assert cand.i_dim == dim
        assert params["w1"].shape[0] == dim
    # the farm genuinely contains an I=4 design point
    assert _load_solution(farm_dir, "hyperlorenz")[0].i_dim == 4


@pytest.mark.parametrize("name", FARM_SYSTEMS)
def test_farm_testbenches_pass(farm_dir, name):
    """Every emitted core's co-simulation testbench passes stand-alone."""
    env = dict(os.environ)
    env["PYTHONPATH"] = f"src:{farm_dir}:" + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, str(farm_dir / name / "testbench.py")],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, (name, r.stderr[-2000:])
    assert "TESTBENCH PASS" in r.stdout


def test_generated_core_bits_match_service(farm_dir):
    """A generated core's fused draw reproduces the serving stack bit for
    bit: seeding, burn-in, and word emission all go through the same
    ``ops.chaotic_bits`` launch."""
    sys.path.insert(0, str(farm_dir))
    try:
        import hyperlorenz as core
        cand, params = _load_solution(farm_dir, "hyperlorenz")
        L, seed, n_words = 128, 77, 700
        svc = PRNGService(params, lanes_per_client=L,
                          backend="pallas_interpret", config=cand,
                          dtype=jnp.dtype(cand.dtype_name))
        svc.register("alice", seed=seed)
        served = svc.draw("alice", n_words)

        # replay through the generated core: same splitmix seeding, same
        # dedicated burn-in launch, same offset-threaded fused draw
        x = _splitmix_seeds(jnp.asarray(_lineage_counter(seed, ()), jnp.uint32),
                            L, core.I_DIM).astype(core.DTYPE)
        _, x = core.generate_bits(x, svc.burn_in, 0, backend="pallas_interpret")
        n_rows = _round_rows(-(-n_words // L), cand.t_block)
        words, _ = core.generate_bits(x, 2 * n_rows, 0,
                                      backend="pallas_interpret")
        np.testing.assert_array_equal(
            np.asarray(words).reshape(-1)[:n_words], served)
    finally:
        sys.path.remove(str(farm_dir))


@pytest.mark.parametrize("name", ["chen", "hyperlorenz"])
def test_farm_client_matches_standalone_service(farm_dir, name):
    """Per system: identical words served standalone vs through the farm."""
    farm = OscillatorFarm.from_generated(farm_dir,
                                         backend="pallas_interpret")
    assert set(farm.cores) == set(FARM_SYSTEMS)
    for core in farm.cores:
        farm.register(core, "alice", seed=5)
    farm.request(name, "alice", 650)
    out = farm.flush()
    assert set(out) == {name}                     # only the active core served

    cand, params = _load_solution(farm_dir, name)
    solo = PRNGService(params, lanes_per_client=128,
                       backend="pallas_interpret", config=cand,
                       dtype=jnp.dtype(cand.dtype_name))
    solo.register("alice", seed=5)
    np.testing.assert_array_equal(out[name]["alice"], solo.draw("alice", 650))


def test_farm_routing_and_errors(farm_dir):
    farm = OscillatorFarm.from_generated(farm_dir, cores=("chen", "lorenz"),
                                         backend="pallas_interpret")
    farm.register("chen", "a", seed=1)
    farm.register("lorenz", "a", seed=1)          # same name, distinct cores
    wa = farm.draw("chen", "a", 300)
    wb = farm.draw("lorenz", "a", 300)
    assert not np.array_equal(wa, wb)             # different oscillators
    with pytest.raises(KeyError):
        farm.draw("ghost_core", "a", 10)
    with pytest.raises(ValueError):
        farm.add_core("chen", _load_solution(farm_dir, "chen")[1])
    with pytest.raises(ValueError):
        # config/dtype/activation are frozen in solution.json
        OscillatorFarm.from_generated(farm_dir, activation="tanh")


def test_farm_snapshot_restore_with_pending(farm_dir):
    """Farm-wide snapshot between request() and flush() keeps the queued
    draws (the service-level `pending` persistence, end to end)."""
    mk = lambda: OscillatorFarm.from_generated(
        farm_dir, cores=("chen", "hyperlorenz"), backend="pallas_interpret")
    farm = mk()
    for core in farm.cores:
        farm.register(core, "c", seed=3)
    farm.draw("chen", "c", 130)
    farm.request("chen", "c", 200)                # in flight at snapshot time
    farm.request("hyperlorenz", "c", 90)
    snap = farm.snapshot()
    a = farm.flush()

    farm2 = mk()
    farm2.restore(snap)
    b = farm2.flush()
    assert set(a) == set(b) == {"chen", "hyperlorenz"}
    for core in a:
        np.testing.assert_array_equal(a[core]["c"], b[core]["c"])
    with pytest.raises(ValueError):
        OscillatorFarm().restore(snap)            # cores must be attached
    extra = OscillatorFarm.from_generated(
        farm_dir, cores=("chen", "hyperlorenz", "lorenz"),
        backend="pallas_interpret")
    with pytest.raises(ValueError):
        extra.restore(snap)                       # ...and none beyond them
