"""PRNG serving engine: batched multi-client launches, determinism,
resumability, and the sharded stream-pool path."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.prng.stream import ChaoticPRNG
from repro.serve.prng_service import PRNGService

from test_kernels import _mk


@pytest.fixture(scope="module")
def params():
    w1, b1, w2, b2, _ = _mk(3, 8, 1)
    return {"w1": w1, "b1": b1, "w2": w2, "b2": b2}


def _service(params, **kw):
    return PRNGService(params, lanes_per_client=128,
                       backend="pallas_interpret", **kw)


def test_eight_clients_one_launch(params):
    svc = _service(params)
    for i in range(8):
        svc.register(f"c{i}", seed=100 + i)
    for i in range(8):
        svc.request(f"c{i}", 400 + 31 * i)
    out = svc.flush()
    assert svc.launches == 1
    assert {k: v.size for k, v in out.items()} == {
        f"c{i}": 400 + 31 * i for i in range(8)}
    # all streams distinct
    heads = [tuple(v[:16]) for v in out.values()]
    assert len(set(heads)) == 8


def test_client_matches_standalone_stream(params):
    """A served stream == a standalone engine with the same seed/config."""
    svc = _service(params)
    for i in range(8):
        svc.register(f"c{i}", seed=40 + i)
    for i in range(8):
        svc.request(f"c{i}", 700)
    out = svc.flush()
    eng = ChaoticPRNG(params, n_streams=128, backend="pallas_interpret",
                      config=svc.config)
    alone, _ = eng.next_words(eng.init(seed=43), 700)
    np.testing.assert_array_equal(out["c3"], alone)


def test_stream_independent_of_cotenants_and_batching(params):
    svc_a = _service(params)
    svc_a.register("x", seed=7)
    for i in range(5):
        svc_a.register(f"noise{i}", seed=i)
    svc_a.request("x", 200)
    svc_a.request("noise2", 5000)          # forces a much larger launch
    first = svc_a.flush()["x"]
    rest = svc_a.draw("x", 800)

    svc_b = _service(params)
    svc_b.register("x", seed=7)
    whole = svc_b.draw("x", 1000)
    np.testing.assert_array_equal(np.concatenate([first, rest]), whole)


def test_snapshot_restore_resumes_bit_exactly(params):
    svc = _service(params)
    for i in range(3):
        svc.register(f"c{i}", seed=i)
    svc.draw("c1", 333)
    snap = svc.snapshot()
    a = svc.draw("c1", 500)
    svc2 = _service(params)
    svc2.restore(snap)
    b = svc2.draw("c1", 500)
    np.testing.assert_array_equal(a, b)
    assert svc2.launches == svc.launches  # both did one post-snapshot launch


def test_snapshot_between_request_and_flush_keeps_pending(params):
    """Regression: a snapshot taken after request() but before flush() must
    carry the queued draw — restore() used to silently drop it."""
    svc = _service(params)
    svc.register("a", seed=1)
    svc.register("b", seed=2)
    svc.draw("a", 120)
    svc.request("a", 250)                  # in flight
    svc.request("b", 75)
    snap = svc.snapshot()
    out_a = svc.flush()

    svc2 = _service(params)
    svc2.restore(snap)
    assert svc2.clients["a"].pending == 250
    assert svc2.clients["b"].pending == 75
    out_b = svc2.flush()
    assert set(out_a) == set(out_b) == {"a", "b"}
    for name in out_a:
        np.testing.assert_array_equal(out_a[name], out_b[name])


def test_snapshot_restores_outbox_and_pending_roundtrip(params):
    """draw() for one client parks a co-tenant's served words in the outbox;
    snapshot/restore must preserve both outbox and pending invariants."""
    svc = _service(params)
    svc.register("a", seed=1)
    svc.register("b", seed=2)
    svc.request("a", 300)
    svc.draw("b", 200)                     # a's words now parked in outbox
    snap = svc.snapshot()
    svc2 = _service(params)
    svc2.restore(snap)
    a1 = svc.flush()["a"]
    a2 = svc2.flush()["a"]
    np.testing.assert_array_equal(a1, a2)
    solo = _service(params)
    solo.register("a", seed=1)
    np.testing.assert_array_equal(a1, solo.draw("a", 300))


def test_register_duplicate_raises(params):
    svc = _service(params)
    svc.register("a", seed=0)
    with pytest.raises(ValueError):
        svc.register("a", seed=1)


def test_default_seeds_are_per_client(params):
    """Clients registered without a seed must not share a stream."""
    svc = _service(params)
    svc.register("alice")
    svc.register("bob")
    svc.request("alice", 200)
    svc.request("bob", 200)
    out = svc.flush()
    assert not np.array_equal(out["alice"], out["bob"])


def test_idle_clients_frozen(params):
    """Idle clients neither buffer overdraw nor advance their streams."""
    svc = _service(params)
    svc.register("busy", seed=1)
    svc.register("idle", seed=2)
    for _ in range(3):
        svc.draw("busy", 3000)
    idle = svc.clients["idle"]
    assert len(idle.buf) == 0 and idle.row == 0
    # the idle client's stream is untouched by the co-tenant's draws
    solo = _service(params)
    solo.register("idle", seed=2)
    np.testing.assert_array_equal(svc.draw("idle", 500),
                                  solo.draw("idle", 500))


def test_draw_never_drops_cotenant_requests(params):
    """A draw()-triggered flush parks other clients' served words in the
    outbox instead of discarding them; a later flush delivers them."""
    svc = _service(params)
    svc.register("a", seed=1)
    svc.register("b", seed=2)
    svc.request("a", 300)
    got_b = svc.draw("b", 200)         # serves a's request too
    assert got_b.size == 200
    got_a = svc.flush()["a"]           # a's words arrive, not dropped
    solo = _service(params)
    solo.register("a", seed=1)
    np.testing.assert_array_equal(got_a, solo.draw("a", 300))


def test_draw_after_own_request_returns_only_new_words(params):
    svc = _service(params)
    svc.register("a", seed=1)
    svc.request("a", 150)
    got = svc.draw("a", 100)           # must be words 150..250, not 0..250
    assert got.size == 100
    solo = _service(params)
    solo.register("a", seed=1)
    whole = solo.draw("a", 250)
    np.testing.assert_array_equal(got, whole[150:])
    np.testing.assert_array_equal(svc.flush()["a"], whole[:150])


def test_small_draw_does_not_pay_full_time_block(params):
    """A 10-word request must not compute/buffer a whole autotuned time
    block (t_block=256 would mean 128 rows = 16k words for one client);
    small launches shrink to the next power of two of the needed rows."""
    svc = _service(params)
    svc.register("a", seed=1)
    got = svc.draw("a", 10)
    assert got.size == 10
    assert len(svc.clients["a"].buf) <= 4 * svc.lanes_per_client - 10
    # and the small-draw stream still matches a large-draw replay
    solo = _service(params)
    solo.register("a", seed=1)
    np.testing.assert_array_equal(got, solo.draw("a", 2000)[:10])


def test_zero_and_negative_draws(params):
    svc = _service(params)
    svc.register("a", seed=0)
    z = svc.draw("a", 0)
    assert z.shape == (0,) and z.dtype == np.uint32
    assert svc.launches == 0               # zero draw must not launch
    with pytest.raises(ValueError):
        svc.draw("a", -1)
    with pytest.raises(KeyError):
        svc.draw("ghost", 0)


def test_sharded_pool_matches_unsharded(params):
    """shard_map over the stream axis is exact (single-device mesh here;
    the multi-device case runs in a subprocess below)."""
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    svc_m = _service(params, mesh=mesh)
    svc_u = _service(params)
    for svc in (svc_m, svc_u):
        svc.register("a", seed=1)
        svc.register("b", seed=2)
    np.testing.assert_array_equal(svc_m.draw("a", 400), svc_u.draw("a", 400))


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.serve.prng_service import PRNGService

    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    params = {"w1": jax.random.normal(ks[0], (3, 8)) * 0.5,
              "b1": jax.random.normal(ks[1], (8,)) * 0.1,
              "w2": jax.random.normal(ks[2], (8, 3)) * 0.5,
              "b2": jax.random.normal(ks[3], (3,)) * 0.1}
    mesh = Mesh(np.asarray(jax.devices()[:4]), ("data",))
    kw = dict(lanes_per_client=128, backend="pallas_interpret")
    svc_m = PRNGService(params, mesh=mesh, **kw)
    svc_u = PRNGService(params, **kw)
    for svc in (svc_m, svc_u):
        for i in range(4):
            svc.register(f"c{i}", seed=i)
    a = svc_m.draw("c2", 600)
    b = svc_u.draw("c2", 600)
    assert np.array_equal(a, b)
    print("SHARDED OK")
""")


def test_sharded_pool_multidevice():
    """4-device shard_map pool == single-device pool, bitwise."""
    r = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu", "HOME": "/root"})
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-3000:])
    assert "SHARDED OK" in r.stdout
