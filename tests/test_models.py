"""Per-arch smoke tests (reduced configs): forward + one train step on CPU,
shape and finiteness asserts; plus layer-level equivalence tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import transformer as tf
from repro.train.optimizer import Adam
from repro.train.train_step import TrainStepConfig, init_train_state, make_train_step


def _batch(cfg, b=2, s=64, key=0):
    k = jax.random.PRNGKey(key)
    toks = jax.random.randint(k, (b, s + 1), 0, cfg.vocab_size)
    batch = {"labels": toks[:, 1:]}
    if cfg.frontend != "text":
        batch["embeds"] = jax.random.normal(k, (b, s, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = toks[:, :-1]
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    opt = Adam(lr=1e-3, clip_norm=1.0)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = make_train_step(cfg, opt, TrainStepConfig(num_microbatches=2))
    batch = _batch(cfg, b=4, s=64)

    logits, aux = tf.forward(cfg, state.params, batch.get("tokens"),
                             embeds=batch.get("embeds"))
    assert logits.shape == (4, 64, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state.step) == 1
    # params actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, new_state.params))
    assert max(moved) > 0


@pytest.mark.parametrize("arch", ["llama3_2_3b", "rwkv6_1_6b", "zamba2_1_2b",
                                  "mixtral_8x7b", "qwen3_moe_30b_a3b"])
def test_loss_decreases_two_steps(arch):
    cfg = get_smoke_config(arch)
    opt = Adam(lr=3e-3)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt, TrainStepConfig()))
    batch = _batch(cfg, b=8, s=64)
    losses = []
    for _ in range(8):
        state, m = step(state, batch)   # same batch: loss must drop
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ["llama3_2_3b", "gemma_7b", "mixtral_8x7b",
                                  "rwkv6_1_6b", "zamba2_1_2b", "musicgen_large"])
def test_decode_matches_forward(arch):
    """Greedy parity: decode_step token-by-token must reproduce the full
    forward's next-token logits at every position."""
    cfg = get_smoke_config(arch)
    # drop-free MoE capacity so the train-path forward is the exact mixture
    cfg = dataclasses.replace(
        cfg, remat=False,
        capacity_factor=float(cfg.n_experts) if cfg.is_moe else cfg.capacity_factor)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)

    full_logits, _ = tf.forward(cfg, params, toks)

    state = tf.init_decode_state(cfg, b, max_len=s)
    step = jax.jit(lambda st, t: tf.decode_step(cfg, params, st, t))
    dec = []
    for i in range(s):
        lg, state = step(state, toks[:, i:i + 1])
        dec.append(lg[:, 0])
    dec = jnp.stack(dec, axis=1)
    # MoE capacity drops can perturb small logits; compare argmax + values
    atol = 2e-1 if cfg.is_moe else 2e-2
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32), atol=atol)


def test_swa_ring_cache_decode():
    """Sliding-window decode past the window edge stays correct."""
    cfg = dataclasses.replace(get_smoke_config("mixtral_8x7b"),
                              attn_window=8, n_experts=2, n_experts_per_tok=1)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    b, s = 1, 24   # window 8, decode 3x beyond
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full_logits, _ = tf.forward(cfg, params, toks)
    state = tf.init_decode_state(cfg, b, max_len=s)
    step = jax.jit(lambda st, t: tf.decode_step(cfg, params, st, t))
    for i in range(s):
        lg, state = step(state, toks[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(full_logits[:, -1], np.float32),
                               atol=2e-1)


def test_gqa_equals_mha_when_kv_equals_heads():
    from repro.models.attention import AttnDims, attn_apply, attn_init
    d, h, hd = 64, 4, 16
    dims_g = AttnDims(n_heads=h, n_kv_heads=h, head_dim=hd)
    p = attn_init(jax.random.PRNGKey(0), d, dims_g, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, d))
    # grouped path with g=1 must equal itself run through plain einsum
    out = attn_apply(p, x, dims_g)
    assert out.shape == x.shape and bool(jnp.all(jnp.isfinite(out)))


def test_flash_equals_plain_attention():
    from repro.models import attention as A
    d, h, hd = 64, 4, 16
    dims = A.AttnDims(n_heads=h, n_kv_heads=2, head_dim=hd)
    p = A.attn_init(jax.random.PRNGKey(0), d, dims, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, d))
    q, k, v = A._project_qkv(p, x, dims, jnp.arange(64)[None, :])
    plain = A._plain_attention(q, k, v, dims)
    old_q, old_kv = A.FLASH_BLOCK_Q, A.FLASH_BLOCK_KV
    try:
        A.FLASH_BLOCK_Q = A.FLASH_BLOCK_KV = 16
        flash = A._flash_attention(q, k, v, dims)
    finally:
        A.FLASH_BLOCK_Q, A.FLASH_BLOCK_KV = old_q, old_kv
    np.testing.assert_allclose(np.asarray(flash), np.asarray(plain), atol=2e-5)


def test_flash_swa_masking():
    from repro.models import attention as A
    d, h, hd = 32, 2, 16
    dims = A.AttnDims(n_heads=h, n_kv_heads=2, head_dim=hd, window=24)
    p = A.attn_init(jax.random.PRNGKey(0), d, dims, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, d))
    q, k, v = A._project_qkv(p, x, dims, jnp.arange(64)[None, :])
    plain = A._plain_attention(q, k, v, dims)
    old_q, old_kv = A.FLASH_BLOCK_Q, A.FLASH_BLOCK_KV
    try:
        A.FLASH_BLOCK_Q = A.FLASH_BLOCK_KV = 16
        flash = A._flash_attention(q, k, v, dims)
    finally:
        A.FLASH_BLOCK_Q, A.FLASH_BLOCK_KV = old_q, old_kv
    np.testing.assert_allclose(np.asarray(flash), np.asarray(plain), atol=2e-5)


def test_moe_router_invariants():
    from repro.models.moe import moe_apply, moe_init
    d, f, e, k = 32, 64, 8, 2
    p = moe_init(jax.random.PRNGKey(0), d, f, e, True, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, d))
    out, aux = moe_apply(p, x, top_k=k, activation="silu", glu=True,
                         group_size=64, capacity_factor=8.0)  # no drops
    assert out.shape == x.shape
    assert float(aux["dropped_frac"]) == 0.0
    assert float(aux["lb_loss"]) >= 1.0 - 1e-3   # lower bound at balance
    # with huge capacity, output = weighted sum of top-k expert outputs:
    # scaling all expert outputs by 2 must scale output by 2
    p2 = dict(p, wo=p["wo"] * 2)
    out2, _ = moe_apply(p2, x, top_k=k, activation="silu", glu=True,
                        group_size=64, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out2), 2 * np.asarray(out), rtol=2e-4)


def test_moe_capacity_drops_counted():
    from repro.models.moe import moe_apply, moe_init
    d, f, e = 16, 32, 4
    p = moe_init(jax.random.PRNGKey(0), d, f, e, False, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, d))
    _, aux = moe_apply(p, x, top_k=2, activation="relu", glu=False,
                       group_size=64, capacity_factor=0.25)
    assert float(aux["dropped_frac"]) > 0.0


def test_zamba2_shared_block_weight_reuse():
    """The hybrid arch must have exactly ONE shared attn block's params."""
    cfg = get_smoke_config("zamba2_1_2b")
    params = tf.init(cfg, jax.random.PRNGKey(0))
    assert "shared" in params
    # shared attn weights are NOT stacked per layer
    assert params["shared"]["attn"]["wq"].ndim == 2
    assert tf.n_shared_invocations(cfg) == cfg.n_layers // cfg.hybrid_shared_every


def test_rope_preserves_norm():
    from repro.models.attention import apply_rope
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32))
    y = apply_rope(x, jnp.arange(8)[None, :], 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    from repro.models.attention import apply_rope
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
    def score(m, n):
        qm = apply_rope(q, jnp.asarray([[m]]), 10_000.0)
        kn = apply_rope(k, jnp.asarray([[n]]), 10_000.0)
        return float(jnp.sum(qm * kn))
    assert abs(score(5, 3) - score(102, 100)) < 1e-3
    assert abs(score(7, 7) - score(0, 0)) < 1e-3


def test_moe_bf16_dispatch_parity():
    """bf16 dispatch (the §Perf lever) must match f32 dispatch closely:
    one-hots are exact in bf16; only the gate values round."""
    import jax.numpy as jnp
    from repro.models.moe import moe_apply, moe_init
    d, f, e, k = 32, 64, 8, 2
    p = moe_init(jax.random.PRNGKey(0), d, f, e, True, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, d))
    kw = dict(top_k=k, activation="silu", glu=True, group_size=64,
              capacity_factor=8.0)
    out32, _ = moe_apply(p, x, dispatch_dtype=jnp.float32, **kw)
    out16, _ = moe_apply(p, x, dispatch_dtype=jnp.bfloat16, **kw)
    err = float(jnp.max(jnp.abs(out32 - out16)))
    scale = float(jnp.max(jnp.abs(out32)))
    assert err < 0.02 * scale + 1e-3, (err, scale)
