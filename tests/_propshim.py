"""Drop-in fallback for ``hypothesis`` so tier-1 collection never breaks.

When hypothesis is installed we re-export the real thing.  When it is not
(the CI/container baseline), ``given`` degrades to a deterministic
parametrized sweep: each strategy yields its boundary values plus seeded
pseudo-random samples, and the test body runs over ``max_examples`` fixed
combinations.  No shrinking, no database — just enough to keep the
property tests meaningful and the suite importable everywhere.

Usage in tests (replaces ``from hypothesis import ...``)::

    from _propshim import given, settings, strategies as st
"""
from __future__ import annotations

import functools
import itertools
import random
import zlib

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        """A deterministic example generator standing in for a strategy."""

        def __init__(self, examples_fn):
            self._examples_fn = examples_fn

        def examples(self, rng: random.Random, n: int):
            return self._examples_fn(rng, n)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            def gen(rng, n):
                edge = [min_value, max_value, (min_value + max_value) // 2]
                rnd = [rng.randint(min_value, max_value) for _ in range(n)]
                return (edge + rnd)[:max(n, 1)]
            return _Strategy(gen)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            def gen(rng, n):
                edge = [min_value, max_value, (min_value + max_value) / 2.0]
                rnd = [rng.uniform(min_value, max_value) for _ in range(n)]
                return (edge + rnd)[:max(n, 1)]
            return _Strategy(gen)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)

            def gen(rng, n):
                reps = -(-max(n, 1) // len(elements))
                return (elements * reps)[:max(n, 1)]
            return _Strategy(gen)

        @staticmethod
        def booleans():
            return strategies.sampled_from([False, True])

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
        def deco(fn):
            fn._propshim_max_examples = max_examples
            return fn
        return deco

    def given(**named_strategies):
        def deco(fn):
            # NOTE: no functools.wraps — the wrapper must expose a ()-arg
            # signature so pytest doesn't mistake strategy names for
            # fixtures; and @settings may be applied *above* @given, so
            # max_examples is read lazily at call time.
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_propshim_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                rng = random.Random(0xC0FFEE ^ zlib.crc32(fn.__name__.encode()))
                names = sorted(named_strategies)
                columns = [named_strategies[k].examples(rng, n) for k in names]
                # zip the columns so every strategy's edge cases appear and
                # combinations vary (not a full cartesian product).
                cases = list(itertools.islice(
                    zip(*(itertools.cycle(c) for c in columns)), n))
                for case in cases:
                    fn(*args, **dict(zip(names, case)), **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
