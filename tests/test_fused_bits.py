"""Fused bit-extraction kernel vs the unfused trajectory->pack pipeline.

Equivalence contract: for the SAME float trajectory, the in-kernel packing
(fold16 + Weyl + Murmur3) is bit-exact with ``ops.bits_from_trajectory``.
The mxu compute path reproduces the pure-jnp oracle's floats bit-for-bit on
CPU, so there the fused words also equal the all-reference pipeline; the
vpu path's broadcast-FMA ordering differs from the oracle matmul by ~1 ulp,
which chaos amplifies — for it the contract is stated against the unfused
kernel trajectory (same fp order), which is the packing-correctness claim.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chaotic_ann import chaotic_ann_bits_pallas, chaotic_ann_pallas
from repro.kernels.ops import bits_from_trajectory, chaotic_bits, pack_words
from repro.kernels.ref import chaotic_ann_ref

from test_kernels import SWEEP, _mk


@pytest.mark.parametrize("i,h,s,t,sb,tb,un,unit", SWEEP)
def test_fused_equals_unfused_packing_sweep(i, h, s, t, sb, tb, un, unit):
    """Fused kernel == bits_from_trajectory over its own trajectory, bitwise."""
    w1, b1, w2, b2, x0 = _mk(i, h, s)
    words, final = chaotic_ann_bits_pallas(
        w1, b1, w2, b2, x0, n_steps=t, s_block=sb, t_block=tb, unroll=un,
        compute_unit=unit, interpret=True)
    traj = chaotic_ann_pallas(w1, b1, w2, b2, x0, n_steps=t, s_block=sb,
                              t_block=tb, unroll=un, compute_unit=unit,
                              interpret=True)
    assert words.dtype == jnp.uint32 and words.shape == (t // 2, s)
    np.testing.assert_array_equal(np.asarray(words),
                                  np.asarray(bits_from_trajectory(traj)))
    # The final-state output is the resume handle: it must be the last
    # trajectory sample exactly.
    np.testing.assert_array_equal(np.asarray(final), np.asarray(traj[-1]))


@pytest.mark.parametrize("i,h,s,t,sb,tb,un", [
    (3, 8, 256, 64, 256, 32, 1),
    (4, 8, 384, 48, 128, 16, 4),
])
def test_fused_mxu_equals_reference_pipeline(i, h, s, t, sb, tb, un):
    """mxu fused words == bits_from_trajectory(chaotic_ann_ref(...)), bitwise."""
    w1, b1, w2, b2, x0 = _mk(i, h, s)
    words, _ = chaotic_ann_bits_pallas(
        w1, b1, w2, b2, x0, n_steps=t, s_block=sb, t_block=tb, unroll=un,
        compute_unit="mxu", interpret=True)
    ref_words = bits_from_trajectory(chaotic_ann_ref(w1, b1, w2, b2, x0, t))
    np.testing.assert_array_equal(np.asarray(words), np.asarray(ref_words))


def test_vpu_vs_mxu_agreement():
    """vpu and mxu agree on the trajectory (pre-divergence window) and each
    is bit-exact with its own unfused packing; both word streams are
    monobit-balanced (the fp-order 1-ulp difference decorrelates the low
    mantissa bits, so bitwise word equality across units is not a claim)."""
    w1, b1, w2, b2, x0 = _mk(3, 8, 256)
    out = {}
    for unit in ("vpu", "mxu"):
        traj = chaotic_ann_pallas(w1, b1, w2, b2, x0, n_steps=64, s_block=128,
                                  t_block=32, compute_unit=unit, interpret=True)
        words, _ = chaotic_ann_bits_pallas(
            w1, b1, w2, b2, x0, n_steps=64, s_block=128, t_block=32,
            compute_unit=unit, interpret=True)
        np.testing.assert_array_equal(np.asarray(words),
                                      np.asarray(bits_from_trajectory(traj)))
        out[unit] = (np.asarray(traj), np.asarray(words))
    np.testing.assert_allclose(out["vpu"][0][:4], out["mxu"][0][:4], atol=5e-4)
    for unit, (_, words) in out.items():
        ones = np.unpackbits(words.view(np.uint8)).mean()
        assert abs(ones - 0.5) < 0.02, (unit, ones)


def test_word_offset_resumes_weyl_sequence():
    """Chunked draws with carried (state, offset) == one long draw, bitwise."""
    w1, b1, w2, b2, x0 = _mk(3, 8, 128)
    params = {"w1": w1, "b1": b1, "w2": w2, "b2": b2}
    full, _ = chaotic_bits(params, x0, 96, backend="pallas_interpret",
                           s_block=128, t_block=32)
    a, s1 = chaotic_bits(params, x0, 32, backend="pallas_interpret",
                         s_block=128, t_block=32)
    b, s2 = chaotic_bits(params, s1, 64, 16, backend="pallas_interpret",
                         s_block=128, t_block=32)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(a), np.asarray(b)]), np.asarray(full))


def test_pack_words_matches_bits_from_trajectory():
    w1, b1, w2, b2, x0 = _mk(3, 8, 64)
    traj = chaotic_ann_ref(w1, b1, w2, b2, x0, 32)
    np.testing.assert_array_equal(np.asarray(pack_words(traj, 0)),
                                  np.asarray(bits_from_trajectory(traj)))
    # per-stream offsets: each column continues its own Weyl sequence
    off = jnp.arange(64, dtype=jnp.uint32)
    shifted = pack_words(traj, off)
    assert shifted.shape == (16, 64)
    base = pack_words(traj, 0)
    assert not np.array_equal(np.asarray(shifted), np.asarray(base))
    np.testing.assert_array_equal(np.asarray(shifted[:, 0]),
                                  np.asarray(base[:, 0]))  # offset 0 column


def test_uniform_from_trajectory_signature_and_range():
    """Regression: the dead (ignored) `scale_bits` parameter is gone — the
    signature no longer advertises a knob that does nothing."""
    import inspect
    from repro.kernels import ops
    assert "scale_bits" not in inspect.signature(
        ops.uniform_from_trajectory).parameters
    w1, b1, w2, b2, x0 = _mk(3, 8, 64)
    traj = chaotic_ann_ref(w1, b1, w2, b2, x0, 32)
    u = np.asarray(ops.uniform_from_trajectory(traj))
    assert u.shape == (16, 64)
    assert u.min() >= 0.0 and u.max() < 1.0


def test_fused_backend_dispatch_and_validation():
    w1, b1, w2, b2, x0 = _mk(3, 8, 128)
    params = {"w1": w1, "b1": b1, "w2": w2, "b2": b2}
    with pytest.raises(ValueError):
        chaotic_ann_bits_pallas(w1, b1, w2, b2, x0, n_steps=33, interpret=True)
    words, state = chaotic_bits(params, x0, 32, backend="ref")
    assert words.shape == (16, 128) and state.shape == (128, 3)


def test_fused_bf16_carries_real_entropy():
    """bf16 words come from the bf16 mantissa (bitcast at half width), not
    from a zero-entropy f32 upcast: streams must differ from each other and
    from the pure counter hash, stay bit-exact with the unfused packing,
    and stay balanced."""
    w1, b1, w2, b2, x0 = _mk(3, 8, 128)
    xb = x0.astype(jnp.bfloat16)
    words, state = chaotic_ann_bits_pallas(
        w1, b1, w2, b2, xb, n_steps=64, s_block=128, t_block=32,
        interpret=True)
    assert words.shape == (32, 128)
    assert state.dtype == jnp.bfloat16
    traj = chaotic_ann_pallas(w1, b1, w2, b2, xb, n_steps=64, s_block=128,
                              t_block=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(words),
                                  np.asarray(bits_from_trajectory(traj)))
    w = np.asarray(words)
    # a zero-entropy fold would make every stream's word row identical
    assert np.unique(w, axis=1).shape[1] > 1
    ones = np.unpackbits(w.view(np.uint8)).mean()
    assert abs(ones - 0.5) < 0.05, ones
