"""Demand-shaped gang launches: ragged kernels + the cost-model planner.

Kernel level: a ragged launch (per-block / per-core row maps) must
reproduce per-core launches of each member's OWN row count, bit for bit —
words prefix AND final state — across dtypes and both gang layouts.

Planner level: golden decisions (uniform demand -> one padded group-max
launch; heavily skewed -> a ragged or split launch), bit-identity of
delivered words vs ``gang=False`` whatever shape the planner picks, plan
caching in steady state, and mid-flush snapshot/restore across a
planner-chosen split.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dse import Candidate, GangCostModel
from repro.kernels import ops
from repro.kernels.chaotic_ann import gang_effective_rows
from repro.serve.farm import OscillatorFarm

from test_kernels import _mk

CAND = Candidate(i_dim=3, h_dim=8, p=0, compute_unit="vpu",
                 dtype_bytes=4, unroll=2, t_block=32)


def _params(i_dim=3, h_dim=8, key=0):
    w1, b1, w2, b2, _ = _mk(i_dim, h_dim, 1, key=key)
    return {"w1": w1, "b1": b1, "w2": w2, "b2": b2}


def _stacked(param_list):
    return {k: jnp.stack([p[k] for p in param_list])
            for k in ("w1", "b1", "w2", "b2")}


# ---------------------------------------------------------------------------
# Kernel level: ragged row maps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_concat_matches_per_core(dtype):
    """Each lane block of a ragged lane-concat launch computes exactly its
    effective rows, bit-identical to a per-core launch of that many."""
    s_block, n_steps = 128, 64
    plist = [_params(key=k) for k in range(3)]
    core_map = np.asarray([0, 2, 1, 2], np.int32)
    row_map = np.asarray([32, 8, 4, 17], np.int32)
    s_total = len(core_map) * s_block
    _, _, _, _, x0 = _mk(3, 8, s_total, key=9)
    x0 = x0.astype(dtype)
    rng = np.random.default_rng(3)
    offs = jnp.asarray(rng.integers(0, 10_000, size=s_total), np.uint32)

    eff = gang_effective_rows(row_map, n_steps, 32, 2)
    assert list(eff) == [32, 8, 4, 18]       # 17 rounds up to unroll chunks
    gw, gs = ops.chaotic_bits_gang(
        _stacked(plist), x0, n_steps, offs, core_map=core_map,
        row_map=row_map, backend="pallas_interpret", s_block=s_block,
        t_block=32, unroll=2)
    for g, c in enumerate(core_map):
        sl = slice(g * s_block, (g + 1) * s_block)
        r_g = int(eff[g])
        w, s = ops.chaotic_bits(
            plist[c], x0[sl], 2 * r_g, offs[sl],
            backend="pallas_interpret", s_block=s_block, t_block=32,
            unroll=2)
        np.testing.assert_array_equal(np.asarray(gw)[:r_g, sl],
                                      np.asarray(w))
        np.testing.assert_array_equal(
            np.asarray(jnp.asarray(gs[sl], jnp.float32)),
            np.asarray(jnp.asarray(s, jnp.float32)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_stacked_matches_per_core(dtype):
    """The sublane-stacked freeze: core c's state stops after exactly
    row_map[c] rows and its word prefix matches a per-core launch."""
    C, S, n_steps = 4, 256, 64
    plist = [_params(key=k) for k in range(C)]
    _, _, _, _, x0 = _mk(3, 8, C * S, key=6)
    x0 = x0.reshape(C, S, 3).astype(dtype)
    rng = np.random.default_rng(8)
    offs = jnp.asarray(rng.integers(0, 10_000, size=(C, S)), np.uint32)
    row_map = np.asarray([32, 5, 1, 20], np.int32)

    gw, gs = ops.chaotic_bits_gang_stacked(
        _stacked(plist), x0, n_steps, offs, row_map=row_map,
        backend="pallas_interpret", s_block=128, t_block=32, unroll=2)
    for c in range(C):
        r_c = int(row_map[c])
        w, s = ops.chaotic_bits(plist[c], x0[c], 2 * r_c, offs[c],
                                backend="pallas_interpret", s_block=128,
                                t_block=32, unroll=2)
        np.testing.assert_array_equal(np.asarray(gw)[:r_c, c],
                                      np.asarray(w))
        np.testing.assert_array_equal(
            np.asarray(jnp.asarray(gs[c], jnp.float32)),
            np.asarray(jnp.asarray(s, jnp.float32)))


def test_ragged_ref_backends_match_per_core_ref():
    """Co-simulation contract for ragged launches: both gang 'ref'
    backends equal per-core 'ref' draws over each member's own rows."""
    plist = [_params(key=k) for k in range(3)]
    core_map = np.asarray([0, 2, 1], np.int32)
    row_map = np.asarray([16, 4, 8], np.int32)
    _, _, _, _, x0 = _mk(3, 8, 3 * 128, key=4)
    rw, rs = ops.chaotic_bits_gang(
        _stacked(plist), x0, 32, jnp.uint32(5), core_map=core_map,
        row_map=row_map, backend="ref", s_block=128, t_block=32, unroll=2)
    eff = gang_effective_rows(row_map, 32, 32, 2)
    for g, c in enumerate(core_map):
        sl = slice(g * 128, (g + 1) * 128)
        r_g = int(eff[g])
        w, s = ops.chaotic_bits(plist[c], x0[sl], 2 * r_g, jnp.uint32(5),
                                backend="ref", s_block=128)
        np.testing.assert_array_equal(np.asarray(rw)[:r_g, sl],
                                      np.asarray(w))
        np.testing.assert_array_equal(np.asarray(rs[sl]), np.asarray(s))

    xs = x0[:3 * 128].reshape(3, 128, 3)
    rw, rs = ops.chaotic_bits_gang_stacked(
        _stacked(plist), xs, 32, jnp.uint32(5), row_map=row_map,
        backend="ref")
    for c in range(3):
        r_c = int(row_map[c])
        w, s = ops.chaotic_bits(plist[c], xs[c], 2 * r_c, jnp.uint32(5),
                                backend="ref", s_block=128)
        np.testing.assert_array_equal(np.asarray(rw)[:r_c, c],
                                      np.asarray(w))
        np.testing.assert_array_equal(np.asarray(rs[c]), np.asarray(s))


# ---------------------------------------------------------------------------
# Planner level
# ---------------------------------------------------------------------------

def _farm(members, lanes=128, **kw):
    farm = OscillatorFarm(**kw)
    for core, params, config, dtype in members:
        farm.add_core(core, params, config=config, dtype=dtype,
                      lanes_per_client=lanes, backend="pallas_interpret")
    return farm


def _members(n=4, dtype=None):
    return [(f"core{i}", _params(key=10 + i), CAND, dtype) for i in range(n)]


def _request_rows(farm, rows_by_core):
    for core, rows in rows_by_core.items():
        farm.request(core, "t", rows * 128)


def _register_all(farm, seed=7):
    for core in farm.cores:
        farm.register(core, "t", seed=seed)


def test_golden_decision_uniform_is_single_padded_launch():
    """Uniform demand: the planner must keep the PR 3 single group-max
    launch (stacked layout for equal vpu pools) — no split, no raggedness."""
    farm = _farm(_members())
    _register_all(farm)
    _request_rows(farm, {c: 16 for c in farm.cores})
    farm.flush()
    assert farm.plan_decisions == {"padded": 1, "ragged": 0, "split": 0}
    assert farm.gang_launches == 1
    assert farm.launches == 1
    (plan,) = farm._sched._plans.values()
    assert plan["mode"] == "stacked"


def test_golden_decision_skewed_is_ragged_or_split():
    """One hot tenant must not force co-tenants to group-max overdraw: the
    planner picks a ragged launch or a split, never the padded policy."""
    farm = _farm(_members())
    _register_all(farm)
    _request_rows(farm, {"core0": 64, "core1": 4, "core2": 4, "core3": 4})
    out = farm.flush()
    dec = farm.plan_decisions
    assert dec["padded"] == 0 and dec["ragged"] + dec["split"] == 1

    # the padded policy (planner=False) still works and matches bit for bit
    policy = _farm(_members(), planner=False)
    _register_all(policy)
    _request_rows(policy, {"core0": 64, "core1": 4, "core2": 4, "core3": 4})
    ref = policy.flush()
    assert policy.plan_decisions["padded"] == 1
    assert set(out) == set(ref)
    for core in ref:
        np.testing.assert_array_equal(out[core]["t"], ref[core]["t"])


@pytest.mark.parametrize("dtype", [None, jnp.bfloat16])
def test_planner_bit_identical_to_solo_across_flushes(dtype):
    """Skewed multi-flush traffic through the planner delivers exactly the
    gang=False words — whatever launch shapes it picks."""
    farms = [_farm(_members(dtype=dtype)),
             _farm(_members(dtype=dtype), gang=False)]
    for f in farms:
        for core in f.cores:
            f.register(core, "u1", seed=21)
            f.register(core, "u2", seed=22)
    traffic = [
        {"core0": [("u1", 64 * 128)], "core1": [("u2", 300)],
         "core2": [("u1", 300)], "core3": [("u2", 300)]},
        {"core0": [("u2", 17)], "core2": [("u1", 2048), ("u2", 7)]},
        {"core1": [("u1", 4096)], "core3": [("u1", 1)]},
    ]
    for round_ in traffic:
        outs = []
        for f in farms:
            for core, reqs in round_.items():
                for client, n in reqs:
                    f.request(core, client, n)
            outs.append(f.flush())
        plan_out, solo_out = outs
        assert set(plan_out) == set(solo_out)
        for core in plan_out:
            assert set(plan_out[core]) == set(solo_out[core])
            for client in plan_out[core]:
                np.testing.assert_array_equal(plan_out[core][client],
                                              solo_out[core][client])
    assert farms[0].launches < farms[1].launches


def test_planner_ragged_pools_still_bit_identical():
    """Ragged POOLS (different client counts) + ragged DEMAND compose: the
    lane-concat layout with a row map stays bit-identical to per-core."""
    members = _members(3)
    farms = [_farm(members), _farm(members, gang=False)]
    for f in farms:
        f.register("core0", "only", seed=31)          # 128-lane pool
        for core in ("core1", "core2"):               # 256-lane pools
            f.register(core, "u1", seed=32)
            f.register(core, "u2", seed=33)
    for f in farms:
        f.request("core0", "only", 64 * 128)          # hot
        f.request("core1", "u2", 512)                 # cold
        f.request("core2", "u1", 512)
    plan_out, solo_out = (f.flush() for f in farms)
    assert set(plan_out) == set(solo_out)
    for core in plan_out:
        for client in plan_out[core]:
            np.testing.assert_array_equal(plan_out[core][client],
                                          solo_out[core][client])


def test_planner_decision_cache_steady_state():
    """Repeating the same bucketed demand vector replans never and
    recompiles never."""
    farm = _farm(_members())
    _register_all(farm)
    for _ in range(4):
        _request_rows(farm, {"core0": 64, "core1": 4, "core2": 4,
                             "core3": 4})
        farm.flush()
    assert len(farm._sched._decisions) == 1
    misses_after_first = farm.dispatch_misses
    _request_rows(farm, {"core0": 64, "core1": 4, "core2": 4, "core3": 4})
    farm.flush()
    assert farm.dispatch_misses == misses_after_first


def test_snapshot_restore_across_planner_split():
    """Snapshot with skewed requests in flight, restore, flush: identical
    words even when the planner chose a SPLIT — and when restored onto a
    padded-policy or gang=False farm (chunk-invariance)."""
    # zero launch overhead makes the split strictly cheapest for this skew
    split_model = GangCostModel(launch_overhead_cycles=0.0)
    farm = _farm(_members(), gang_cost_model=split_model)
    _register_all(farm, seed=9)
    farm.draw("core1", "t", 100)                  # advance some state first
    _request_rows(farm, {"core0": 64, "core1": 4, "core2": 4, "core3": 4})
    snap = farm.snapshot()
    a = farm.flush()
    assert farm.plan_decisions["split"] == 1
    assert farm.launches == 1 + 2         # draw + (solo hot + cold gang)

    b_farm = _farm(_members(), gang_cost_model=split_model)
    b_farm.restore(snap)
    b = b_farm.flush()
    c_farm = _farm(_members(), planner=False)
    c_farm.restore(snap)
    c = c_farm.flush()
    d_farm = _farm(_members(), gang=False)
    d_farm.restore(snap)
    d = d_farm.flush()
    assert set(a) == set(b) == set(c) == set(d)
    for core in a:
        np.testing.assert_array_equal(a[core]["t"], b[core]["t"])
        np.testing.assert_array_equal(a[core]["t"], c[core]["t"])
        np.testing.assert_array_equal(a[core]["t"], d[core]["t"])


def test_gang_cost_multiblock_overdraw():
    """Members spanning several lane blocks: the ragged concat cost must
    credit each member its OWN effective rows (first entry of its block
    span), and padded overdraw must count (dmax - d) words per lane."""
    model = GangCostModel(launch_overhead_cycles=0.0)
    demands, blocks, lanes = [16, 4], [2, 2], [512, 512]
    eff = [16, 16, 4, 4]                   # per-block, member-major
    ragged = model.gang_cost(CAND, demands, blocks, lanes,
                             layout="concat", rows_by_block=eff)
    padded = model.gang_cost(CAND, demands, blocks, lanes, layout="concat")
    step = model.step_cycles(CAND)
    # padded computes 4 blocks x 16 rows, ragged 16+16+4+4: 24 rows saved
    # (48 steps), and padded buffers (16-4)*512 overdraw words
    expected = 2 * 24 * step + model.buffer_cycles((16 - 4) * 512)
    assert padded - ragged == pytest.approx(expected, rel=1e-9)
    # a correct per-member credit means ragged matching demand buffers 0:
    # doubling only the hot member's second block must not change overdraw
    assert (model.gang_cost(CAND, demands, blocks, lanes, layout="concat",
                            rows_by_block=[16, 16, 4, 4])
            < model.gang_cost(CAND, demands, blocks, lanes, layout="concat",
                              rows_by_block=[16, 16, 8, 8]))


def test_profile_stats_accumulate():
    """profile=True farms report per-stage flush wall times."""
    farm = _farm(_members(2), profile=True)
    _register_all(farm)
    _request_rows(farm, {c: 4 for c in farm.cores})
    farm.flush()
    stats = farm.profile_stats
    assert stats is not None and stats["flushes"] == 1.0
    assert stats["launch"] > 0.0
    assert set(stats) >= {"plan", "stack", "launch", "absorb"}
    assert _farm(_members(2)).profile_stats is None
