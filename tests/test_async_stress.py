"""Threaded stress test for the async front-end's thread-safe ingress.

8 threads x 4 tenants hammer ``draw_sync`` through the cross-thread
ingress under a REAL clock with nonzero deadlines, so flushes race
arrivals arbitrarily.  Two properties must survive the chaos:

  * every tenant's concatenated words are bit-identical to a solo
    ``gang=False`` replay of the same totals (chunk-invariance end to
    end, through the deque ingress, coalescing flusher, and gang
    planner);
  * the farm's launch count stays STRICTLY below the number of draws —
    coalescing actually happened.

Marked ``slow``: excluded from tier-1 (pytest.ini deselects it by
default); CI runs it in a separate non-blocking job.
"""
import threading

import numpy as np
import pytest

from repro.core.dse import Candidate
from repro.serve.async_frontend import AsyncOscillatorFarm
from repro.serve.farm import OscillatorFarm

from test_kernels import _mk

CAND = Candidate(i_dim=3, h_dim=8, p=1, compute_unit="vpu",
                 dtype_bytes=4, unroll=4, t_block=64)
N_THREADS = 8
N_CORES = 4
DRAWS_PER_THREAD = 6


def _params(key=0):
    w1, b1, w2, b2, _ = _mk(3, 8, 1, key=key)
    return {"w1": w1, "b1": b1, "w2": w2, "b2": b2}


def _farm(gang=True):
    farm = OscillatorFarm(gang=gang)
    for i in range(N_CORES):
        farm.add_core(f"core{i}", _params(key=10 + i), config=CAND,
                      lanes_per_client=128, backend="pallas_interpret")
        for t in range(N_THREADS):
            farm.register(f"core{i}", f"t{t}", seed=500 + t)
    return farm


@pytest.mark.slow
def test_threaded_hammering_bit_identical_and_coalesced():
    farm = _farm()
    af = AsyncOscillatorFarm(farm, auto_flush_rows=None).start_thread()
    # per-(core, tenant) draw sizes: deterministic, thread-owned tenants so
    # each stream's request order is sequential even under thread racing
    sizes = {(c, t): [37 + 13 * ((c + t + k) % 7) + 128 * (k % 3)
                      for k in range(DRAWS_PER_THREAD)]
             for c in range(N_CORES) for t in range(N_THREADS)}
    got = {}
    errors = []

    def worker(t):
        try:
            for k in range(DRAWS_PER_THREAD):
                for c in range(N_CORES):
                    w = af.draw_sync(f"core{c}", f"t{t}",
                                     sizes[(c, t)][k],
                                     deadline_ms=5, timeout=300)
                    got.setdefault((c, t), []).append(w)
        except Exception as e:              # pragma: no cover - diagnostics
            errors.append((t, repr(e)))

    try:
        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(N_THREADS)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(600)
        assert not errors, errors
        launches = farm.launches
    finally:
        af.close()

    n_draws = N_THREADS * N_CORES * DRAWS_PER_THREAD
    assert launches < n_draws, (
        f"no coalescing: {launches} launches for {n_draws} draws")

    # bit-identity: replay each tenant's totals on a solo gang=False farm
    solo = _farm(gang=False)
    for (c, t), chunks in got.items():
        mine = np.concatenate(chunks)
        ref = solo.draw(f"core{c}", f"t{t}", mine.size)
        np.testing.assert_array_equal(mine, ref,
                                      err_msg=f"stream core{c}/t{t}")
