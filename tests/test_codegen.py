"""Hardware-core generation (paper §III-B.3): generated package imports,
runs, and its testbench (co-simulation analogue) passes."""
import importlib
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import codegen
from repro.core.ann import AnnConfig, extract_parameters, train
from repro.core.chaotic import make_dataset
from repro.core.dse import Candidate


@pytest.fixture(scope="module")
def trained():
    ds = make_dataset("chen", n_samples=20_000)
    params, hist = train(AnnConfig(hidden=8), ds, epochs=120, lr=3e-3)
    assert hist["test_metrics"]["r2"] > 0.999
    return ds, extract_parameters(params)


def _gen(tmp_path, trained, cand, name):
    ds, params = trained
    return codegen.generate_core(name, tmp_path, params=params,
                                 candidate=cand, scale=ds.scale,
                                 offset=ds.offset)


def test_generated_package_structure(tmp_path, trained):
    pkg = _gen(tmp_path, trained, Candidate(i_dim=3, h_dim=8, p=1), "core_a")
    assert (pkg / "__init__.py").exists()
    assert (pkg / "testbench.py").exists()
    assert (pkg / "weights.npz").exists()
    sol = json.loads((pkg / "solution.json").read_text())
    assert sol["candidate"]["p"] == 1
    assert sol["estimated"]["latency_per_stream_cycles"] > 0


def test_generated_core_importable_and_runs(tmp_path, trained):
    pkg = _gen(tmp_path, trained, Candidate(i_dim=3, h_dim=8, p=1,
                                            t_block=32), "core_b")
    sys.path.insert(0, str(tmp_path))
    try:
        mod = importlib.import_module("core_b")
        x0 = np.random.default_rng(0).uniform(-0.5, 0.5, (mod.S_BLOCK, 3)).astype(np.float32)
        traj = mod.generate(x0, 64)
        assert traj.shape == (64, mod.S_BLOCK, 3)
        words, state = mod.generate_bits(x0, 128)
        assert words.dtype == jax.numpy.uint32
        assert words.shape == (64, mod.S_BLOCK)
        assert state.shape == (mod.S_BLOCK, 3)      # resume handle
    finally:
        sys.path.remove(str(tmp_path))


@pytest.mark.parametrize("cand", [
    Candidate(i_dim=3, h_dim=8, p=0, compute_unit="vpu", t_block=32),
    Candidate(i_dim=3, h_dim=8, p=2, compute_unit="mxu", t_block=32),
])
def test_generated_testbench_passes(tmp_path, trained, cand):
    """The emitted validation testbench must pass stand-alone — the HLS
    co-simulation step of the paper's flow."""
    name = f"core_tb_p{cand.p}_{cand.compute_unit}"
    pkg = _gen(tmp_path, trained, cand, name)
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{pkg.parent.parent / 'src'}:{pkg.parent}:" + env.get("PYTHONPATH", "")
    # src path: resolve from repo layout (tests run from repo root)
    env["PYTHONPATH"] = f"src:{pkg.parent}:" + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, str(pkg / "testbench.py")],
                       capture_output=True, text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "TESTBENCH PASS" in r.stdout
