"""Training substrate: optimizer, microbatching, compression, checkpointing,
fault-tolerant loop."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import SyntheticLMDataset
from repro.distributed.compression import compress_grads
from repro.models import transformer as tf
from repro.train import checkpoint as ckpt
from repro.train.loop import LoopConfig, run
from repro.train.optimizer import Adam, global_norm, warmup_cosine
from repro.train.train_step import (TrainState, TrainStepConfig,
                                    init_train_state, make_train_step)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adam_matches_reference():
    """One Adam step against a hand-computed reference."""
    opt = Adam(lr=0.1, b1=0.9, b2=0.999, eps=1e-8)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, 0.5])}
    state = opt.init(params)
    new_params, state = opt.update(grads, state, params)
    # step1: mhat = g, vhat = g^2 -> update = lr * g/(|g|+eps) = lr*sign(g)
    np.testing.assert_allclose(np.asarray(new_params["w"]),
                               [1.0 - 0.1, -2.0 - 0.1], rtol=1e-5)


def test_adam_clip_norm():
    opt = Adam(lr=1.0, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 100.0)}
    st_ = opt.init(params)
    _, st2 = opt.update(grads, st_, params)
    assert float(global_norm(st2.mu)) <= 0.1 * 1.0 + 1e-6  # (1-b1)*clipped


def test_warmup_cosine_schedule():
    sch = warmup_cosine(1e-3, warmup_steps=10, total_steps=100)
    assert float(sch(jnp.asarray(0))) == 0.0
    assert float(sch(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(sch(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)
    assert float(sch(jnp.asarray(5))) == pytest.approx(5e-4, rel=1e-3)


def test_weight_decay_decoupled():
    opt = Adam(lr=0.1, weight_decay=0.1)
    params = {"w": jnp.asarray([10.0])}
    grads = {"w": jnp.asarray([0.0])}
    st_ = opt.init(params)
    new_params, _ = opt.update(grads, st_, params)
    np.testing.assert_allclose(np.asarray(new_params["w"]), [10.0 - 0.1 * 0.1 * 10.0])


# ---------------------------------------------------------------------------
# Microbatching
# ---------------------------------------------------------------------------

def test_microbatched_equals_full_batch():
    """Gradient accumulation over microbatches == single big batch."""
    cfg = get_smoke_config("llama3_2_3b")
    opt = Adam(lr=1e-3)
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, opt, key)
    toks = jax.random.randint(key, (8, 33), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    s1 = jax.jit(make_train_step(cfg, opt, TrainStepConfig(num_microbatches=1)))
    s4 = jax.jit(make_train_step(cfg, opt, TrainStepConfig(num_microbatches=4)))
    st1, m1 = s1(state, batch)
    st4, m4 = s4(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-3)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), st1.params, st4.params)
    assert max(jax.tree.leaves(d)) < 5e-2   # bf16 params, fp32 accum


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_compression_bounded_error():
    g = {"a": jnp.asarray(np.random.default_rng(0).normal(size=(1000,)), jnp.float32)}
    comp, err = compress_grads(g)
    # int8 per-block: |error| <= scale/2 = max|block|/254
    assert float(jnp.max(jnp.abs(err["a"]))) <= float(jnp.max(jnp.abs(g["a"]))) / 254 + 1e-7
    np.testing.assert_allclose(np.asarray(comp["a"] + err["a"]),
                               np.asarray(g["a"]), atol=1e-6)


def test_compression_error_feedback_accumulates():
    """Repeating the same gradient with feedback converges to the true mean:
    sum of compressed updates tracks sum of raw gradients."""
    rng = np.random.default_rng(1)
    g = {"a": jnp.asarray(rng.normal(size=(512,)) * 1e-3, jnp.float32)}
    err = None
    total = jnp.zeros(512)
    for _ in range(50):
        comp, err = compress_grads(g, err)
        total = total + comp["a"]
    np.testing.assert_allclose(np.asarray(total), 50 * np.asarray(g["a"]),
                               atol=float(jnp.max(jnp.abs(g["a"]))) / 100)


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-6, 1e3), n=st.integers(10, 300))
def test_compression_property(scale, n):
    rng = np.random.default_rng(n)
    g = {"a": jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)}
    comp, err = compress_grads(g)
    assert comp["a"].shape == g["a"].shape
    # reconstruction identity: comp + err == g
    np.testing.assert_allclose(np.asarray(comp["a"] + err["a"]),
                               np.asarray(g["a"]), rtol=1e-4, atol=scale * 1e-5)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("rwkv6_1_6b")
    opt = Adam(lr=1e-3)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    ckpt.save(tmp_path, 7, state)
    assert ckpt.latest_step(tmp_path) == 7
    restored = ckpt.restore(tmp_path, state)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k(tmp_path):
    state = {"w": jnp.arange(4.0)}
    for step in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, step, state, keep=2)
    assert ckpt.all_steps(tmp_path) == [4, 5]


def test_checkpoint_atomic_no_partial(tmp_path):
    state = {"w": jnp.arange(4.0)}
    ckpt.save(tmp_path, 1, state)
    # a stale tmp dir must not be visible as a checkpoint
    (tmp_path / ".tmp_step_0000000099").mkdir()
    assert ckpt.all_steps(tmp_path) == [1]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, {"w": jnp.zeros((5,))})


# ---------------------------------------------------------------------------
# Fault-tolerant loop
# ---------------------------------------------------------------------------

def _tiny_setup():
    cfg = get_smoke_config("llama3_2_3b")
    opt = Adam(lr=1e-3)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt, TrainStepConfig()))
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    batch_at = lambda i: {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
    return state, step, batch_at


def test_loop_runs_and_checkpoints(tmp_path):
    state, step, batch_at = _tiny_setup()
    res = run(state, step, batch_at,
              LoopConfig(total_steps=6, ckpt_dir=str(tmp_path), ckpt_every=3,
                         log_every=100), log_fn=lambda s: None)
    assert int(res.final_state.step) == 6
    assert ckpt.latest_step(tmp_path) == 6
    assert not res.preempted


def test_loop_resume_exact(tmp_path):
    """Crash/restart: resumed run must land on the same final params as an
    uninterrupted run (deterministic data + state restore)."""
    state, step, batch_at = _tiny_setup()
    full = run(state, step, batch_at,
               LoopConfig(total_steps=8, ckpt_dir=None, log_every=100),
               log_fn=lambda s: None)

    run(state, step, batch_at,
        LoopConfig(total_steps=4, ckpt_dir=str(tmp_path), ckpt_every=4,
                   log_every=100), log_fn=lambda s: None)
    resumed = run(state, step, batch_at,
                  LoopConfig(total_steps=8, ckpt_dir=str(tmp_path),
                             ckpt_every=4, log_every=100), log_fn=lambda s: None)
    assert resumed.resumed_from == 4
    for a, b in zip(jax.tree.leaves(full.final_state.params),
                    jax.tree.leaves(resumed.final_state.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_data_pipeline_deterministic_and_resumable():
    ds = SyntheticLMDataset(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    b5a = ds.batch_at(5)
    b5b = SyntheticLMDataset(vocab_size=100, seq_len=16, global_batch=4, seed=3).batch_at(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    it = ds.iterate(start_step=5)
    np.testing.assert_array_equal(next(it)["tokens"], b5a["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(ds.batch_at(0)["labels"][:, :-1],
                                  ds.batch_at(0)["tokens"][:, 1:])
