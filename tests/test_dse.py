"""DSE estimation models (paper Eqs. 8-9, Figs. 3-5) and selection modes."""
import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.core.dse import (Candidate, CostModel, LatencyModel, VMEM_USABLE,
                            enumerate_candidates, measure_candidate,
                            pareto_front, select, vmem_bytes)


@pytest.fixture(scope="module")
def models():
    return LatencyModel.fit(), CostModel.fit()


def test_latency_decreases_with_parallelism(models):
    """Paper Fig. 3b: normalized latency falls with P."""
    lm, _ = models
    for unit in ("vpu", "mxu"):
        lats = [lm.predict(3, 8, p, unit, 4) for p in range(6)]
        assert all(a >= b for a, b in zip(lats, lats[1:])), (unit, lats)


def test_latency_scales_with_ih(models):
    """Eq. 8: latency proportional to I*H at fixed P."""
    lm, _ = models
    l1 = lm.predict(3, 8, 2)
    l2 = lm.predict(3, 16, 2)
    assert abs(l2 / l1 - 2.0) < 0.05   # (I*H) doubles


def test_cost_increases_with_parallelism(models):
    """Paper: higher parallelism -> more hardware (VMEM here)."""
    _, cm = models
    costs = [cm.predict(3, 8, p) for p in range(6)]
    assert all(a < b for a, b in zip(costs, costs[1:])), costs


def test_cost_model_accuracy(models):
    """Eq. 9 linear fit tracks the measured VMEM within 5% (paper Table III
    style estimate-vs-actual)."""
    _, cm = models
    for p in (0, 2, 4):
        for i, h in ((3, 4), (3, 8), (3, 16), (4, 8)):
            c = Candidate(i_dim=i, h_dim=h, p=p)
            actual = vmem_bytes(c)
            est = cm.predict(i, h, p)
            assert abs(est - actual) / actual < 0.05, (p, i, h, est, actual)


def test_latency_model_accuracy(models):
    """Eq. 8 cubic fit tracks per-config measurements within 15%."""
    lm, _ = models
    for p in range(6):
        c = Candidate(i_dim=3, h_dim=8, p=p)
        actual = measure_candidate(c)["per_stream_latency_cycles"]
        est = lm.predict(3, 8, p)
        assert abs(est - actual) / actual < 0.15, (p, est, actual)


def test_mxu_vs_vpu_tradeoff():
    """VPU wins for tiny H (I=3, H=8: MXU pads 3->128); the padding waste
    shrinks as H grows (the paper's DSP-vs-LUT analogue trade-off)."""
    vpu8 = measure_candidate(Candidate(h_dim=8, compute_unit="vpu"))
    mxu8 = measure_candidate(Candidate(h_dim=8, compute_unit="mxu"))
    assert vpu8["cycles_per_step"] < mxu8["cycles_per_step"]
    # ratio improves for MXU with larger H
    vpu64 = measure_candidate(Candidate(h_dim=64, compute_unit="vpu"))
    mxu64 = measure_candidate(Candidate(h_dim=64, compute_unit="mxu"))
    assert (mxu64["cycles_per_step"] / vpu64["cycles_per_step"]
            < mxu8["cycles_per_step"] / vpu8["cycles_per_step"])


def test_enumerate_respects_vmem():
    cands = enumerate_candidates(3, 16)
    assert cands
    assert all(vmem_bytes(c) <= VMEM_USABLE for c in cands)


def test_pareto_front_is_nondominated(models):
    lm, cm = models
    front = pareto_front(enumerate_candidates(3, 16), lm, cm)
    assert len(front) >= 3
    for i, (_, c1, l1) in enumerate(front):
        for j, (_, c2, l2) in enumerate(front):
            if i != j:
                assert not (c2 <= c1 and l2 <= l1 and (c2 < c1 or l2 < l1))


def test_selection_modes(models):
    lm, cm = models
    fast = select(3, 8, "min_latency", latency_model=lm, cost_model=cm)
    cheap = select(3, 8, "lowest_cost", latency_model=lm, cost_model=cm)
    assert fast.p > cheap.p   # paper: min-latency = max parallelism
    mid = select(3, 8, "pareto", p=2, latency_model=lm, cost_model=cm)
    assert mid.p == 2


def test_select_agrees_with_select_config(models):
    """Regression: select() used to ignore (t_block, unroll) ties — the
    estimators are blind to them — and return the worst enumeration-order
    candidate, contradicting select_config's overhead tie-break.  Both now
    share one scoring rule, so the DSE output is consistent everywhere."""
    from repro.core.dse import _overhead_share, select_config
    lm, cm = models
    for i_dim, h_dim in ((3, 8), (4, 16)):
        for objective in ("min_latency", "lowest_cost"):
            a = select(i_dim, h_dim, objective, latency_model=lm, cost_model=cm)
            b = select_config(i_dim, h_dim, s_total=a.s_block,
                              dtype=a.dtype_bytes, objective=objective)
            assert a == b, (objective, a, b)
            twins = [t for t in enumerate_candidates(i_dim, h_dim)
                     if (t.p, t.compute_unit, t.dtype_bytes) ==
                        (a.p, a.compute_unit, a.dtype_bytes)]
            if objective == "min_latency":
                # latency ties break toward low control overhead
                assert _overhead_share(a) == min(map(_overhead_share, twins))
            else:
                # cost ties break toward the smallest REAL working set
                assert vmem_bytes(a) == min(map(vmem_bytes, twins))


def test_pareto_front_tie_break_consistent(models):
    """Front representatives for estimator-tied (cost, latency) points are
    the lowest-overhead candidates, not enumeration-order accidents."""
    from repro.core.dse import _overhead_share
    lm, cm = models
    front = pareto_front(enumerate_candidates(3, 8), lm, cm)
    for c, _, _ in front:
        twins = [t for t in enumerate_candidates(3, 8)
                 if (t.p, t.compute_unit, t.dtype_bytes) ==
                    (c.p, c.compute_unit, c.dtype_bytes)]
        assert _overhead_share(c) == min(_overhead_share(t) for t in twins)


@settings(max_examples=30, deadline=None)
@given(i=st.integers(2, 8), h=st.integers(4, 64), p=st.integers(0, 5),
       unit=st.sampled_from(["vpu", "mxu"]), dt=st.sampled_from([2, 4]))
def test_measure_candidate_invariants(i, h, p, unit, dt):
    """Property: measurements are finite, positive; throughput = streams /
    cycles * clock; vmem grows monotonically in every size knob."""
    c = Candidate(i_dim=i, h_dim=h, p=p, compute_unit=unit, dtype_bytes=dt)
    m = measure_candidate(c)
    assert m["cycles_per_step"] > 0 and np.isfinite(m["cycles_per_step"])
    assert m["per_stream_latency_cycles"] * c.s_block == pytest.approx(
        m["cycles_per_step"])
    assert vmem_bytes(c) < vmem_bytes(
        Candidate(i_dim=i, h_dim=h, p=p + 1, compute_unit=unit, dtype_bytes=dt))
