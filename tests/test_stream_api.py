"""Chunked/resumable stream semantics + counter-based fork independence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dse import Candidate
from repro.prng.nist import cross_correlation
from repro.prng.stream import ChaoticPRNG, ChaoticStream, _lineage_counter

from test_kernels import _mk


@pytest.fixture(scope="module")
def params():
    w1, b1, w2, b2, _ = _mk(3, 8, 1)
    return {"w1": w1, "b1": b1, "w2": w2, "b2": b2}


# An mxu config: on CPU the mxu step is bit-identical to the jnp oracle, so
# the cross-backend identity below is exact (vpu differs by fp-order ulps).
MXU_CFG = Candidate(i_dim=3, h_dim=8, p=0, compute_unit="mxu",
                    t_block=32, unroll=1)


def test_same_counter_bit_identical_across_backends(params):
    """Same counter => bit-identical words from 'ref' and 'pallas_interpret'."""
    engines = {
        b: ChaoticPRNG(params, n_streams=128, backend=b, config=MXU_CFG)
        for b in ("ref", "pallas_interpret")
    }
    words = {b: e.next_words(e.init(seed=11), 3000)[0]
             for b, e in engines.items()}
    np.testing.assert_array_equal(words["ref"], words["pallas_interpret"])


@pytest.mark.parametrize("chunks", [[2500], [100, 2400], [1, 1249, 1250],
                                    [337, 1000, 1163]])
def test_chunk_size_invariance(params, chunks):
    """Any chunking of draws emits the same word sequence, bit for bit."""
    eng = ChaoticPRNG(params, n_streams=128, backend="pallas_interpret")
    ref, _ = eng.next_words(eng.init(seed=3), 2500)
    state = eng.init(seed=3)
    parts = []
    for n in chunks:
        w, state = eng.next_words(state, n)
        parts.append(w)
    np.testing.assert_array_equal(np.concatenate(parts), ref)


def test_state_is_a_value_not_a_cursor(params):
    """Drawing twice from the same snapshot replays identically (resume)."""
    eng = ChaoticPRNG(params, n_streams=128, backend="pallas_interpret")
    s0 = eng.init(seed=5)
    _, s1 = eng.next_words(s0, 777)
    a, _ = eng.next_words(s1, 500)
    b, _ = eng.next_words(s1, 500)
    np.testing.assert_array_equal(a, b)


def test_fork_streams_uncorrelated():
    """fork()ed streams pass the cross-correlation check (calibrated: each
    pair test has ~alpha false-positive rate, so allow 1 failure in 18).

    Uses the *trained* Chen oscillator: stream independence is a property
    of the chaotic dynamics (positive Lyapunov exponent), which random
    untrained weights do not provide — their streams partially synchronize.
    """
    from repro.prng.stream import default_params
    eng = ChaoticPRNG(default_params(), n_streams=128,
                      backend="pallas_interpret")
    fails = 0
    for seed in (0, 1, 2):
        parent = eng.init(seed=seed)
        kids = eng.fork(parent, 3)
        streams = [eng.next_words(s, 4000)[0] for s in [parent] + kids]
        for i in range(len(streams)):
            for j in range(i + 1, len(streams)):
                res = cross_correlation(streams[i], streams[j])
                fails += res["p_value"] < 0.01
    assert fails <= 1, fails


def test_fork_is_counter_based(params):
    """Children depend only on (seed, path), not on parent draw position."""
    eng = ChaoticPRNG(params, n_streams=128, backend="pallas_interpret")
    fresh = eng.init(seed=9)
    _, advanced = eng.next_words(fresh, 5000)
    kids_fresh = eng.fork(fresh, 2)
    kids_late = eng.fork(advanced, 2)
    for a, b in zip(kids_fresh, kids_late):
        wa, _ = eng.next_words(a, 600)
        wb, _ = eng.next_words(b, 600)
        np.testing.assert_array_equal(wa, wb)
    assert _lineage_counter(9, (0,)) != _lineage_counter(9, (1,))


def test_draw_words_drops_full_burn_in(params):
    """Regression: draw_words generates 2*burn_in burn-in steps and must
    drop ALL of them (a precedence bug kept half: `2 * burn_in // 2`),
    otherwise early words come from a seed-correlated prefix."""
    from repro.kernels import ops
    from repro.prng.stream import _splitmix_seeds, draw_words
    n_streams, burn_in, n_words = 64, 8, 500
    got = draw_words(params["w1"], params["b1"], params["w2"], params["b2"],
                     3, n_words, n_streams, burn_in, "relu", "pallas_interpret")
    x0 = _splitmix_seeds(jnp.asarray(3, jnp.uint32), n_streams, 3)
    steps = 2 * (-(-n_words // n_streams)) + 2 * burn_in
    traj = ops.chaotic_trajectory(params, x0, steps,
                                  activation="relu",
                                  backend="pallas_interpret")
    want = ops.bits_from_trajectory(traj[2 * burn_in:]).reshape(-1)[:n_words]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_weight_registry_disk_cache(tmp_path, monkeypatch):
    """trained_oscillator caches per system on disk and reloads the exact
    bundle (the per-system registry behind the farm)."""
    import repro.prng.stream as stream
    monkeypatch.setenv("REPRO_WEIGHTS_DIR", str(tmp_path))
    monkeypatch.setattr(stream, "_WEIGHTS_CACHE", {})
    monkeypatch.setattr(stream, "_TRAIN_EPOCHS", 2)      # speed: cache, not R2
    monkeypatch.setattr(stream, "_TRAIN_SAMPLES", 2000)
    a = stream.trained_oscillator("rossler")
    assert (tmp_path / "rossler.npz").exists()
    assert set(a) >= {"w1", "b1", "w2", "b2", "scale", "offset"}
    monkeypatch.setattr(stream, "_WEIGHTS_CACHE", {})    # force disk reload
    b = stream.trained_oscillator("rossler")
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    with pytest.raises(KeyError):
        stream.trained_oscillator("no_such_system")


def test_chaotic_stream_wrapper_compat(params):
    """The legacy wrapper draws through the resumable engine."""
    s = ChaoticStream.from_trained(params, n_streams=64)
    u = np.asarray(s.uniform((500,)))
    assert 0.0 <= u.min() and u.max() < 1.0
    a = np.asarray(s.bits(100))
    b = np.asarray(s.bits(100))
    assert not np.array_equal(a, b)        # counter advances
    kids = s.fork(2)
    ka = np.asarray(kids[0].bits(100))
    kb = np.asarray(kids[1].bits(100))
    assert not np.array_equal(ka, kb)
    assert isinstance(kids[0], ChaoticStream)
    assert dataclasses.asdict(kids[0])["n_streams"] == 64
