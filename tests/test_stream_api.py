"""Chunked/resumable stream semantics + counter-based fork independence."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.dse import Candidate
from repro.prng.nist import cross_correlation
from repro.prng.stream import ChaoticPRNG, ChaoticStream, _lineage_counter

from test_kernels import _mk


@pytest.fixture(scope="module")
def params():
    w1, b1, w2, b2, _ = _mk(3, 8, 1)
    return {"w1": w1, "b1": b1, "w2": w2, "b2": b2}


# An mxu config: on CPU the mxu step is bit-identical to the jnp oracle, so
# the cross-backend identity below is exact (vpu differs by fp-order ulps).
MXU_CFG = Candidate(i_dim=3, h_dim=8, p=0, compute_unit="mxu",
                    t_block=32, unroll=1)


def test_same_counter_bit_identical_across_backends(params):
    """Same counter => bit-identical words from 'ref' and 'pallas_interpret'."""
    engines = {
        b: ChaoticPRNG(params, n_streams=128, backend=b, config=MXU_CFG)
        for b in ("ref", "pallas_interpret")
    }
    words = {b: e.next_words(e.init(seed=11), 3000)[0]
             for b, e in engines.items()}
    np.testing.assert_array_equal(words["ref"], words["pallas_interpret"])


@pytest.mark.parametrize("chunks", [[2500], [100, 2400], [1, 1249, 1250],
                                    [337, 1000, 1163]])
def test_chunk_size_invariance(params, chunks):
    """Any chunking of draws emits the same word sequence, bit for bit."""
    eng = ChaoticPRNG(params, n_streams=128, backend="pallas_interpret")
    ref, _ = eng.next_words(eng.init(seed=3), 2500)
    state = eng.init(seed=3)
    parts = []
    for n in chunks:
        w, state = eng.next_words(state, n)
        parts.append(w)
    np.testing.assert_array_equal(np.concatenate(parts), ref)


def test_state_is_a_value_not_a_cursor(params):
    """Drawing twice from the same snapshot replays identically (resume)."""
    eng = ChaoticPRNG(params, n_streams=128, backend="pallas_interpret")
    s0 = eng.init(seed=5)
    _, s1 = eng.next_words(s0, 777)
    a, _ = eng.next_words(s1, 500)
    b, _ = eng.next_words(s1, 500)
    np.testing.assert_array_equal(a, b)


def test_fork_streams_uncorrelated():
    """fork()ed streams pass the cross-correlation check (calibrated: each
    pair test has ~alpha false-positive rate, so allow 1 failure in 18).

    Uses the *trained* Chen oscillator: stream independence is a property
    of the chaotic dynamics (positive Lyapunov exponent), which random
    untrained weights do not provide — their streams partially synchronize.
    """
    from repro.prng.stream import default_params
    eng = ChaoticPRNG(default_params(), n_streams=128,
                      backend="pallas_interpret")
    fails = 0
    for seed in (0, 1, 2):
        parent = eng.init(seed=seed)
        kids = eng.fork(parent, 3)
        streams = [eng.next_words(s, 4000)[0] for s in [parent] + kids]
        for i in range(len(streams)):
            for j in range(i + 1, len(streams)):
                res = cross_correlation(streams[i], streams[j])
                fails += res["p_value"] < 0.01
    assert fails <= 1, fails


def test_fork_is_counter_based(params):
    """Children depend only on (seed, path), not on parent draw position."""
    eng = ChaoticPRNG(params, n_streams=128, backend="pallas_interpret")
    fresh = eng.init(seed=9)
    _, advanced = eng.next_words(fresh, 5000)
    kids_fresh = eng.fork(fresh, 2)
    kids_late = eng.fork(advanced, 2)
    for a, b in zip(kids_fresh, kids_late):
        wa, _ = eng.next_words(a, 600)
        wb, _ = eng.next_words(b, 600)
        np.testing.assert_array_equal(wa, wb)
    assert _lineage_counter(9, (0,)) != _lineage_counter(9, (1,))


def test_chaotic_stream_wrapper_compat(params):
    """The legacy wrapper draws through the resumable engine."""
    s = ChaoticStream.from_trained(params, n_streams=64)
    u = np.asarray(s.uniform((500,)))
    assert 0.0 <= u.min() and u.max() < 1.0
    a = np.asarray(s.bits(100))
    b = np.asarray(s.bits(100))
    assert not np.array_equal(a, b)        # counter advances
    kids = s.fork(2)
    ka = np.asarray(kids[0].bits(100))
    kb = np.asarray(kids[1].bits(100))
    assert not np.array_equal(ka, kb)
    assert isinstance(kids[0], ChaoticStream)
    assert dataclasses.asdict(kids[0])["n_streams"] == 64
