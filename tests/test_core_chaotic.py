"""Chaotic systems, RK-4 integrator and op-count models (paper §II, Table I)."""
import jax.numpy as jnp
import numpy as np
import pytest
from scipy.integrate import odeint

from repro.core.chaotic import (SYSTEMS, ann_op_counts, get_system, integrate,
                                make_dataset, rk4_op_counts, rk4_step)


def test_table1_op_counts():
    # Paper Table I: ANN 3-8-3 = 48 mul / 59 add; RK-4 + Chen = 60 mul / 59 add
    assert ann_op_counts((3, 8, 3)) == (48, 59)
    assert rk4_op_counts(get_system("chen")) == (60, 59)


def test_eq7_general_ann():
    # 3-16-3: 3*16 + 16*3 = 96 muls; 16*(3+1) + 3*(16+1) = 115 adds
    assert ann_op_counts((3, 16, 3)) == (96, 115)
    assert ann_op_counts((3, 4, 3)) == (24, 31)


@pytest.mark.parametrize("name", sorted(SYSTEMS))
def test_rk4_matches_scipy(name):
    """Our jitted RK-4 tracks scipy.odeint over a short horizon."""
    sys_ = get_system(name)
    n_steps, dt = 200, sys_.dt
    x0 = np.asarray(sys_.x0, np.float64)
    ours = np.asarray(integrate(name, jnp.asarray(x0, jnp.float32), n_steps, dt))

    f = lambda x, t: np.asarray(sys_.f(jnp.asarray(x, jnp.float32)), np.float64)
    ts = np.arange(n_steps + 1) * dt
    ref = odeint(f, x0, ts, rtol=1e-10, atol=1e-10)
    # fp32 fixed-step RK4 vs fp64 adaptive: agreement degrades with horizon;
    # compare over the first quarter where divergence hasn't amplified.
    q = n_steps // 4
    scale = np.maximum(np.abs(ref[:q]).max(axis=0), 1.0)
    err = np.abs(ours[:q] - ref[:q]) / scale
    assert err.max() < 5e-3, f"{name}: rel err {err.max()}"


def test_rk4_convergence_order():
    """Halving dt reduces one-step error ~16x (4th order)."""
    sys_ = get_system("lorenz")
    x0 = jnp.asarray(sys_.x0, jnp.float64)
    f64 = lambda x: sys_.f(x).astype(jnp.float64)

    def two_halves(dt):
        x = rk4_step(f64, x0, dt)
        return x

    dt = 0.02
    ref = rk4_step(f64, rk4_step(f64, x0, 1e-4), 1e-4)  # not used as oracle
    # oracle: very fine steps
    fine = x0
    for _ in range(1000):
        fine = rk4_step(f64, fine, dt / 1000)
    e1 = float(jnp.abs(two_halves(dt) - fine).max())
    half = rk4_step(f64, rk4_step(f64, x0, dt / 2), dt / 2)
    e2 = float(jnp.abs(half - fine).max())
    ratio = e1 / max(e2, 1e-16)
    assert ratio > 8, f"RK4 order check: ratio {ratio}"


def test_batched_integration():
    x0 = jnp.asarray(np.random.default_rng(0).normal(size=(8, 3)), jnp.float32) * 0.1
    traj = integrate("chen", x0, 50)
    assert traj.shape == (51, 8, 3)
    assert bool(jnp.all(jnp.isfinite(traj)))


def test_dataset_shapes_and_split():
    ds = make_dataset("chen", n_samples=5000, train_frac=0.8)
    assert ds.x_train.shape == (4000, 3) and ds.x_test.shape == (1000, 3)
    # normalized into [-1, 1]
    assert ds.x_train.min() >= -1.0 - 1e-6 and ds.x_train.max() <= 1.0 + 1e-6
    # each labelled pair is (X_t, X_{t+1}): y must be reachable by one rk4 step
    assert np.isfinite(ds.y_train).all()


def test_dataset_pairs_consistent():
    """y = normalized rk4_step(denormalized x) for every pair."""
    ds = make_dataset("lorenz", n_samples=2000)
    sys_ = get_system("lorenz")
    x = ds.x_train[:100] * ds.scale + ds.offset
    y_ref = np.asarray(rk4_step(sys_.f, jnp.asarray(x), ds.dt))
    y_ref = (y_ref - ds.offset) / ds.scale
    np.testing.assert_allclose(ds.y_train[:100], y_ref, atol=2e-5)
