"""Block-coupled oscillator lattices, end to end.

The lattice is the stack's escape from quadratic hardware scaling
(ROADMAP "Coupled-oscillator lattices"): N copies of a base chaotic
system coupled diffusively on a ring/torus, state dim N * d, Jacobian
block-sparse — never a dense N^2 operator.  These tests pin the whole
route: the ODE-level coupling structure, the block-diagonal parameter
expansion, bitwise ref-vs-Pallas identity for BOTH compute units, fork
non-overlap and gang bit-identity at lattice dims, the stacked-layout
VMEM cliff (planner falls back to lane-concat past it), registry-derived
lattice bundles, farm serving next to scalar cores — plus the burn-in
parity identity fixes that rode along in this change.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ann import expand_lattice_params, lattice_meta_tuple
from repro.core.chaotic import (DEFAULT_LATTICE_COUPLING, get_system,
                                lattice, lattice_coupling_matrix,
                                parse_lattice_name)
from repro.core.dse import (VMEM_USABLE, Candidate, select_config,
                            stacked_gang_vmem_bytes)
from repro.kernels import ops

from test_kernels import _mk

N, D, H = 8, 3, 8                 # 8-node chen-shaped ring: I = 24, H = 64
I_LAT, H_LAT = N * D, N * H

# Small-block config keeping interpret-mode kernel bodies cheap to compile
# (trace cost grows ~quadratically with t_block * (I + H) unrolled ops).
CFG = Candidate(i_dim=I_LAT, h_dim=H_LAT, p=0, compute_unit="vpu",
                dtype_bytes=4, t_block=8, unroll=2)
CFG_MXU = Candidate(i_dim=I_LAT, h_dim=H_LAT, p=0, compute_unit="mxu",
                    dtype_bytes=4, t_block=8, unroll=2)


def _base_params(key=0):
    w1, b1, w2, b2, _ = _mk(D, H, 1, key=key)
    return {"w1": w1, "b1": b1, "w2": w2, "b2": b2}


def _lat_params(n_nodes=N, topology="ring", key=0,
                coupling=DEFAULT_LATTICE_COUPLING):
    return expand_lattice_params(_base_params(key), n_nodes=n_nodes,
                                 coupling=coupling, topology=topology)


def _f32(a):
    return np.asarray(jnp.asarray(a, jnp.float32))


# ---------------------------------------------------------------------------
# ODE level: coupling structure
# ---------------------------------------------------------------------------

def test_lattice_coupling_matrix_is_block_sparse_laplacian():
    """C = strength * (A - deg I) (x) I_d: zero row sums (diffusive — a
    synchronized lattice feels no coupling force), symmetric for the ring,
    and only diagonal + nearest-neighbour d x d blocks are nonzero."""
    n, d, s = 6, 3, 0.07
    C = lattice_coupling_matrix(n, d, s)
    assert C.shape == (n * d, n * d)
    np.testing.assert_allclose(C.sum(axis=1), 0.0, atol=1e-6)
    np.testing.assert_allclose(C, C.T, atol=1e-7)
    for a in range(n):
        for b in range(n):
            blk = C[a * d:(a + 1) * d, b * d:(b + 1) * d]
            ring_dist = min((a - b) % n, (b - a) % n)
            if ring_dist == 0:
                np.testing.assert_allclose(blk, -2 * s * np.eye(d),
                                           atol=1e-7)
            elif ring_dist == 1:
                np.testing.assert_allclose(blk, s * np.eye(d), atol=1e-7)
            else:
                assert not blk.any(), f"non-neighbour block ({a},{b}) nonzero"


def test_lattice_ode_is_base_dynamics_plus_coupling():
    sys_ = lattice("chen", 4, coupling=0.05)
    base = get_system("chen")
    assert sys_.dim == 12 and sys_.name == "chen@ring4"
    x = jnp.asarray(np.random.default_rng(0).uniform(-1, 1, 12), jnp.float32)
    C = lattice_coupling_matrix(4, 3, 0.05)
    dyn = jnp.concatenate([base.f(x[i * 3:(i + 1) * 3]) for i in range(4)])
    want = np.asarray(dyn) + C @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(sys_.f(x)), want, rtol=1e-5,
                               atol=1e-5)
    # block-sparse op counts: O(n_nodes), never N^2
    assert sys_.n_mul_dynamic == 4 * base.n_mul_dynamic + 12
    assert sys_.n_add_dynamic == 4 * base.n_add_dynamic + 12 * 2


def test_parse_lattice_name_and_topology_routing():
    assert parse_lattice_name("chen@grid9") == ("chen", "grid", 9)
    assert get_system("chen@ring8").dim == 24
    for bad in ("chen@spiral4", "chen@ring", "chen@4"):
        with pytest.raises(KeyError):
            parse_lattice_name(bad)
    # grid names must build grids (regression: topology was once dropped)
    ring = lattice_coupling_matrix(4, 3, 0.05, "ring")
    grid = lattice_coupling_matrix(4, 3, 0.05, "grid")
    assert not np.array_equal(ring, grid)
    np.testing.assert_allclose(grid.sum(axis=1), 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# Parameter expansion
# ---------------------------------------------------------------------------

def test_expand_lattice_params_block_diagonal():
    base = _base_params()
    p = _lat_params()
    w1 = np.asarray(p["w1"])
    assert w1.shape == (I_LAT, H_LAT)
    for a in range(N):
        for b in range(N):
            blk = w1[a * D:(a + 1) * D, b * H:(b + 1) * H]
            if a == b:
                np.testing.assert_array_equal(blk, np.asarray(base["w1"]))
            else:
                assert not blk.any()
    np.testing.assert_array_equal(np.asarray(p["b1"]),
                                  np.tile(np.asarray(base["b1"]), N))
    np.testing.assert_array_equal(
        np.asarray(p["coupling"]),
        lattice_coupling_matrix(N, D, DEFAULT_LATTICE_COUPLING))
    got_meta = lattice_meta_tuple(p["lattice_meta"])
    assert got_meta[:3] == (N, D, "ring")
    assert got_meta[3] == pytest.approx(DEFAULT_LATTICE_COUPLING)


def test_expand_lattice_params_validation():
    base = _base_params()
    with pytest.raises(ValueError, match="n_nodes"):
        expand_lattice_params(base, n_nodes=1, coupling=0.05)
    with pytest.raises(ValueError, match="8"):
        # 3 nodes x 3 dims = 9 state rows: not sublane-packable
        expand_lattice_params(base, n_nodes=3, coupling=0.05)


# ---------------------------------------------------------------------------
# Kernel level: ref-vs-Pallas bit-identity, both units, both dtypes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cfg", [CFG, CFG_MXU], ids=["vpu", "mxu"])
def test_lattice_ref_vs_pallas_bit_identical(dtype, cfg):
    """The lattice oracle scans the kernels' own step closure, so
    ref == Pallas is EXACT for both compute units (not to ulps)."""
    params = _lat_params()
    x0 = _mk(I_LAT, H_LAT, 128, key=5)[4].astype(dtype)
    got = ops.chaotic_trajectory(params, x0, 64,
                                 backend="pallas_interpret", config=cfg)
    want = ops.chaotic_trajectory(params, x0, 64, backend="ref", config=cfg)
    np.testing.assert_array_equal(_f32(got), _f32(want))
    # fused words ride the same trajectory: ref packing == fused kernel
    gw, gs = ops.chaotic_bits(params, x0, 64, backend="pallas_interpret",
                              config=cfg)
    ww, ws = ops.chaotic_bits(params, x0, 64, backend="ref", config=cfg)
    np.testing.assert_array_equal(np.asarray(gw), np.asarray(ww))
    np.testing.assert_array_equal(_f32(gs), _f32(ws))


def test_lattice_grid_topology_bit_identical_and_distinct():
    params_g = _lat_params(n_nodes=8, topology="grid")
    x0 = _mk(I_LAT, H_LAT, 128, key=7)[4]
    got = ops.chaotic_trajectory(params_g, x0, 32,
                                 backend="pallas_interpret", config=CFG)
    want = ops.chaotic_trajectory(params_g, x0, 32, backend="ref",
                                  config=CFG)
    np.testing.assert_array_equal(_f32(got), _f32(want))
    ring = ops.chaotic_trajectory(_lat_params(), x0, 32,
                                  backend="pallas_interpret", config=CFG)
    assert not np.array_equal(_f32(got), _f32(ring))


def test_lattice_mxu_requires_coupling_operand():
    params = _lat_params()
    bare = {k: params[k] for k in ("w1", "b1", "w2", "b2")}
    bare["lattice_meta"] = params["lattice_meta"]
    x0 = _mk(I_LAT, H_LAT, 128, key=3)[4]
    with pytest.raises(KeyError):
        ops.chaotic_trajectory(bare, x0, 32, backend="pallas_interpret",
                               config=CFG_MXU)


# ---------------------------------------------------------------------------
# Stream level: fork non-overlap at lattice dims
# ---------------------------------------------------------------------------

def test_lattice_fork_children_non_overlapping():
    from repro.prng.stream import ChaoticPRNG
    eng = ChaoticPRNG(_lat_params(), n_streams=128, burn_in=16,
                      backend="pallas_interpret", config=CFG)
    assert eng.config.compute_unit == "vpu"
    root = eng.init(seed=1)
    kids = eng.fork(root, 3)
    words = [eng.next_words(k, 2048)[0] for k in kids]
    for a in range(3):
        for b in range(a + 1, 3):
            assert not np.array_equal(words[a], words[b])
            # positionally, independent uniform words agree w.p. 2^-32
            assert np.mean(words[a] == words[b]) < 0.01
    # forking never consumed the parent: its words are fork-invariant
    w_parent, _ = eng.next_words(root, 256)
    w_again, _ = eng.next_words(eng.init(seed=1), 256)
    np.testing.assert_array_equal(w_parent, w_again)


def test_lattice_engine_autoselects_with_n_nodes():
    """Engine-level select_config must see the lattice: the candidate is
    lattice-aware (n_nodes threaded), not a scalar-core config."""
    from repro.prng.stream import ChaoticPRNG
    eng = ChaoticPRNG(_lat_params(), n_streams=128,
                      backend="pallas_interpret")
    assert eng.config.n_nodes == N
    assert eng.config.i_dim == I_LAT


# ---------------------------------------------------------------------------
# Gang level: >= 24-member bit-identity, both layouts
# ---------------------------------------------------------------------------

def test_lattice_stacked_gang_24_members_bit_identical():
    """One sublane-stacked launch of 24 lattice cores (shared coupling
    operand semantics, distinct per-core weights) == 24 solo lattice
    launches, words AND final states, with per-lane word offsets."""
    C, S, n_steps = 24, 128, 64
    plist = [_lat_params(key=k) for k in range(C)]
    gang = {k: jnp.stack([jnp.asarray(p[k]) for p in plist])
            for k in ("w1", "b1", "w2", "b2")}
    gang["coupling"] = jnp.asarray(plist[0]["coupling"])
    gang["lattice_meta"] = jnp.asarray(plist[0]["lattice_meta"])
    x0 = _mk(I_LAT, H_LAT, C * S, key=9)[4].reshape(C, S, I_LAT)
    offs = np.random.default_rng(3).integers(
        0, 10_000, size=(C, S)).astype(np.uint32)
    gw, gs = ops.chaotic_bits_gang_stacked(
        gang, x0, n_steps, jnp.asarray(offs),
        backend="pallas_interpret", config=CFG)
    gw, gs = np.asarray(gw), _f32(gs)
    for ci in range(C):
        w, s = ops.chaotic_bits(plist[ci], x0[ci], n_steps,
                                jnp.asarray(offs[ci]),
                                backend="pallas_interpret", config=CFG)
        np.testing.assert_array_equal(gw[:, ci, :], np.asarray(w))
        np.testing.assert_array_equal(gs[ci], _f32(s))


def test_lattice_concat_gang_mxu_bit_identical():
    """The lane-concat gang on the mxu path shares ONE (I, I) coupling
    operand across the group; words must equal solo mxu launches."""
    C, S, n_steps = 3, 128, 64
    plist = [_lat_params(key=10 + k) for k in range(C)]
    gang = {k: jnp.stack([jnp.asarray(p[k]) for p in plist])
            for k in ("w1", "b1", "w2", "b2")}
    gang["coupling"] = jnp.asarray(plist[0]["coupling"])
    gang["lattice_meta"] = jnp.asarray(plist[0]["lattice_meta"])
    core_map = np.asarray([0, 1, 2], np.int32)
    x0 = _mk(I_LAT, H_LAT, C * S, key=11)[4]
    offs = jnp.zeros(C * S, jnp.uint32)
    gw, gs = ops.chaotic_bits_gang(
        gang, x0, n_steps, offs, core_map=core_map,
        backend="pallas_interpret", config=CFG_MXU)
    for ci in range(C):
        sl = slice(ci * S, (ci + 1) * S)
        w, s = ops.chaotic_bits(plist[ci], x0[sl], n_steps,
                                backend="pallas_interpret", config=CFG_MXU)
        np.testing.assert_array_equal(np.asarray(gw)[:, sl], np.asarray(w))
        np.testing.assert_array_equal(_f32(gs)[sl], _f32(s))


# ---------------------------------------------------------------------------
# Planner: the stacked-layout VMEM cliff
# ---------------------------------------------------------------------------

def test_stacked_vmem_cliff_planner_falls_back_to_concat():
    """Past the core count where one stacked launch exceeds the VMEM
    budget, the planner must stop choosing the sublane-stacked layout
    and fall back to lane-concat — same words, feasible launch."""
    from repro.serve.farm import GangScheduler

    # engineered cliff: wide lanes + deep unroll put one core's resident
    # stack in the tens of MB, so the cliff lands at a handful of cores
    cand = Candidate(i_dim=I_LAT, h_dim=H_LAT, p=5, compute_unit="vpu",
                     dtype_bytes=4, unroll=8, t_block=256)
    cliff = 1
    while stacked_gang_vmem_bytes(cand, cliff) <= VMEM_USABLE:
        cliff += 1
        assert cliff < 64, "engineered candidate never crossed the budget"
    assert cliff >= 2, "candidate must fit at least one core stacked"

    class _FakeSvc:
        mesh = None
        mesh_axis = "data"

        def __init__(self, c, s):
            self.config = c
            self.pool_x = np.zeros((s, c.i_dim), np.float32)

    def decide(n_cores):
        sched = GangScheduler(planner=True)
        members = [(f"c{i}", _FakeSvc(cand, cand.s_block), 8, None)
                   for i in range(n_cores)]
        return sched._decide(("k",), members, demands=(16,) * n_cores)

    below = decide(cliff - 1)
    assert below["parts"][0]["layout"] == "stacked"
    above = decide(cliff)
    assert above["parts"][0]["layout"] == "concat"


# ---------------------------------------------------------------------------
# Registry + farm serving
# ---------------------------------------------------------------------------

def test_lattice_registry_bundle_derived_from_base():
    """A lattice bundle is a pure function of the base registry entry:
    block-diagonal expansion + tiled normalizers, never retrained or
    persisted separately."""
    from repro.prng.stream import trained_oscillator
    b = trained_oscillator("chen@ring8")
    base = trained_oscillator("chen")
    d, h = base["w1"].shape
    assert b["w1"].shape == (8 * d, 8 * h)
    np.testing.assert_array_equal(b["w1"][:d, :h], base["w1"])
    assert not b["w1"][:d, h:].any()
    np.testing.assert_array_equal(b["scale"], np.tile(base["scale"], 8))
    np.testing.assert_array_equal(b["offset"], np.tile(base["offset"], 8))
    meta = lattice_meta_tuple(b["lattice_meta"])
    assert meta[:3] == (8, d, "ring")
    assert meta[3] == pytest.approx(DEFAULT_LATTICE_COUPLING)
    # RAM-cached: the same object comes back, not a recomputation
    assert trained_oscillator("chen@ring8") is b


def test_farm_serves_lattice_cores_next_to_scalars():
    """Two same-meta lattice cores gang with each other (one stacked
    launch), never with the scalar core; delivered words are bit-identical
    to a gang=False farm."""
    from repro.serve.farm import OscillatorFarm, _compat_key

    scal = _base_params(key=4)
    scal_cfg = Candidate(i_dim=D, h_dim=H, p=0, compute_unit="vpu",
                         dtype_bytes=4, t_block=32, unroll=2)

    def build(gang):
        farm = OscillatorFarm(gang=gang)
        farm.add_core("lat_a", _lat_params(key=1), config=CFG,
                      lanes_per_client=128, backend="pallas_interpret")
        farm.add_core("lat_b", _lat_params(key=2), config=CFG,
                      lanes_per_client=128, backend="pallas_interpret")
        farm.add_core("chen", scal, config=scal_cfg,
                      lanes_per_client=128, backend="pallas_interpret")
        for core in farm.cores:
            farm.register(core, "t", seed=5)
        return farm

    ganged, solo = build(True), build(False)
    keys = {c: _compat_key(ganged.services[c]) for c in ganged.cores}
    assert keys["lat_a"] == keys["lat_b"]
    assert keys["lat_a"] != keys["chen"]

    for _ in range(2):
        for farm in (ganged, solo):
            for core in farm.cores:
                farm.request(core, "t", 4096)
        out_g, out_s = ganged.flush(), solo.flush()
        for core in ganged.cores:
            np.testing.assert_array_equal(out_g[core]["t"],
                                          out_s[core]["t"])
    assert ganged.gang_launches >= 1


# ---------------------------------------------------------------------------
# Burn-in parity identity (the satellite bugfix)
# ---------------------------------------------------------------------------

def test_odd_burn_in_warns_and_records_effective_value():
    from repro.prng.stream import (ChaoticPRNG, effective_burn_in,
                                   registry_fingerprint)
    with pytest.warns(UserWarning, match="rounded up"):
        assert effective_burn_in(15) == 16
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert effective_burn_in(16) == 16
        assert effective_burn_in(0) == 0
    with pytest.raises(ValueError):
        effective_burn_in(-2)

    params = _base_params()
    cfg = Candidate(i_dim=D, h_dim=H, p=0, compute_unit="vpu",
                    dtype_bytes=4, t_block=32, unroll=1)
    with pytest.warns(UserWarning, match="burn_in"):
        odd = ChaoticPRNG(params, n_streams=128, burn_in=15,
                          backend="pallas_interpret", config=cfg)
    even = ChaoticPRNG(params, n_streams=128, burn_in=16,
                       backend="pallas_interpret", config=cfg)
    assert odd.burn_in == 16
    st = odd.init(seed=0)
    assert st.burn_in == 16                 # the stream records what RAN
    w_odd, st2 = odd.next_words(st, 256)
    w_even, _ = even.next_words(even.init(seed=0), 256)
    np.testing.assert_array_equal(w_odd, w_even)
    assert st2.burn_in == 16                # carried through draws

    # the fingerprint distinguishes effective burn-ins — and only those
    assert (registry_fingerprint("chen", 16)
            != registry_fingerprint("chen", 18))
    with pytest.warns(UserWarning):
        same = registry_fingerprint("chen", 15)
    assert same == registry_fingerprint("chen", 16)
    # None keeps legacy stamps byte-stable
    assert registry_fingerprint("chen") == registry_fingerprint("chen")


def test_service_snapshot_burn_in_identity_guard():
    from repro.serve.prng_service import PRNGService

    params = _base_params()
    cfg = Candidate(i_dim=D, h_dim=H, p=0, compute_unit="vpu",
                    dtype_bytes=4, t_block=32, unroll=1)

    def mk(burn_in):
        return PRNGService(params, lanes_per_client=128, burn_in=burn_in,
                           backend="pallas_interpret", config=cfg)

    svc = mk(16)
    svc.register("a", seed=1)
    snap = svc.snapshot()
    assert snap["burn_in"] == 16

    other = mk(18)
    with pytest.raises(ValueError, match="burn"):
        other.restore(snap)

    # round trip on a matching service is exact
    twin = mk(16)
    twin.restore(snap)
    np.testing.assert_array_equal(twin.draw("a", 64), svc.draw("a", 64))

    # legacy snapshots (no burn_in recorded) still restore
    legacy = dict(snap)
    legacy.pop("burn_in")
    mk(18).restore(legacy)
