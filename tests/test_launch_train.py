"""The production launcher end-to-end at smoke scale (2x2 debug mesh)."""
import subprocess
import sys


def test_launch_train_smoke(tmp_path):
    script = (
        "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=4';"
        "from repro.launch.train import main;"
        f"r = main(['--arch','llama3.2-3b','--smoke','--steps','6',"
        f"'--ckpt-dir','{tmp_path}','--ckpt-every','3','--chaotic-shuffle']);"
        "assert int(r.final_state.step) == 6"
    )
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu", "HOME": "/root"})
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-3000:])


def test_launch_train_resume(tmp_path):
    base = (
        "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=4';"
        "from repro.launch.train import main;"
    )
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu", "HOME": "/root"}
    r1 = subprocess.run([sys.executable, "-c", base +
                         f"main(['--arch','rwkv6-1.6b','--smoke','--steps','3',"
                         f"'--ckpt-dir','{tmp_path}','--ckpt-every','3'])"],
                        capture_output=True, text=True, timeout=560, env=env)
    assert r1.returncode == 0, r1.stderr[-3000:]
    r2 = subprocess.run([sys.executable, "-c", base +
                         f"r = main(['--arch','rwkv6-1.6b','--smoke','--steps','6',"
                         f"'--ckpt-dir','{tmp_path}','--ckpt-every','3']);"
                         "assert r.resumed_from == 3, r.resumed_from"],
                        capture_output=True, text=True, timeout=560, env=env)
    assert r2.returncode == 0, r2.stderr[-3000:]
