"""Weight-registry lifecycle: fingerprint versioning + invalidation.

A registry entry is train-once, serve-forever — unless the training
recipe or the jax version changes, in which case the stamped fingerprint
no longer matches and ``trained_oscillator`` must retrain instead of
serving stale weights.
"""
import numpy as np
import pytest

import repro.prng.stream as stream
from repro.prng.stream import (_FINGERPRINT_KEY, registry_fingerprint,
                               trained_oscillator)


@pytest.fixture()
def fast_registry(tmp_path, monkeypatch):
    """Isolated on-disk registry with a cheap training recipe."""
    monkeypatch.setenv("REPRO_WEIGHTS_DIR", str(tmp_path))
    monkeypatch.setattr(stream, "_TRAIN_EPOCHS", 2)
    monkeypatch.setattr(stream, "_TRAIN_SAMPLES", 512)
    monkeypatch.setattr(stream, "_WEIGHTS_CACHE", {})
    return tmp_path


def test_registry_entries_are_stamped(fast_registry):
    trained_oscillator("chen")
    saved = dict(np.load(fast_registry / "chen.npz"))
    assert str(saved[_FINGERPRINT_KEY]) == registry_fingerprint("chen")


def test_fresh_stamp_serves_from_disk(fast_registry, monkeypatch):
    trained_oscillator("chen")
    monkeypatch.setattr(stream, "_WEIGHTS_CACHE", {})

    def boom(*a, **kw):
        raise AssertionError("retrained despite a fresh stamp")
    monkeypatch.setattr("repro.core.ann.train", boom)
    trained_oscillator("chen")                     # disk hit, no training


@pytest.mark.parametrize("staleness", ["recipe_change", "missing_stamp"])
def test_stale_or_unstamped_entry_retrains(fast_registry, monkeypatch,
                                           staleness):
    bundle = trained_oscillator("chen")
    if staleness == "recipe_change":
        # the recipe the weights were trained under no longer matches
        monkeypatch.setattr(stream, "_TRAIN_EPOCHS", 3)
    else:
        # pre-versioning file: no stamp at all
        saved = dict(np.load(fast_registry / "chen.npz"))
        saved.pop(_FINGERPRINT_KEY)
        np.savez(fast_registry / "chen.npz", **saved)
    monkeypatch.setattr(stream, "_WEIGHTS_CACHE", {})

    calls = []
    real_train = __import__("repro.core.ann", fromlist=["train"]).train

    def spy(*a, **kw):
        calls.append(1)
        return real_train(*a, **kw)
    monkeypatch.setattr("repro.core.ann.train", spy)
    again = trained_oscillator("chen")
    assert calls, "stale registry entry was served instead of retrained"
    # and the re-published entry carries the new stamp
    saved = dict(np.load(fast_registry / "chen.npz"))
    assert str(saved[_FINGERPRINT_KEY]) == registry_fingerprint("chen")
    assert set(bundle) == set(again)


def test_fingerprint_depends_on_recipe(monkeypatch):
    a = registry_fingerprint("chen")
    monkeypatch.setattr(stream, "_TRAIN_LR", 1e-4)
    assert registry_fingerprint("chen") != a
    assert registry_fingerprint("chen") != registry_fingerprint("lorenz")
