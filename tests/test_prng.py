"""Chaotic PRNG streams + NIST SP 800-22 subset (paper's PRNG claim)."""
import numpy as np
import pytest

from repro.prng import ChaoticStream, default_stream, run_nist_subset
from repro.prng.nist import ALL_TESTS, _to_bits


@pytest.fixture(scope="module")
def stream():
    return default_stream(n_streams=256)


def test_nist_calibration_on_numpy_rng():
    """The suite must pass a known-good RNG.  At alpha=0.01 each test has a
    ~1% false-positive rate by design, so calibrate statistically: across 10
    seeds x 7 tests, at most 3 failures (P[>3 | p_fp=0.01] < 1e-4)."""
    fails = 0
    for seed in range(10):
        rng = np.random.default_rng(seed)
        words = rng.integers(0, 2 ** 32, size=30_000, dtype=np.uint32)
        res = run_nist_subset(words)
        fails += sum(not v["passed"] for v in res.values())
    assert fails <= 3, fails


def test_nist_rejects_constant_and_periodic():
    res = run_nist_subset(np.zeros(10_000, dtype=np.uint32))
    assert not res["monobit"]["passed"]
    res = run_nist_subset(np.full(10_000, 0xAAAAAAAA, dtype=np.uint32))
    # perfectly balanced bits but trivially periodic: serial/apen must fail
    assert not (res["serial"]["passed"] and res["approximate_entropy"]["passed"])


def test_chaotic_stream_passes_nist(stream):
    """Paper §II cites ANN chaotic PRNGs passing NIST; we verify the subset
    on 1.28 Mbit of emitted words."""
    words = np.asarray(stream.bits(40_000))
    res = run_nist_subset(words)
    failed = {k: v for k, v in res.items() if not v["passed"]}
    assert not failed, failed


def test_stream_determinism():
    s1 = default_stream(n_streams=128)
    s2 = default_stream(n_streams=128)
    np.testing.assert_array_equal(np.asarray(s1.bits(1000)),
                                  np.asarray(s2.bits(1000)))


def test_stream_counter_advances(stream):
    a = np.asarray(stream.bits(1000))
    b = np.asarray(stream.bits(1000))
    assert not np.array_equal(a, b)


def test_uniform_statistics(stream):
    u = np.asarray(stream.uniform((20_000,)))
    assert 0.0 <= u.min() and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(u.std() - (1 / 12) ** 0.5) < 0.01


def test_bernoulli_and_permutation(stream):
    m = np.asarray(stream.bernoulli(0.25, (20_000,)))
    assert abs(m.mean() - 0.25) < 0.02
    perm = np.asarray(stream.permutation(512))
    assert sorted(perm.tolist()) == list(range(512))


def test_bit_unpacking_helper():
    bits = _to_bits(np.asarray([0xFFFFFFFF, 0x0], dtype=np.uint32))
    assert bits.size == 64 and bits[:32].sum() == 32 and bits[32:].sum() == 0
