"""Admission control: token-bucket math, ceiling gauge, front-end wiring.

Pure-policy tests drive ``AdmissionController`` directly under a
``FakeClock`` (token refill is arithmetic on fake time — zero sleeps);
the integration tests attach a controller to ``AsyncOscillatorFarm`` and
prove the serving-tier contract: over-limit submits fail fast with a
typed ``Overloaded`` (carrying ``retry_after_ms``) while already-admitted
futures all resolve.
"""
import asyncio

import numpy as np
import pytest

from repro.serve.admission import AdmissionController, Overloaded
from repro.serve.async_frontend import AsyncOscillatorFarm
from repro.serve.clock import FakeClock

from test_async_frontend import TEST_TIMEOUT, _farm, _run


# ---------------------------------------------------------------------------
# Token-bucket policy (no farm involved)
# ---------------------------------------------------------------------------

def test_bucket_burst_then_rate():
    fc = FakeClock()
    ac = AdmissionController(rate_words_per_s=100.0, burst_words=500.0,
                             clock=fc)
    # burst drains first...
    ac.admit("c", "t", 500, rows_est=1)
    # ...then an empty bucket rejects with an exact refill-time hint
    with pytest.raises(Overloaded) as ei:
        ac.admit("c", "t", 200, rows_est=1)
    assert ei.value.scope == "tenant"
    assert ei.value.core == "c" and ei.value.client == "t"
    assert ei.value.retry_after_ms == pytest.approx(2000.0)  # 200 w / 100 w/s
    # a rejection must not consume tokens: refill exactly the hint and
    # the same request is admitted
    fc.advance(2.0)
    ac.admit("c", "t", 200, rows_est=1)
    assert ac.stats()["admitted"] == 2.0
    assert ac.stats()["rejected_tenant"] == 1.0


def test_bucket_refill_caps_at_burst():
    fc = FakeClock()
    ac = AdmissionController(rate_words_per_s=10.0, burst_words=100.0,
                             clock=fc)
    ac.admit("c", "t", 100, rows_est=1)
    fc.advance(1e6)                        # eons: still only `burst` tokens
    ac.admit("c", "t", 100, rows_est=1)
    with pytest.raises(Overloaded):
        ac.admit("c", "t", 1, rows_est=1)


def test_oversized_request_never_admissible():
    ac = AdmissionController(rate_words_per_s=10.0, burst_words=50.0,
                             clock=FakeClock())
    with pytest.raises(Overloaded) as ei:
        ac.admit("c", "t", 51, rows_est=1)
    assert ei.value.retry_after_ms == float("inf")


def test_per_tenant_override_and_isolation():
    fc = FakeClock()
    ac = AdmissionController(rate_words_per_s=10.0, burst_words=10.0,
                             per_tenant={("c", "vip"): (1000.0, 1000.0)},
                             clock=fc)
    ac.admit("c", "vip", 900, rows_est=1)      # override bucket
    ac.admit("c", "t", 10, rows_est=1)         # default bucket
    with pytest.raises(Overloaded):
        ac.admit("c", "t", 10, rows_est=1)     # t exhausted...
    ac.admit("c", "vip", 100, rows_est=1)      # ...vip unaffected


def test_ceiling_gauge_admit_release_lifecycle():
    ac = AdmissionController(max_queued_rows=10, ceiling_retry_ms=7.5,
                             clock=FakeClock())
    ac.admit("c", "t", 1, rows_est=6)
    ac.admit("c", "u", 1, rows_est=4)
    assert ac.queued_rows == 10
    with pytest.raises(Overloaded) as ei:
        ac.admit("c", "v", 1, rows_est=1)
    assert ei.value.scope == "farm"
    assert ei.value.retry_after_ms == pytest.approx(7.5)
    ac.release(4)                              # one request left the queue
    ac.admit("c", "v", 1, rows_est=1)
    assert ac.queued_rows == 7
    assert ac.stats()["rejected_farm"] == 1.0


def test_rate_and_burst_must_pair():
    with pytest.raises(ValueError, match="together"):
        AdmissionController(rate_words_per_s=10.0)


# ---------------------------------------------------------------------------
# Front-end integration
# ---------------------------------------------------------------------------

def test_frontend_rejects_fail_fast_admitted_futures_resolve():
    """Over-ceiling load is refused at submit() with Overloaded while every
    already-admitted future still resolves with its exact words."""
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        ac = AdmissionController(max_queued_rows=3, clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc, admission=ac) as af:
            # lanes_per_client=128 => 128 words = 1 row estimate
            admitted = [af.submit("core0", "t", 128, deadline_ms=50)
                        for _ in range(3)]
            assert ac.queued_rows == 3
            with pytest.raises(Overloaded) as ei:
                af.submit("core0", "t", 128, deadline_ms=50)
            assert ei.value.scope == "farm"
            assert ei.value.retry_after_ms > 0.0
            fc.advance(0.05)
            await af.drain()
            assert all(f.result().size == 128 for f in admitted)
            # the flush released the gauge: the same submit now admits
            assert ac.queued_rows == 0
            ok = await af.draw("core0", "t", 128, deadline_ms=0)
            assert ok.size == 128
    _run(go())


def test_frontend_cancel_releases_ceiling_rows():
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        ac = AdmissionController(max_queued_rows=2, clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc, admission=ac) as af:
            doomed = af.submit("core0", "t", 256, deadline_ms=10_000)
            with pytest.raises(Overloaded):
                af.submit("core0", "t", 128, deadline_ms=10_000)
            doomed.cancel()
            await af.drain()                  # flusher pass prunes + releases
            assert ac.queued_rows == 0
            ok = await af.draw("core0", "t", 128, deadline_ms=0)
            assert ok.size == 128
    _run(go())


def test_frontend_tenant_rate_limit_and_stream_integrity():
    """A rate-limited tenant's rejected submit never reaches the farm: the
    served stream stays bit-identical to a solo farm that saw only the
    admitted draws."""
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        ac = AdmissionController(rate_words_per_s=1000.0, burst_words=200.0,
                                 clock=fc)
        served = []
        async with AsyncOscillatorFarm(farm, clock=fc, admission=ac) as af:
            served.append(await af.draw("core0", "t", 200, deadline_ms=0))
            with pytest.raises(Overloaded) as ei:
                af.submit("core0", "t", 200, deadline_ms=0)
            # bucket refills on fake time: the hint is honest
            fc.advance(ei.value.retry_after_ms / 1e3)
            served.append(await af.draw("core0", "t", 200, deadline_ms=0))
        solo = _farm(gang=False)
        for words in served:
            np.testing.assert_array_equal(words, solo.draw("core0", "t", 200))
    _run(go())


def test_draw_sync_rejected_by_admission_raises_in_caller_thread():
    fc = FakeClock()
    farm = _farm(clock=fc)
    ac = AdmissionController(rate_words_per_s=10.0, burst_words=64.0,
                             clock=fc)
    af = AsyncOscillatorFarm(farm, clock=fc, admission=ac).start_thread()
    try:
        out = af.draw_sync("core0", "t", 64, deadline_ms=0,
                           timeout=TEST_TIMEOUT)
        assert out.size == 64
        with pytest.raises(Overloaded):
            af.draw_sync("core0", "t", 64, deadline_ms=0,
                         timeout=TEST_TIMEOUT)
        assert ac.stats()["rejected_tenant"] == 1.0
        assert ac.queued_rows == 0            # rejected submit queued nothing
    finally:
        af.close()
