"""Admission control: token-bucket math, ceiling gauge, front-end wiring.

Pure-policy tests drive ``AdmissionController`` directly under a
``FakeClock`` (token refill is arithmetic on fake time — zero sleeps);
the integration tests attach a controller to ``AsyncOscillatorFarm`` and
prove the serving-tier contract: over-limit submits fail fast with a
typed ``Overloaded`` (carrying ``retry_after_ms``) while already-admitted
futures all resolve.
"""
import asyncio

import numpy as np
import pytest

from repro.serve.admission import (AdmissionController, Overloaded,
                                   RETRY_CAP_MS, RETRY_FLOOR_MS)
from repro.serve.async_frontend import AsyncOscillatorFarm
from repro.serve.clock import FakeClock

from test_async_frontend import TEST_TIMEOUT, _farm, _run


# ---------------------------------------------------------------------------
# Token-bucket policy (no farm involved)
# ---------------------------------------------------------------------------

def test_bucket_burst_then_rate():
    fc = FakeClock()
    ac = AdmissionController(rate_words_per_s=100.0, burst_words=500.0,
                             clock=fc)
    # burst drains first...
    ac.admit("c", "t", 500, rows_est=1)
    # ...then an empty bucket rejects with an exact refill-time hint
    with pytest.raises(Overloaded) as ei:
        ac.admit("c", "t", 200, rows_est=1)
    assert ei.value.scope == "tenant"
    assert ei.value.core == "c" and ei.value.client == "t"
    assert ei.value.retry_after_ms == pytest.approx(2000.0)  # 200 w / 100 w/s
    # a rejection must not consume tokens: refill exactly the hint and
    # the same request is admitted
    fc.advance(2.0)
    ac.admit("c", "t", 200, rows_est=1)
    assert ac.stats()["admitted"] == 2.0
    assert ac.stats()["rejected_tenant"] == 1.0


def test_bucket_refill_caps_at_burst():
    fc = FakeClock()
    ac = AdmissionController(rate_words_per_s=10.0, burst_words=100.0,
                             clock=fc)
    ac.admit("c", "t", 100, rows_est=1)
    fc.advance(1e6)                        # eons: still only `burst` tokens
    ac.admit("c", "t", 100, rows_est=1)
    with pytest.raises(Overloaded):
        ac.admit("c", "t", 1, rows_est=1)


def test_oversized_request_never_admissible():
    ac = AdmissionController(rate_words_per_s=10.0, burst_words=50.0,
                             clock=FakeClock())
    with pytest.raises(Overloaded) as ei:
        ac.admit("c", "t", 51, rows_est=1)
    # an oversized request can NEVER be admitted, but the hint must stay
    # finite: an inf would leak straight into client sleep arithmetic
    assert ei.value.retry_after_ms == RETRY_CAP_MS


def test_retry_hint_clamped_to_positive_floor():
    # a near-instant refill used to round to a 0 ms hint — every rejected
    # client retried in the same scheduler tick (a synchronized stampede)
    fc = FakeClock()
    ac = AdmissionController(rate_words_per_s=1e6, burst_words=100.0,
                             clock=fc)
    ac.admit("c", "t", 100, rows_est=1)
    with pytest.raises(Overloaded) as ei:
        ac.admit("c", "t", 50, rows_est=1)     # refills in 0.05 ms
    assert ei.value.retry_after_ms == RETRY_FLOOR_MS
    assert Overloaded("x", scope="farm",
                      retry_after_ms=float("nan")).retry_after_ms == \
        RETRY_CAP_MS
    assert Overloaded("x", scope="farm",
                      retry_after_ms=-5.0).retry_after_ms == RETRY_FLOOR_MS


def test_capacity_factor_scales_row_ceiling():
    ac = AdmissionController(max_queued_rows=100, clock=FakeClock())
    assert ac.current_ceiling == 100
    ac.set_capacity_factor(0.5)                # 1 of 2 cores quarantined
    assert ac.current_ceiling == 50
    ac.admit("c", "t", 1, rows_est=50)
    with pytest.raises(Overloaded) as ei:
        ac.admit("c", "u", 1, rows_est=1)
    assert ei.value.scope == "farm"
    assert ac.stats()["capacity_factor"] == 0.5
    ac.set_capacity_factor(9.9)                # clamped into [0, 1]
    assert ac.current_ceiling == 100
    ac.set_capacity_factor(-1.0)
    assert ac.current_ceiling == 0


def test_per_tenant_override_and_isolation():
    fc = FakeClock()
    ac = AdmissionController(rate_words_per_s=10.0, burst_words=10.0,
                             per_tenant={("c", "vip"): (1000.0, 1000.0)},
                             clock=fc)
    ac.admit("c", "vip", 900, rows_est=1)      # override bucket
    ac.admit("c", "t", 10, rows_est=1)         # default bucket
    with pytest.raises(Overloaded):
        ac.admit("c", "t", 10, rows_est=1)     # t exhausted...
    ac.admit("c", "vip", 100, rows_est=1)      # ...vip unaffected


def test_ceiling_gauge_admit_release_lifecycle():
    ac = AdmissionController(max_queued_rows=10, ceiling_retry_ms=7.5,
                             clock=FakeClock())
    ac.admit("c", "t", 1, rows_est=6)
    ac.admit("c", "u", 1, rows_est=4)
    assert ac.queued_rows == 10
    with pytest.raises(Overloaded) as ei:
        ac.admit("c", "v", 1, rows_est=1)
    assert ei.value.scope == "farm"
    assert ei.value.retry_after_ms == pytest.approx(7.5)
    ac.release(4)                              # one request left the queue
    ac.admit("c", "v", 1, rows_est=1)
    assert ac.queued_rows == 7
    assert ac.stats()["rejected_farm"] == 1.0


def test_rate_and_burst_must_pair():
    with pytest.raises(ValueError, match="together"):
        AdmissionController(rate_words_per_s=10.0)


# ---------------------------------------------------------------------------
# Front-end integration
# ---------------------------------------------------------------------------

def test_frontend_rejects_fail_fast_admitted_futures_resolve():
    """Over-ceiling load is refused at submit() with Overloaded while every
    already-admitted future still resolves with its exact words."""
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        ac = AdmissionController(max_queued_rows=3, clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc, admission=ac) as af:
            # lanes_per_client=128 => 128 words = 1 row estimate
            admitted = [af.submit("core0", "t", 128, deadline_ms=50)
                        for _ in range(3)]
            assert ac.queued_rows == 3
            with pytest.raises(Overloaded) as ei:
                af.submit("core0", "t", 128, deadline_ms=50)
            assert ei.value.scope == "farm"
            assert ei.value.retry_after_ms > 0.0
            fc.advance(0.05)
            await af.drain()
            assert all(f.result().size == 128 for f in admitted)
            # the flush released the gauge: the same submit now admits
            assert ac.queued_rows == 0
            ok = await af.draw("core0", "t", 128, deadline_ms=0)
            assert ok.size == 128
    _run(go())


def test_frontend_cancel_releases_ceiling_rows():
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        ac = AdmissionController(max_queued_rows=2, clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc, admission=ac) as af:
            doomed = af.submit("core0", "t", 256, deadline_ms=10_000)
            with pytest.raises(Overloaded):
                af.submit("core0", "t", 128, deadline_ms=10_000)
            doomed.cancel()
            await af.drain()                  # flusher pass prunes + releases
            assert ac.queued_rows == 0
            ok = await af.draw("core0", "t", 128, deadline_ms=0)
            assert ok.size == 128
    _run(go())


def test_frontend_tenant_rate_limit_and_stream_integrity():
    """A rate-limited tenant's rejected submit never reaches the farm: the
    served stream stays bit-identical to a solo farm that saw only the
    admitted draws."""
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        ac = AdmissionController(rate_words_per_s=1000.0, burst_words=200.0,
                                 clock=fc)
        served = []
        async with AsyncOscillatorFarm(farm, clock=fc, admission=ac) as af:
            served.append(await af.draw("core0", "t", 200, deadline_ms=0))
            with pytest.raises(Overloaded) as ei:
                af.submit("core0", "t", 200, deadline_ms=0)
            # bucket refills on fake time: the hint is honest
            fc.advance(ei.value.retry_after_ms / 1e3)
            served.append(await af.draw("core0", "t", 200, deadline_ms=0))
        solo = _farm(gang=False)
        for words in served:
            np.testing.assert_array_equal(words, solo.draw("core0", "t", 200))
    _run(go())


def test_draw_sync_rejected_by_admission_raises_in_caller_thread():
    fc = FakeClock()
    farm = _farm(clock=fc)
    ac = AdmissionController(rate_words_per_s=10.0, burst_words=64.0,
                             clock=fc)
    af = AsyncOscillatorFarm(farm, clock=fc, admission=ac).start_thread()
    try:
        out = af.draw_sync("core0", "t", 64, deadline_ms=0,
                           timeout=TEST_TIMEOUT)
        assert out.size == 64
        with pytest.raises(Overloaded):
            af.draw_sync("core0", "t", 64, deadline_ms=0,
                         timeout=TEST_TIMEOUT)
        assert ac.stats()["rejected_tenant"] == 1.0
        assert ac.queued_rows == 0            # rejected submit queued nothing
    finally:
        af.close()


# ---------------------------------------------------------------------------
# Adaptive ceiling: max_queued_rows from throughput, not a constant
# ---------------------------------------------------------------------------

def test_adaptive_ceiling_prior_from_fitted_cost_model():
    """Cold start: with zero observations the ceiling comes from the
    fitted GangCostModel (modeled rows/s x target delay); with neither
    model nor observations it stays wide open (max_rows)."""
    from repro.core.dse import GangCostModel
    from repro.serve.admission import AdaptiveCeiling
    from test_async_frontend import CAND as c

    blind = AdaptiveCeiling(max_rows=12345)
    assert blind.rows_per_s() is None
    assert blind.ceiling() == 12345

    # an unfitted model (sec_per_cycle=None) gives no prior either
    assert AdaptiveCeiling(cost_model=GangCostModel(), candidate=c,
                           max_rows=12345).ceiling() == 12345

    fitted = GangCostModel(sec_per_cycle=1e-9)
    ad = AdaptiveCeiling(cost_model=fitted, candidate=c,
                         target_delay_ms=50.0, min_rows=1, max_rows=1 << 30)
    rps = ad.prior_rows_per_s()
    assert rps is not None and rps > 0
    assert ad.ceiling() == int(rps * 0.050)


def test_adaptive_ceiling_tracks_observed_flush_rate():
    """Observations supersede the prior, over a rolling window: the
    ceiling follows measured rows/s * target delay, clamped."""
    from repro.serve.admission import AdaptiveCeiling

    ad = AdaptiveCeiling(target_delay_ms=100.0, window=4,
                         min_rows=8, max_rows=10_000)
    for _ in range(4):
        ad.observe(0.010, 50)           # 5000 rows/s
    assert ad.rows_per_s() == pytest.approx(5000.0)
    assert ad.ceiling() == 500          # 5000 * 0.1 s
    # the farm slows 10x; the window forgets the fast past
    for _ in range(4):
        ad.observe(0.100, 50)
    assert ad.ceiling() == 50
    # clamps hold at the extremes
    for _ in range(4):
        ad.observe(10.0, 1)             # glacial
    assert ad.ceiling() == 8
    for _ in range(4):
        ad.observe(1e-6, 1000)          # implausibly fast
    assert ad.ceiling() == 10_000


def test_adaptive_ceiling_gates_admission_with_drain_hint():
    """AdmissionController(adaptive=...) keeps the typed Overloaded
    contract; the farm-scope retry hint covers the modeled drain time."""
    from repro.serve.admission import AdaptiveCeiling

    ad = AdaptiveCeiling(target_delay_ms=10.0, min_rows=1)
    for _ in range(3):
        ad.observe(1.0, 100)            # 100 rows/s -> ceiling 1 row
    ac = AdmissionController(adaptive=ad, ceiling_retry_ms=2.0,
                             clock=FakeClock())
    assert ac.current_ceiling == 1
    ac.admit("core0", "t", 64, 1)
    with pytest.raises(Overloaded) as ei:
        ac.admit("core0", "t", 640, 10)
    assert ei.value.scope == "farm"
    # 10 excess rows at 100 rows/s = 100 ms, far above the 2 ms floor
    assert ei.value.retry_after_ms == pytest.approx(100.0)
    assert ac.stats()["ceiling"] == 1.0
    ac.release(1)
    ac.admit("core0", "t", 64, 1)       # drained: admitted again


def test_adaptive_ceiling_fed_by_frontend_profile_stats():
    """End to end: a profiled farm + adaptive admission — each flush
    feeds one (stage seconds, rows) observation, and the ceiling leaves
    max_rows once real throughput is measured.  Real clock: the profile
    stage timers read the farm's injected clock, so a FakeClock would
    yield zero-second deltas and no observations."""
    from repro.serve.admission import AdaptiveCeiling

    async def go():
        farm = _farm(n_cores=1, profile=True)
        ad = AdaptiveCeiling(target_delay_ms=50.0, min_rows=16,
                             max_rows=1 << 20)
        ac = AdmissionController(adaptive=ad)
        async with AsyncOscillatorFarm(farm, admission=ac) as af:
            for _ in range(3):
                await af.draw("core0", "t", 200, deadline_ms=0)
        # flush 1 primes the stage-timer baseline; flushes 2+ observe
        assert ad.updates >= 1
        assert ad.rows_per_s() is not None
        assert 16 <= ad.ceiling() < 1 << 20    # left max_rows: measured

    _run(go())


def test_degraded_ceiling_never_quantizes_to_zero():
    """Regression: a small base ceiling times a reduced-but-nonzero
    capacity factor used to truncate to a ZERO ceiling — rejecting all
    traffic while healthy cores remained.  A nonzero factor now floors
    the scaled ceiling at one row (static) / ``min_rows`` (adaptive);
    a factor of exactly 0 (every core quarantined) still closes the
    gate."""
    from repro.serve.admission import AdaptiveCeiling

    ac = AdmissionController(max_queued_rows=3, clock=FakeClock())
    ac.set_capacity_factor(0.2)              # int(3 * 0.2) == 0
    assert ac.current_ceiling == 1
    ac.admit("c", "t", 1, rows_est=1)        # one row still flows
    ac.release(1)
    ac.set_capacity_factor(0.0)
    assert ac.current_ceiling == 0
    with pytest.raises(Overloaded):
        ac.admit("c", "t", 1, rows_est=1)

    ad = AdaptiveCeiling(target_delay_ms=50.0, window=4,
                         min_rows=8, max_rows=10_000)
    for _ in range(4):
        ad.observe(0.010, 50)                # 5000 rows/s -> base 250
    acc = AdmissionController(adaptive=ad, clock=FakeClock())
    assert acc.current_ceiling == 250
    acc.set_capacity_factor(0.001)           # int(250 * 0.001) == 0
    assert acc.current_ceiling == 8          # floored at min_rows
    acc.set_capacity_factor(0.0)
    assert acc.current_ceiling == 0


def test_adaptive_prior_scales_with_launch_shape_and_lattice_dims():
    """Regression: the cold-start prior always modeled one nominal
    t_block/2-row launch, whatever the plan actually launches — so a
    farm flushing bigger coalesced launches under-estimated its own
    throughput, and a lattice core (i_dim = n_nodes x base dim) priced
    its rows like a scalar core and over-admitted on cold start."""
    from repro.core.dse import GangCostModel, select_config
    from repro.serve.admission import AdaptiveCeiling
    from test_async_frontend import CAND as c

    fitted = GangCostModel(sec_per_cycle=1e-9)
    base = AdaptiveCeiling(cost_model=fitted, candidate=c)
    shaped = AdaptiveCeiling(cost_model=fitted, candidate=c,
                             rows_per_launch=4 * c.t_block)
    # bigger launches amortize per-launch overhead: a plan-shaped prior
    # must credit that, not repeat the nominal-block estimate
    assert shaped.prior_rows_per_s() > base.prior_rows_per_s()

    # same launch shape, lattice-vs-scalar rows: pin rows_per_launch so
    # only the per-row cost differs — a 32-node lattice row carries
    # ~n_nodes the compute of the 3-D scalar core's and must price so
    lat = select_config(96, 256, s_total=128, unit="vpu", n_nodes=32)
    assert lat.n_nodes == 32
    scal_prior = AdaptiveCeiling(cost_model=fitted, candidate=c,
                                 rows_per_launch=128).prior_rows_per_s()
    lat_prior = AdaptiveCeiling(cost_model=fitted, candidate=lat,
                                rows_per_launch=128).prior_rows_per_s()
    assert lat_prior < scal_prior / 4
    # and the DSE's actual lattice pick (mxu) prices between the two:
    # costlier than the scalar core, cheaper than brute-force vpu rows
    latm = select_config(96, 256, s_total=128, unit="mxu", n_nodes=32)
    latm_prior = AdaptiveCeiling(cost_model=fitted, candidate=latm,
                                 rows_per_launch=128).prior_rows_per_s()
    assert lat_prior < latm_prior < scal_prior
