# lint-as: src/repro/serve/fixture.py
"""BAD: load-await-store under the lock — the gauge lost-update shape.

Between the load of ``self.free`` and the store, the await lets another
coroutine release rows too; the store clobbers its update."""


class Gauge:
    async def release_rows(self, n):
        async with self._lock:
            free = self.free
            await self._notify_waiters()
            self.free = free + n
