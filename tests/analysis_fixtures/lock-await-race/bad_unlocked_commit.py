# lint-as: src/repro/serve/fixture.py
"""BAD: flush-mutating phases outside the single-flight lock.

Two coroutines entering flush_cycle interleave commit/absorb/resolve
against one farm and corrupt word accounting."""


class Frontend:
    async def flush_cycle(self):
        batch = self._commit()
        await self._launch()
        self._resolve(batch)

    async def absorb_words(self, group, words):
        self.farm.absorb(group, words)
