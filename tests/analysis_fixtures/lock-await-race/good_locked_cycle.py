# lint-as: src/repro/serve/fixture.py
"""GOOD: mutating phases under the lock; await-crossing updates either
re-read after the await (fresh store) or use augmented assignment."""


class Frontend:
    async def flush_cycle(self):
        async with self._flush_lock:
            batch = self._commit()
            self._inflight = True
            try:
                await self._launch()
                self._resolve(batch)
                self.flushes += 1
            finally:
                self._inflight = False
