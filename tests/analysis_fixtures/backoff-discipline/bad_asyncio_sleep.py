# lint-as: src/repro/serve/fixture.py
"""BAD: retry backoff sleeps real time — FakeClock cannot drive it, and
the resilience suite would need seconds of wall sleeping per storm."""
import asyncio


class Flusher:
    async def launch_with_retries(self, batch):
        for attempt in range(1, 5):
            try:
                return self.launch(batch)
            except RuntimeError:
                await asyncio.sleep(0.005 * 2 ** attempt)
