# lint-as: src/repro/serve/fixture.py
"""GOOD: backoff routes through the injected Clock — a private event
that only the timeout (fake or real time advancing) wakes, so a
FakeClock drives the whole retry schedule with zero real sleeps."""
import asyncio


class Flusher:
    async def launch_with_retries(self, batch):
        for attempt in range(1, 5):
            try:
                return self.launch(batch)
            except RuntimeError:
                await self.clock.wait(asyncio.Event(),
                                      self.health.backoff_ms(attempt) / 1e3)
