# lint-as: src/repro/launch/fixture.py
"""GOOD: narrowed to expected exceptions, or broad with a stated reason
and a place the error is kept."""


def load(path):
    try:
        return path.read_text()
    except (OSError, UnicodeDecodeError):
        return None


def sweep(cells, errors):
    for cell in cells:
        try:
            cell()
        # repro: allow[broad-except] reason=sweep isolation: one cell failure is recorded in errors and the remaining cells still run
        except Exception as e:
            errors.append(e)
