# lint-as: src/repro/launch/fixture.py
"""BAD: broad catches with no reason — AttributeError-level bugs vanish."""


def load(path):
    try:
        return path.read_text()
    except Exception:
        return None


def probe(fn):
    try:
        fn()
    except:  # noqa: E722
        pass
