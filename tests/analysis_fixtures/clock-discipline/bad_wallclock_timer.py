# lint-as: src/repro/train/fixture.py
"""BAD: raw time reads — the PR-3 training-loop timer bug class.

Wall-clock timers dodge FakeClock injection (untestable deadlines) and
time.time() can step under NTP mid-measurement."""
import time


def run_step(step_fn, batch):
    t0 = time.perf_counter()
    out = step_fn(batch)
    return out, time.perf_counter() - t0


def wall_stamp():
    return time.time()
