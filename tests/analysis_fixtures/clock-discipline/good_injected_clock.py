# lint-as: src/repro/train/fixture.py
"""GOOD: the same timer routed through an injected Clock."""
from repro.clock import Clock, SystemClock


def run_step(step_fn, batch, clock: Clock = None):
    clock = clock or SystemClock()
    t0 = clock.now()
    out = step_fn(batch)
    return out, clock.now() - t0
