# lint-as: src/repro/core/fixture.py
"""BAD: direct writes to committed artifact paths — a crash or a
concurrent reader sees a torn file."""
import json


def publish_solution(out_dir, record):
    with open(out_dir / "solution.json", "w") as f:
        json.dump(record, f)


def publish_manifest(path, text):
    path.write_text(text)
