# lint-as: src/repro/core/fixture.py
"""GOOD: tmp sibling + os.replace — readers see old-complete or
new-complete, never torn."""
import json
import os


def publish_solution(out_dir, record):
    tmp = out_dir / ".solution.tmp"
    with open(tmp, "w") as f:
        json.dump(record, f)
    os.replace(tmp, out_dir / "solution.json")
