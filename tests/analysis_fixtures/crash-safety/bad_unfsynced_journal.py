# lint-as: src/repro/serve/fixture_journal.py
"""BAD: journal append without fsync — the flush record may still be in
the page cache when the process dies, breaking crash recovery's
record-exists-before-acted-on ordering."""
import json


class Journal:
    def append(self, rec):
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
