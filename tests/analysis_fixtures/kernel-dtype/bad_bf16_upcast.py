# lint-as: src/repro/kernels/fixture.py
"""BAD: the bf16 zero-entropy bug class — upcast before bitcast.

astype(f32) zero-fills the low 16 mantissa bits of a half-width float,
so the low-bit fold emits a counter hash with zero entropy."""
import jax
import jax.numpy as jnp


def fold_low16(x):
    u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return u & jnp.uint32(0xFFFF)
