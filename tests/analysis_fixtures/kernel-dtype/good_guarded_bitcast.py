# lint-as: src/repro/kernels/fixture.py
"""GOOD: width-guarded bitcast (ops._fold_low16 shape) and a kernel body
that sticks to jax-family ops + module-local helpers."""
import jax
import jax.numpy as jnp


def fold_low16(x):
    if x.dtype.itemsize == 2:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
        return u & jnp.uint32((1 << jnp.finfo(x.dtype).nmant) - 1)
    else:
        u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
        return u & jnp.uint32(0xFFFF)


def bits_kernel(x_ref, words_ref):
    folded = fold_low16(x_ref[...])
    words_ref[...] = jnp.asarray(folded, jnp.uint32)
