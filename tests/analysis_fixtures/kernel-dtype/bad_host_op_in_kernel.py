# lint-as: src/repro/kernels/fixture.py
"""BAD: host-side ops inside a Pallas kernel body — numpy calls
constant-fold host values into the traced program; print is a trace-time
effect."""
import numpy as np


def fold_kernel(x_ref, o_ref):
    print("tracing")
    o_ref[...] = np.tanh(x_ref[...])
