# lint-as: src/repro/serve/custom_launcher.py
"""BAD: a serve-layer module wrapping its own shard_map around a launch.

Sharding belongs to the launch stack (``ops.chaotic_bits_gang(...,
mesh=)`` / ``shard_stream_pool``): a direct ``shard_map`` here bypasses
the gang scheduler, the cost model, and the topology-keyed plan caches,
and its words sit outside every bit-identity suite.
"""
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import ops


def launch_sharded(params, x0, n_steps, mesh):
    def local(x_l):
        return ops.chaotic_bits(params, x_l, n_steps, 0)

    fn = shard_map(local, mesh=mesh, in_specs=(P("data", None),),
                   out_specs=(P(None, "data"), P("data", None)))
    return fn(x0)
