# lint-as: results/generated_cores/fixture/__init__.py
"""BAD: generate_bits without word_offset — chunked serving cannot
resume the word sequence; tenant streams diverge at the first flush
boundary."""
from repro.kernels import ops


def params():
    return {}


def generate_bits(x0, n_steps, *, backend="auto"):
    return ops.chaotic_bits(params(), x0, n_steps, 0, backend=backend)
