# lint-as: src/repro/serve/custom_launcher.py
"""GOOD: serve-layer sharding routes through the gang path — the mesh is
handed to ``ops.chaotic_bits_gang``, which owns the shard_map and its
bit-identity contract; mentioning it in prose (shard_map) is fine.
"""
from repro.kernels import ops


def launch_sharded(params, x0, n_steps, core_map, mesh):
    return ops.chaotic_bits_gang(params, x0, n_steps, 0,
                                 core_map=core_map, mesh=mesh,
                                 mesh_axis="data")
