# lint-as: results/generated_cores/fixture/__init__.py
"""BAD: host-side fold instead of the fused launch — not bit-compatible
with gang serving (and word_offset is accepted but never forwarded)."""
import numpy as np


def generate(x0, n_steps):
    return np.zeros((n_steps, len(x0)))


def generate_bits(x0, n_steps, word_offset=0, *, backend="auto"):
    traj = generate(x0, n_steps)
    words = np.asarray(traj, np.uint32)
    return words, traj[-1]
