# lint-as: results/generated_cores/fixture/__init__.py
"""GOOD: the codegen template shape — fused ops.chaotic_bits with
word_offset forwarded and (words, final_state) returned."""
import jax.numpy as jnp

from repro.kernels import ops

DTYPE = jnp.float32


def params():
    return {}


def generate_bits(x0, n_steps, word_offset=0, *, backend="auto"):
    return ops.chaotic_bits(
        params(), jnp.asarray(x0, DTYPE), n_steps, word_offset,
        backend=backend)
