# lint-as: src/repro/serve/fixture.py
"""BAD: delivering gang flush + sync draw on the loop thread."""


class Frontend:
    async def flush_cycle(self):
        out = self.farm.flush()        # gang launch runs on the loop
        return out

    async def draw_words(self, core, client, n):
        return self.farm.draw_sync(core, client, n)   # deadlock
