# lint-as: src/repro/serve/fixture.py
"""BAD: front-end submit() from sync code — the foreign-thread queue
race (PR 6 S4 bug class): asyncio futures and the request queue are
loop-thread-only."""


def feed(frontend, core, client, n):
    return frontend.submit(core, client, n)
