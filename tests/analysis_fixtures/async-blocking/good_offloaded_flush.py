# lint-as: src/repro/serve/fixture.py
"""GOOD: launch offloaded to the executor; delivery pass is launch-free."""
import functools


class Frontend:
    async def flush_cycle(self):
        launch = functools.partial(self.farm.flush, deliver=False)
        await self.loop.run_in_executor(self.executor, launch)
        self.farm.flush(deliver=False)

    def launch_later(self, fn):
        return self.executor.submit(fn)     # executor submit is sync-safe
