# lint-as: src/repro/serve/fixture.py
"""BAD: disk barrier + journal append on the event loop thread.

The historical shape: the journal's fsync-backed append ran inline in
the flush coroutine, stalling ingress/cancellation for the fsync."""
import os


class Flusher:
    async def flush_cycle(self):
        os.fsync(self.journal_fd)
        self.journal.record_flush(self.farm)
