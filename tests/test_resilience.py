"""Self-healing farm: fault injection, retries, breakers, quality rotation.

The whole suite is FakeClock-driven — storms of transient launch
failures, exponential-backoff retries, circuit-breaker quarantines and
standby rotations all run with ZERO real sleeps: fake time advances only
when a test says so, and the supervision layer's backoff routes through
the injected ``Clock`` (enforced repo-wide by the ``backoff-discipline``
rule of ``repro.analysis``).

The headline contracts:

* a transiently failed launch never reached ``absorb()``, so its
  committed demand is still parked at the same absolute stream rows —
  a retried flush serves words **bit-identical** to a never-failed one;
* a core that keeps failing trips its breaker and is quarantined: its
  tenants get a typed ``CoreQuarantined`` (never a hang), its gang
  group re-plans without it, and every OTHER tenant's words stay
  bit-identical to a fault-free run;
* a core whose *served words* go statistically bad (the online NIST
  gate over sampled windows) is quarantined within bounded flushes and
  its standby rotated into the routing slot;
* the journal records quarantines/rotations, so kill-and-replay
  reconstructs the crashed process's DEGRADED topology, not just its
  stream positions.

Launch-fault tests use the fast toy weights with a never-filling sample
window (toy networks are not trained oscillators — their words fail any
honest NIST gate, which is the quality monitor doing its job, not noise
to silence).  Quality-gate tests use the trained registry weights, whose
streams pass; only the FaultPlan's poisoned sampling fails them.
"""
import asyncio

import numpy as np
import pytest

from repro.prng.stream import default_params
from repro.serve.admission import AdmissionController
from repro.serve.async_frontend import AsyncOscillatorFarm
from repro.serve.clock import FakeClock
from repro.serve.farm import OscillatorFarm
from repro.serve.faults import FaultPlan, InjectedFault
from repro.serve.health import CoreQuarantined, HealthMonitor
from repro.serve.journal import replay_journal

from test_async_frontend import CAND, _farm, _params, _run

# Launch-fault tests: a window this large never fills from test traffic,
# so the quality gate stays silent and only launch supervision is on
# trial (toy test weights would fail any honest NIST gate).
BIG_WINDOW = 1 << 20


def _health(**kw):
    kw.setdefault("window_words", BIG_WINDOW)
    kw.setdefault("backoff_base_ms", 5.0)
    return HealthMonitor(**kw)


async def _drive(fc, futs, rounds=400, step_s=0.05):
    """Pump the loop + fake time until every future settles."""
    for _ in range(rounds):
        await asyncio.sleep(0)
        fc.advance(step_s)
        if all(f.done() for f in futs):
            return
    raise AssertionError(
        f"futures never settled: "
        f"{sum(1 for f in futs if not f.done())} still pending")


def _trained_farm(n_cores=3, clock=None, faults=None, standby_for=()):
    """Trained-registry cores (words pass the online gate) — the quality
    monitor only condemns what the FaultPlan poisons."""
    params = default_params(system="chen")
    farm = OscillatorFarm(gang=True, clock=clock, faults=faults)
    for i in range(n_cores):
        farm.add_core(f"core{i}", params, lanes_per_client=128)
        farm.register(f"core{i}", "t", seed=40)
    for core in standby_for:
        farm.add_standby(core, params, lanes_per_client=128)
    return farm


def _trained_solo_words(rounds, n_words=300):
    """Reference stream: one trained core served solo from registration."""
    params = default_params(system="chen")
    farm = OscillatorFarm(gang=False)
    farm.add_core("c", params, lanes_per_client=128)
    farm.register("c", "t", seed=40)
    return [farm.draw("c", "t", n_words) for _ in range(rounds)]


# ---------------------------------------------------------------------------
# FaultPlan: deterministic, replayable schedules
# ---------------------------------------------------------------------------

def _schedule(plan, launches=64):
    out = []
    for _ in range(launches):
        try:
            plan.on_launch(["a", "b"])
            out.append(False)
        except InjectedFault:
            out.append(True)
    return out


def test_fault_plan_same_seed_same_schedule():
    a = _schedule(FaultPlan(seed=7, transient_rate=0.3))
    b = _schedule(FaultPlan(seed=7, transient_rate=0.3))
    assert a == b and any(a)
    assert _schedule(FaultPlan(seed=8, transient_rate=0.3)) != a


def test_fault_plan_draw_per_launch_regardless_of_outcome():
    # the schedule depends only on the launch SEQUENCE: capping injected
    # faults must not shift later draws
    full = _schedule(FaultPlan(seed=7, transient_rate=0.3))
    capped_plan = FaultPlan(seed=7, transient_rate=0.3, max_transients=2)
    capped = _schedule(capped_plan)
    k = [i for i, hit in enumerate(full) if hit][1]
    assert capped[:k + 1] == full[:k + 1]
    assert capped_plan.injected["transient"] == 2
    assert not any(capped[k + 1:])


def test_fault_plan_scoping_and_arming():
    plan = FaultPlan(seed=0, transient_rate=1.0, transient_cores={"x"})
    plan.on_launch(["a", "b"])                    # not eligible: no x
    with pytest.raises(InjectedFault):
        plan.on_launch(["a", "x"])
    plan.disarm()
    plan.on_launch(["x"])                         # disarmed: no injection
    plan.arm()
    pers = FaultPlan(persistent_cores={"p"})
    with pytest.raises(InjectedFault) as ei:
        pers.on_launch(["a", "p"])
    assert ei.value.cores == ("p",) and ei.value.persistent
    pers.heal("p")
    pers.on_launch(["a", "p"])                    # healed
    with pytest.raises(ValueError):
        FaultPlan(transient_rate=1.5)


def test_failed_sync_flush_leaves_demand_parked_bit_exact():
    """A failed launch never absorbs: the SAME flush retried serves the
    same words — the bit-identity-by-construction the retry loop rests
    on, shown on the bare sync farm."""
    faults = FaultPlan(persistent_cores={"core0"})
    farm = _farm(n_cores=1, faults=faults)
    clean = _farm(n_cores=1)
    farm.request("core0", "t", 500)
    clean.request("core0", "t", 500)
    with pytest.raises(InjectedFault):
        farm.flush()
    assert farm.services["core0"].rows_needed() > 0   # demand still parked
    faults.heal("core0")
    out = farm.flush()
    ref = clean.flush()
    assert np.array_equal(out["core0"]["t"], ref["core0"]["t"])


# ---------------------------------------------------------------------------
# HealthMonitor policy units
# ---------------------------------------------------------------------------

def test_backoff_capped_exponential_with_bounded_jitter():
    h = HealthMonitor(backoff_base_ms=5.0, backoff_cap_ms=40.0,
                      backoff_jitter=0.25, seed=3)
    for attempt, base in ((1, 5.0), (2, 10.0), (3, 20.0), (4, 40.0),
                          (5, 40.0), (9, 40.0)):
        ms = h.backoff_ms(attempt)
        assert base <= ms <= base * 1.25, (attempt, ms)
    with pytest.raises(ValueError):
        h.backoff_ms(0)
    # seeded: two monitors replay the identical jitter sequence
    a = HealthMonitor(seed=11)
    b = HealthMonitor(seed=11)
    assert [a.backoff_ms(i) for i in (1, 2, 3)] == \
           [b.backoff_ms(i) for i in (1, 2, 3)]


def test_breaker_counts_consecutive_failures_only():
    h = HealthMonitor(breaker_threshold=3)
    assert h.note_launch_failure(["a", "b"]) == []
    assert h.note_launch_failure(["a"]) == []
    h.note_launch_success(["a"])                  # streak broken
    assert h.note_launch_failure(["a"]) == []
    assert h.note_launch_failure(["a"]) == []
    assert h.note_launch_failure(["a", "b"]) == ["a"]   # a: 3rd consecutive
    assert h.consecutive_failures("b") == 2
    assert h.stats["breaker_trips"] == 1


def test_monitor_windows_pop_exactly_and_memory_is_bounded():
    h = HealthMonitor(window_words=256)
    rng = np.random.default_rng(0)
    h.ingest("c", rng.integers(0, 2**32, 200, dtype=np.uint32))
    assert h.evaluate() == {}                     # window not full yet
    h.ingest("c", rng.integers(0, 2**32, 200, dtype=np.uint32))
    assert h.buffered_words("c") == 400
    verdicts = h.evaluate()                       # healthy words: no verdict
    assert verdicts == {}
    assert h.buffered_words("c") == 400 - 256     # rest carried over
    for _ in range(100):
        h.ingest("c", rng.integers(0, 2**32, 10_000, dtype=np.uint32))
    assert h.buffered_words("c") <= 2 * 256       # hard memory bound
    h.reset("c")
    assert h.buffered_words("c") == 0


def test_monitor_hard_failure_condemns_in_one_window():
    h = HealthMonitor(window_words=256)
    rng = np.random.default_rng(0)
    poisoned = rng.integers(0, 2**32, 256, dtype=np.uint32) & np.uint32(
        0xFFFF0000)
    h.ingest("bad", poisoned)
    verdicts = h.evaluate()
    assert "bad" in verdicts
    assert "monobit" in verdicts["bad"]["gate"]["hard_failed_tests"]
    assert h.stats["quality_quarantines"] == 1


# ---------------------------------------------------------------------------
# Supervised front-end: transient retries are invisible in the words
# ---------------------------------------------------------------------------

def test_transient_retries_serve_bit_identical_words():
    results = {}

    async def faulty():
        fc = FakeClock()
        faults = FaultPlan(seed=3, transient_rate=0.5, max_transients=4)
        health = _health(breaker_threshold=10, seed=1)
        farm = _farm(clock=fc, faults=faults)
        async with AsyncOscillatorFarm(farm, clock=fc, offload=False,
                                       health=health) as af:
            futs = [af.submit(f"core{i}", "t", 300) for i in range(3)]
            await _drive(fc, futs)
            results["faulty"] = [f.result() for f in futs]
        assert faults.injected["transient"] > 0
        assert health.stats["retries"] > 0
        assert health.stats["breaker_trips"] == 0

    async def clean():
        fc = FakeClock()
        farm = _farm(clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc, offload=False) as af:
            futs = [af.submit(f"core{i}", "t", 300) for i in range(3)]
            await _drive(fc, futs)
            results["clean"] = [f.result() for f in futs]

    _run(faulty())
    _run(clean())
    for a, b in zip(results["faulty"], results["clean"]):
        assert np.array_equal(a, b)


def test_retry_budget_exhausted_propagates_to_futures():
    async def go():
        fc = FakeClock()
        faults = FaultPlan(seed=0, transient_rate=1.0, max_transients=None)
        # threshold above the retry budget: the breaker never trips, the
        # budget runs out first and the error reaches the tenants
        health = _health(breaker_threshold=100, max_retries_per_flush=2)
        farm = _farm(n_cores=1, clock=fc, faults=faults)
        async with AsyncOscillatorFarm(farm, clock=fc, offload=False,
                                       health=health) as af:
            fut = af.submit("core0", "t", 100)
            await _drive(fc, [fut])
            assert isinstance(fut.exception(), InjectedFault)
            assert health.stats["retries"] == 2
            assert len(af.flush_errors) >= 1
    _run(go())


# ---------------------------------------------------------------------------
# Circuit breaker: quarantine, re-planned gang, typed errors
# ---------------------------------------------------------------------------

def test_breaker_quarantines_core_and_group_replans_without_it():
    healthy_words = {}

    async def storm():
        fc = FakeClock()
        faults = FaultPlan(persistent_cores={"core1"})
        health = _health(breaker_threshold=3)
        farm = _farm(clock=fc, faults=faults)
        async with AsyncOscillatorFarm(farm, clock=fc, offload=False,
                                       health=health) as af:
            f_bad = af.submit("core1", "t", 100)
            f_ok = [af.submit(f"core{i}", "t", 100) for i in (0, 2)]
            await _drive(fc, f_ok + [f_bad])
            err = f_bad.exception()
            assert isinstance(err, CoreQuarantined)
            assert err.core == "core1" and not err.rotated
            assert farm.quarantined == frozenset({"core1"})
            assert health.stats["breaker_trips"] == 1
            healthy_words["storm"] = [f.result() for f in f_ok]
            # fail-fast at submit for the dead core, typed
            with pytest.raises(CoreQuarantined):
                af.submit("core1", "t", 10)
            # the re-planned group (core0+core2) keeps serving
            f2 = [af.submit(f"core{i}", "t", 50) for i in (0, 2)]
            await _drive(fc, f2)
            assert all(f.exception() is None for f in f2)

    async def clean():
        fc = FakeClock()
        farm = _farm(clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc, offload=False) as af:
            futs = [af.submit(f"core{i}", "t", 100) for i in (0, 2)]
            await _drive(fc, futs)
            healthy_words["clean"] = [f.result() for f in futs]

    _run(storm())
    _run(clean())
    for a, b in zip(healthy_words["storm"], healthy_words["clean"]):
        assert np.array_equal(a, b)


def test_quarantine_without_standby_shrinks_admission_ceiling():
    async def go():
        fc = FakeClock()
        faults = FaultPlan(persistent_cores={"core1"})
        health = _health(breaker_threshold=2)
        adm = AdmissionController(max_queued_rows=300, clock=fc)
        farm = _farm(clock=fc, faults=faults)
        async with AsyncOscillatorFarm(farm, clock=fc, offload=False,
                                       health=health, admission=adm) as af:
            assert adm.current_ceiling == 300
            fut = af.submit("core1", "t", 100)
            await _drive(fc, [fut])
            assert isinstance(fut.exception(), CoreQuarantined)
            # 2 of 3 cores healthy: the ceiling shrinks with capacity
            assert adm.capacity_factor == pytest.approx(2 / 3)
            assert adm.current_ceiling == 200
            assert adm.stats()["capacity_factor"] == pytest.approx(2 / 3)
    _run(go())


# ---------------------------------------------------------------------------
# Online quality gate: poisoned sampling -> quarantine + rotation
# ---------------------------------------------------------------------------

def test_poisoned_core_rotates_within_three_flushes_bit_exact():
    rotated_words = []

    async def go():
        fc = FakeClock()
        faults = FaultPlan(poison={"core0"})
        health = HealthMonitor(window_words=256)
        adm = AdmissionController(max_queued_rows=10_000, clock=fc)
        farm = _trained_farm(clock=fc, faults=faults,
                             standby_for=("core0",))
        async with AsyncOscillatorFarm(farm, clock=fc, offload=False,
                                       health=health, admission=adm) as af:
            rotated_at = None
            for round_ in range(4):
                futs = [af.submit(f"core{i}", "t", 300) for i in range(3)]
                await _drive(fc, futs)
                for i, f in enumerate(futs):
                    assert f.exception() is None, (round_, i, f.exception())
                rotated_words.append(futs[0].result())
                if rotated_at is None and farm.rotations.get("core0") == 1:
                    rotated_at = round_ + 1
            # the acceptance bound: quarantined + rotated within 3 flushes
            assert rotated_at is not None and rotated_at <= 3
            assert health.stats["quality_quarantines"] == 1
            assert farm.quarantined == frozenset()       # rotation lifted it
            assert adm.capacity_factor == 1.0            # capacity restored

    _run(go())
    # Bit-identity across the rotation: the rounds before it match the
    # original core served solo; the rounds after match the STANDBY
    # served solo from registration (same seed, row 0) — delivered words
    # were never corrupted (only the monitor's samples were).
    n = len(rotated_words)
    for split in range(n + 1):
        ref = _trained_solo_words(split) + _trained_solo_words(n - split)
        if all(np.array_equal(a, b) for a, b in zip(rotated_words, ref)):
            assert 0 < split <= 3    # rotation actually happened mid-run
            return
    raise AssertionError("rotated-core words match no rotation point")


def test_standby_samples_clean_after_rotation():
    """Poison binds to the PHYSICAL service: after rotation the monitor
    sees the standby's honest words and never re-condemns the slot."""
    async def go():
        fc = FakeClock()
        faults = FaultPlan(poison={"core0"})
        health = HealthMonitor(window_words=256)
        farm = _trained_farm(n_cores=1, clock=fc, faults=faults,
                             standby_for=("core0",))
        async with AsyncOscillatorFarm(farm, clock=fc, offload=False,
                                       health=health) as af:
            for _ in range(6):
                fut = af.submit("core0", "t", 300)
                await _drive(fc, [fut])
                assert fut.exception() is None
            assert farm.rotations.get("core0") == 1   # exactly one rotation
            assert health.stats["quality_quarantines"] == 1
            assert faults.injected["corrupted_samples"] > 0
    _run(go())


# ---------------------------------------------------------------------------
# The storm acceptance test: transients + a poisoned core, all at once
# ---------------------------------------------------------------------------

def test_storm_every_admitted_request_bit_identical_to_solo():
    served = []     # (round, core_index, words) for every resolved future

    async def go():
        fc = FakeClock()
        # seed chosen so the 10% coin actually lands at least once in
        # this short run (seeded schedule: same seed, same storm)
        faults = FaultPlan(seed=2, transient_rate=0.10, poison={"core0"})
        health = HealthMonitor(window_words=256, breaker_threshold=5,
                               backoff_base_ms=5.0)
        farm = _trained_farm(clock=fc, faults=faults,
                             standby_for=("core0",))
        async with AsyncOscillatorFarm(farm, clock=fc, offload=False,
                                       health=health) as af:
            for round_ in range(5):
                futs = [af.submit(f"core{i}", "t", 300) for i in range(3)]
                await _drive(fc, futs)
                for i, f in enumerate(futs):
                    # under this storm every request resolves (transients
                    # retry, the poisoned core rotates) — a CoreQuarantined
                    # here would also be acceptable per the contract, but
                    # must then be typed
                    if f.exception() is not None:
                        assert isinstance(f.exception(), CoreQuarantined)
                        continue
                    served.append((round_, i, f.result()))
            assert farm.rotations.get("core0") == 1
        assert faults.injected["transient"] > 0
        assert faults.injected["corrupted_samples"] > 0

    _run(go())
    # every served word bit-identical to a fault-free solo run of the
    # same per-round demand (core0: try all rotation split points)
    rounds = 5
    solo = {i: _trained_solo_words(rounds) for i in (1, 2)}
    for round_, i, words in served:
        if i == 0:
            continue
        assert np.array_equal(words, solo[i][round_]), (round_, i)
    core0 = [(r, w) for r, i, w in served if i == 0]
    n0 = len(core0)
    for split in range(rounds + 1):
        ref = _trained_solo_words(split) + _trained_solo_words(rounds - split)
        got = [np.array_equal(w, ref[r]) for r, w in core0]
        if all(got):
            return
    raise AssertionError("core0 storm words match no rotation split")


# ---------------------------------------------------------------------------
# Kill-and-replay reconstructs the DEGRADED topology from the journal
# ---------------------------------------------------------------------------

def test_kill_and_replay_reconstructs_quarantine_and_rotation(tmp_path):
    jpath = tmp_path / "storm.journal"
    params = default_params(system="chen")
    live_tail = {}

    async def serve_through_storm():
        fc = FakeClock()
        faults = FaultPlan(persistent_cores={"core1"}, poison={"core0"})
        health = HealthMonitor(window_words=256, breaker_threshold=2)
        # bare cores: EVERY registration goes through the front-end so
        # the journal alone can rebuild the client set
        farm = OscillatorFarm(gang=True, clock=fc, faults=faults)
        for i in range(3):
            farm.add_core(f"core{i}", params, lanes_per_client=128)
        farm.add_standby("core0", params, lanes_per_client=128)
        async with AsyncOscillatorFarm(farm, clock=fc, offload=False,
                                       health=health, journal=jpath) as af:
            for i in range(3):
                af.register(f"core{i}", "j", seed=77)
            f_bad = af.submit("core1", "j", 100)
            futs = [af.submit(f"core{i}", "j", 300) for i in (0, 2)]
            await _drive(fc, futs + [f_bad])
            assert isinstance(f_bad.exception(), CoreQuarantined)
            for _ in range(2):       # poisoned core0 rotates along the way
                futs = [af.submit(f"core{i}", "j", 300) for i in (0, 2)]
                await _drive(fc, futs)
                assert all(f.exception() is None for f in futs)
            assert farm.quarantined == frozenset({"core1"})
            assert farm.rotations.get("core0") == 1
            # the continuation a correct replay must reproduce
            live_tail["core0"] = farm.draw("core0", "j", 128)
            live_tail["core2"] = farm.draw("core2", "j", 128)

    _run(serve_through_storm())

    # a NEW process: same cores + the same standby, journal only
    reborn = OscillatorFarm(gang=True)
    for i in range(3):
        reborn.add_core(f"core{i}", params, lanes_per_client=128)
    reborn.add_standby("core0", params, lanes_per_client=128)
    summary = replay_journal(reborn, jpath)
    # two quarantine events (core1 by breaker; core0 by quality gate,
    # then lifted by its rotation) and one rotation
    assert summary["quarantines"] == 2 and summary["rotations"] == 1
    assert reborn.quarantined == frozenset({"core1"})
    assert reborn.rotations == {"core0": 1}
    with pytest.raises(CoreQuarantined):
        reborn.draw("core1", "j", 10)
    for core in ("core0", "core2"):
        assert np.array_equal(reborn.draw(core, "j", 128), live_tail[core])


# ---------------------------------------------------------------------------
# S4: restore(replan) composed with a quarantined/rotated topology
# ---------------------------------------------------------------------------

def _quarantined_snapshot(params):
    """A farm mid-life: core1 quarantined, core0 already rotated once."""
    farm = OscillatorFarm(gang=True)
    for i in range(3):
        farm.add_core(f"core{i}", params, lanes_per_client=128)
        farm.register(f"core{i}", "t", seed=40)
    farm.add_standby("core0", params, lanes_per_client=128)
    for i in range(3):
        farm.draw(f"core{i}", "t", 200)
    farm.quarantine("core0", reason="drill")
    farm.rotate("core0")
    farm.draw("core0", "t", 100)
    farm.quarantine("core1", reason="dead")
    snap = farm.snapshot()
    tail = {c: farm.draw(c, "t", 64) for c in ("core0", "core2")}
    return snap, tail


def test_restore_preserves_quarantine_set_and_rotations():
    params = default_params(system="chen")
    snap, tail = _quarantined_snapshot(params)
    assert snap["quarantined"] == ["core1"]
    assert snap["rotations"] == {"core0": 1}
    target = OscillatorFarm(gang=True)
    for i in range(3):
        target.add_core(f"core{i}", params, lanes_per_client=128)
    target.add_standby("core0", params, lanes_per_client=128)
    target.restore(snap)
    assert target.quarantined == frozenset({"core1"})
    assert target.rotations == {"core0": 1}
    with pytest.raises(CoreQuarantined):
        target.request("core1", "t", 10)
    for c in ("core0", "core2"):
        assert np.array_equal(target.draw(c, "t", 64), tail[c])


def test_restore_refuses_to_unrotate():
    params = default_params(system="chen")
    snap, _ = _quarantined_snapshot(params)
    target = OscillatorFarm(gang=True)
    for i in range(3):
        target.add_core(f"core{i}", params, lanes_per_client=128)
        target.register(f"core{i}", "t", seed=40)
    target.add_standby("core0", params, lanes_per_client=128)
    target.add_standby("core1", params, lanes_per_client=128)
    target.quarantine("core1", reason="x")
    target.rotate("core1")           # rotation the snapshot never saw
    with pytest.raises(ValueError, match="un-rotate"):
        target.restore(snap)


def test_restore_replan_across_device_counts_keeps_quarantine():
    """The S4 composition: a snapshot of a DEGRADED sharded farm restores
    onto an unsharded farm with ``on_topology_mismatch='replan'`` —
    quarantine set and rotation count survive, streams continue
    bit-exactly (device-count-invariant words)."""
    import jax
    from jax.sharding import Mesh
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 host devices — run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=2")
    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    params = default_params(system="chen")
    farm = OscillatorFarm(gang=True)
    for i in range(2):
        farm.add_core(f"core{i}", params, lanes_per_client=128, mesh=mesh)
        farm.register(f"core{i}", "t", seed=40)
    farm.add_standby("core0", params, lanes_per_client=128, mesh=mesh)
    farm.draw("core0", "t", 200)
    farm.quarantine("core0", reason="drill")
    farm.rotate("core0")
    farm.quarantine("core1", reason="dead")
    snap = farm.snapshot()
    tail = farm.draw("core0", "t", 64)

    unsharded = OscillatorFarm(gang=True)
    for i in range(2):
        unsharded.add_core(f"core{i}", params, lanes_per_client=128)
    unsharded.add_standby("core0", params, lanes_per_client=128)
    with pytest.raises(ValueError, match="topology"):
        unsharded.restore(snap)                     # refuse by default
    unsharded.restore(snap, on_topology_mismatch="replan")
    assert unsharded.quarantined == frozenset({"core1"})
    assert unsharded.rotations == {"core0": 1}
    assert np.array_equal(unsharded.draw("core0", "t", 64), tail)
