"""NIST subset gate swept across the whole weight registry (f32 + bf16).

PR 1 gated the trained Chen f32 stream only; the farm serves every
registry system in two dtypes, so the quality claim must hold — or be
quarantined — per (system, dtype).  The gate draws through the exact
serving path (``ChaoticPRNG`` + fused kernel) with fixed seeds, so every
p-value here is deterministic: a failure is a real regression, not flake.

Policy (see ``repro.prng.quality``): f32 cores are the paper's claim and
must pass outright; bf16 cores fold a 7-bit mantissa and are allowed
single-test chance failures, but anything beyond that quarantines the
(system, dtype) — which ``benchmarks/farm.py`` then marks in
BENCH_farm.json so a rollout can exclude it.
"""
import numpy as np
import pytest

from repro.core.chaotic import SYSTEMS
from repro.prng.quality import (MAX_CHANCE_FAILS, nist_gate,
                                quarantined_systems)

GATE_KW = dict(n_words=20_000, backend="pallas_interpret")


@pytest.mark.parametrize("system", sorted(SYSTEMS))
def test_f32_registry_stream_passes_nist(system):
    """Hard gate: every f32 registry core emits NIST-clean words."""
    res = nist_gate(system, "float32", **GATE_KW)
    assert not res["failed_tests"], res
    assert not res["quarantined"]


@pytest.mark.parametrize("system", sorted(SYSTEMS))
def test_bf16_registry_stream_not_quarantined(system):
    """Soft gate: half-width cores may lose single tests to chance, but a
    quarantine-level failure of a shipping bf16 core fails tier-1."""
    res = nist_gate(system, "bfloat16", **GATE_KW)
    assert len(res["failed_tests"]) <= MAX_CHANCE_FAILS, res
    assert not res["hard_failed_tests"], res
    assert not res["quarantined"]


def test_quarantine_policy_mechanism():
    """quarantined_systems() collects exactly the quarantined pairs."""
    sweep = {
        "a/float32": {"system": "a", "dtype": "float32",
                      "quarantined": False},
        "a/bfloat16": {"system": "a", "dtype": "bfloat16",
                       "quarantined": True},
        "b/bfloat16": {"system": "b", "dtype": "bfloat16",
                       "quarantined": True},
    }
    assert quarantined_systems(sweep) == {"a": ["bfloat16"],
                                          "b": ["bfloat16"]}


def test_gate_detects_catastrophic_bias():
    """A hard single-test failure (p < ALPHA_HARD) must quarantine even
    though it is only one test: feed the suite a constant stream through
    the same scoring rule the gate applies."""
    from repro.prng.nist import run_nist_subset
    from repro.prng import quality
    res = run_nist_subset(np.zeros(10_000, np.uint32))
    hard = [k for k, v in res.items() if v["p_value"] < quality.ALPHA_HARD]
    assert hard  # monobit at least
