import os
import pathlib
import sys

# Keep CPU device count at 1 for smoke tests/benches (the dry-run sets its
# own 512-device flag in-process, in a subprocess when tested).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Make the tests dir importable (for _propshim) regardless of invocation dir.
sys.path.insert(0, str(pathlib.Path(__file__).parent))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
