"""Pallas chaotic-ANN kernel vs the pure-jnp oracle: shape/dtype sweep in
interpret mode (per-kernel allclose requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.kernels.chaotic_ann import chaotic_ann_pallas
from repro.kernels.ops import bits_from_trajectory, chaotic_trajectory
from repro.kernels.ref import chaotic_ann_ref


def _mk(i_dim, h_dim, s, key=0, scale=0.5):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    w1 = jax.random.normal(ks[0], (i_dim, h_dim)) * scale
    b1 = jax.random.normal(ks[1], (h_dim,)) * 0.1
    w2 = jax.random.normal(ks[2], (h_dim, i_dim)) * scale
    b2 = jax.random.normal(ks[3], (i_dim,)) * 0.1
    x0 = jax.random.normal(ks[4], (s, i_dim)) * 0.5
    return w1, b1, w2, b2, x0


SWEEP = [
    # (I, H, S, T, s_block, t_block, unroll, unit)
    (3, 4, 128, 32, 128, 32, 1, "vpu"),
    (3, 8, 256, 64, 128, 32, 2, "vpu"),
    (3, 16, 256, 64, 256, 64, 4, "vpu"),
    (3, 8, 256, 64, 256, 32, 1, "mxu"),
    (4, 8, 384, 48, 128, 16, 4, "mxu"),
    (6, 32, 128, 32, 128, 32, 8, "vpu"),
    (2, 4, 512, 16, 256, 16, 16, "vpu"),
]


@pytest.mark.parametrize("i,h,s,t,sb,tb,un,unit", SWEEP)
def test_kernel_matches_ref_sweep(i, h, s, t, sb, tb, un, unit):
    w1, b1, w2, b2, x0 = _mk(i, h, s)
    got = chaotic_ann_pallas(w1, b1, w2, b2, x0, n_steps=t, s_block=sb,
                             t_block=tb, unroll=un, compute_unit=unit,
                             interpret=True)
    want = chaotic_ann_ref(w1, b1, w2, b2, x0, t)
    assert got.shape == want.shape == (t, s, i)
    # chaotic divergence amplifies fp reordering ~exp(λt) (λ up to ~2/step
    # for random weights); only a short prefix is bitwise-comparable.
    np.testing.assert_allclose(np.asarray(got[:4]), np.asarray(want[:4]),
                               atol=5e-4)
    assert bool(jnp.all(jnp.isfinite(got)))


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 5e-5), (jnp.bfloat16, 5e-2)])
def test_kernel_dtypes(dtype, atol):
    w1, b1, w2, b2, x0 = _mk(3, 8, 128)
    x0 = x0.astype(dtype)
    got = chaotic_ann_pallas(w1, b1, w2, b2, x0, n_steps=16, s_block=128,
                             t_block=16, interpret=True)
    want = chaotic_ann_ref(w1, b1, w2, b2, x0, 16)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got[:4], np.float32),
                               np.asarray(want[:4], np.float32), atol=atol)


def test_kernel_non_divisible_streams_padded():
    """S not a multiple of s_block: padding streams must not leak."""
    w1, b1, w2, b2, x0 = _mk(3, 8, 200)
    got = chaotic_ann_pallas(w1, b1, w2, b2, x0, n_steps=8, s_block=128,
                             t_block=8, interpret=True)
    want = chaotic_ann_ref(w1, b1, w2, b2, x0, 8)
    assert got.shape == (8, 200, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


def test_kernel_nonpow2_tblock_padding():
    """n_steps not a multiple of t_block."""
    w1, b1, w2, b2, x0 = _mk(3, 8, 128)
    got = chaotic_ann_pallas(w1, b1, w2, b2, x0, n_steps=25, s_block=128,
                             t_block=16, interpret=True)
    want = chaotic_ann_ref(w1, b1, w2, b2, x0, 25)
    assert got.shape == (25, 128, 3)
    np.testing.assert_allclose(np.asarray(got[:4]), np.asarray(want[:4]), atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(
    i=st.integers(2, 6), h=st.sampled_from([4, 8, 12, 16]),
    t=st.sampled_from([4, 8, 16]),
    unit=st.sampled_from(["vpu", "mxu"]),
    act=st.sampled_from(["relu", "tanh", "sigmoid"]),
)
def test_kernel_property_sweep(i, h, t, unit, act):
    """Property: for any tiny (I,H), activation and unit, the kernel equals
    the oracle over a short horizon."""
    w1, b1, w2, b2, x0 = _mk(i, h, 128, key=i * 31 + h)
    got = chaotic_ann_pallas(w1, b1, w2, b2, x0, n_steps=t, s_block=128,
                             t_block=t, activation=act, compute_unit=unit,
                             interpret=True)
    want = chaotic_ann_ref(w1, b1, w2, b2, x0, t, act)
    np.testing.assert_allclose(np.asarray(got[:4]), np.asarray(want[:4]),
                               atol=1e-4)


def test_ops_backend_selection():
    w1, b1, w2, b2, x0 = _mk(3, 8, 128)
    params = {"w1": w1, "b1": b1, "w2": w2, "b2": b2}
    a = chaotic_trajectory(params, x0, 16, backend="ref")
    b = chaotic_trajectory(params, x0, 16, backend="pallas_interpret",
                           s_block=128, t_block=16)
    np.testing.assert_allclose(np.asarray(a[:4]), np.asarray(b[:4]), atol=5e-5)


def test_bits_deterministic_and_balanced():
    w1, b1, w2, b2, x0 = _mk(3, 8, 256)
    params = {"w1": w1, "b1": b1, "w2": w2, "b2": b2}
    traj = chaotic_trajectory(params, x0, 512, backend="ref")
    bits1 = bits_from_trajectory(traj)
    bits2 = bits_from_trajectory(traj)
    assert bits1.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(bits1), np.asarray(bits2))
    ones = np.unpackbits(np.asarray(bits1).view(np.uint8)).mean()
    assert abs(ones - 0.5) < 0.02
